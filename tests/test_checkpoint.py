"""Checkpointing: atomic save/restore, hashes, async manager, sparse
layouts, bf16, elastic restore template."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, load_pytree, save_pytree
from repro.core.layouts import FixedMaskTensor
from repro.core import nmg

KEY = jax.random.PRNGKey(0)


def tree():
    return {
        "dense": jax.random.normal(KEY, (8, 16)),
        "bf16": jax.random.normal(KEY, (4, 4)).astype(jnp.bfloat16),
        "sparse": FixedMaskTensor.from_dense(
            jax.random.normal(jax.random.PRNGKey(1), (8, 8))),
        "nmg": nmg.dense_to_grouped_nm(
            jax.random.normal(jax.random.PRNGKey(2), (8, 96)), 2, 4, 2),
        "step": jnp.asarray(7, jnp.int32),
    }


def assert_tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(x, dtype=np.float32) if hasattr(x, "dtype") and
            "bfloat16" in str(x.dtype) else np.asarray(x),
            np.asarray(y, dtype=np.float32) if hasattr(y, "dtype") and
            "bfloat16" in str(y.dtype) else np.asarray(y),
        )


def test_save_load_roundtrip(tmp_path):
    t = tree()
    save_pytree(t, tmp_path / "ck", meta={"step": 7})
    t2, meta = load_pytree(t, tmp_path / "ck")
    assert meta["step"] == 7
    assert_tree_equal(t, t2)
    assert isinstance(t2["sparse"], FixedMaskTensor)


def test_corruption_detected(tmp_path):
    t = {"w": jnp.arange(16.0)}
    save_pytree(t, tmp_path / "ck")
    man = json.loads((tmp_path / "ck" / "MANIFEST.json").read_text())
    man["index"][0]["sha"] = "deadbeefdeadbeef"
    (tmp_path / "ck" / "MANIFEST.json").write_text(json.dumps(man))
    with pytest.raises(IOError):
        load_pytree(t, tmp_path / "ck")


def test_structure_mismatch_detected(tmp_path):
    save_pytree({"w": jnp.ones(4)}, tmp_path / "ck")
    with pytest.raises(ValueError):
        load_pytree({"w": jnp.ones(4), "extra": jnp.ones(2)},
                    tmp_path / "ck")


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = {"w": jnp.zeros(4)}
    for step in (10, 20, 30):
        mgr.save(step, {"w": jnp.full(4, float(step))}, blocking=True)
    assert mgr.latest_step() == 30
    dirs = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(dirs) == 2  # rotation kept the last two
    step, got, _ = mgr.restore_latest(t)
    assert step == 30
    np.testing.assert_allclose(np.asarray(got["w"]), 30.0)


def test_restore_template_shapedtype(tmp_path):
    """Elastic restore: the template can be ShapeDtypeStructs (fresh job)."""
    t = {"w": jax.random.normal(KEY, (4, 4))}
    save_pytree(t, tmp_path / "ck")
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    t2, _ = load_pytree(template, tmp_path / "ck")
    assert_tree_equal(t, t2)


def test_atomic_commit_no_partial(tmp_path):
    """A directory without MANIFEST is never produced by a finished save."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.ones(8)}, blocking=True)
    for d in tmp_path.glob("step_*"):
        assert (d / "MANIFEST.json").exists()

"""Serving-path correctness: token-by-token decode from a prefilled cache
must reproduce the parallel forward's logits (teacher forcing), for each
attention family — this exercises KV caches, MLA latent caches, absorbed
decode, SSM recurrence vs chunked scan, ring caches, and hybrid fusion."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import decode_step, forward, init_lm, logits_of, prefill

B, S_PRE, S_GEN = 2, 16, 6

FAMILIES = {
    "bert-base-sten": 2e-3,   # plain GQA/MHA
    "minicpm3-4b": 2e-2,      # MLA absorbed decode vs full-rank forward
    "mamba2-370m": 2e-3,      # SSD chunked scan vs recurrence
    "hymba-1.5b": 2e-3,       # hybrid window attn + SSM
    "gemma2-9b": 2e-3,        # local/global pairs, softcaps, ring cache
    "qwen1.5-4b": 2e-3,       # QKV bias
    "starcoder2-15b": 2e-3,   # GQA + non-gated MLP
}


@pytest.mark.parametrize("arch", sorted(FAMILIES))
def test_decode_matches_forward(arch):
    cfg = dataclasses.replace(get_smoke(arch), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    total = S_PRE + S_GEN
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab, jnp.int32)

    # parallel forward over the whole sequence (ground truth)
    hidden, _ = forward(params, cfg, toks, remat="none")
    full_logits = np.asarray(logits_of(params, cfg, hidden),
                             dtype=np.float32)

    # prefill the first S_PRE tokens, then teacher-forced decode
    logits, cache = prefill(params, cfg, toks[:, :S_PRE], cache_len=total)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), full_logits[:, S_PRE - 1],
        rtol=FAMILIES[arch], atol=FAMILIES[arch],
    )
    for i in range(S_GEN):
        tok = toks[:, S_PRE + i][:, None]
        got, cache = decode_step(params, cfg, tok, cache,
                                 jnp.asarray(S_PRE + i))
        want = full_logits[:, S_PRE + i]
        np.testing.assert_allclose(
            np.asarray(got, np.float32), want,
            rtol=FAMILIES[arch], atol=FAMILIES[arch],
            err_msg=f"{arch} step {i}",
        )


def test_int8_kv_cache_decode():
    """int8 KV/latent caches: teacher-forced decode stays within quantization
    tolerance of the f32-cache forward (the §Perf serving optimization)."""
    for arch in ("qwen1.5-4b", "minicpm3-4b"):
        cfg = dataclasses.replace(get_smoke(arch), dtype="float32",
                                  kv_cache_dtype="int8")
        key = jax.random.PRNGKey(0)
        params = init_lm(key, cfg)
        toks = jax.random.randint(key, (2, 24), 0, cfg.vocab, jnp.int32)
        hidden, _ = forward(params, cfg, toks, remat="none")
        full = np.asarray(logits_of(params, cfg, hidden), np.float32)
        logits, cache = prefill(params, cfg, toks[:, :16], cache_len=24)
        for i in range(4):
            tok = toks[:, 16 + i][:, None]
            got, cache = decode_step(params, cfg, tok, cache,
                                     jnp.asarray(16 + i))
            np.testing.assert_allclose(
                np.asarray(got, np.float32), full[:, 16 + i],
                atol=0.05, rtol=0.05, err_msg=f"{arch} step {i}")

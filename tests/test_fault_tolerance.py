"""Fault tolerance: watchdog, remesh planning, kill/resume training."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.dist.elastic import StragglerWatchdog, plan_remesh

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_plan_remesh():
    assert plan_remesh(512, 16) == (32, 16)
    assert plan_remesh(500, 16) == (31, 16)  # lost 12 chips -> smaller DP
    with pytest.raises(ValueError):
        plan_remesh(8, 16)


def test_watchdog_flags_slow_host():
    w = StragglerWatchdog(n_hosts=4, min_steps=5)
    for step in range(10):
        for h in range(4):
            w.observe(h, 1.0 if h != 2 else 3.5)
    assert w.stragglers() == [2]


def test_watchdog_quiet_when_uniform():
    w = StragglerWatchdog(n_hosts=4, min_steps=5)
    for step in range(10):
        for h in range(4):
            w.observe(h, 1.0 + 0.01 * h)
    assert w.stragglers() == []


def test_watchdog_flags_straggler_on_two_hosts():
    # leave-one-out reference: a 2-host fleet can still flag its straggler
    w = StragglerWatchdog(n_hosts=2, min_steps=5)
    for step in range(10):
        w.observe(0, 1.0)
        w.observe(1, 10.0)
    assert w.stragglers() == [1]


def test_watchdog_unwarmed_host_does_not_silence_fleet():
    # host 2 never reports (hung); the warmed-up hosts stay monitored
    w = StragglerWatchdog(n_hosts=3, min_steps=5)
    for step in range(10):
        w.observe(0, 1.0)
        w.observe(1, 5.0)
    assert w.stragglers() == [1]


def test_train_kill_resume(tmp_path):
    """Train 20 steps with checkpoints, 'crash', resume to 30 — loss stream
    continues and the data pipeline picks up at the exact step."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    base = [sys.executable, "-m", "repro.launch.train",
            "--arch", "bert-base-sten", "--smoke", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(tmp_path),
            "--ckpt-every", "10", "--log-every", "5"]
    out1 = subprocess.run(base + ["--steps", "20"], capture_output=True,
                          text=True, env=env, timeout=600)
    assert out1.returncode == 0, out1.stderr
    out2 = subprocess.run(base + ["--steps", "30", "--resume"],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert out2.returncode == 0, out2.stderr
    assert "resumed from step 20" in out2.stdout
    # resumed run starts where the first left off
    assert "step    20" in out2.stdout

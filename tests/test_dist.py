"""Distributed tests — run in subprocesses with 8 fake host devices so the
main test process keeps seeing 1 CPU device (assignment: never set the
device-count flag globally)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, timeout=600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_pjit_train_step_8dev():
    """A jitted train step under a (2 data, 4 model) mesh produces finite
    loss and keeps sparse masks intact."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.dist.sharding import ShardingRules, param_specs, \\
            tree_shardings
        from repro.launch import steps as steps_mod
        from repro.launch.mesh import make_host_mesh
        from repro.models import init_lm
        from repro.optim import AdamWConfig, adamw_init

        assert len(jax.devices()) == 8
        cfg = get_smoke("bert-base-sten")
        mesh = make_host_mesh(2, 4)
        rules = ShardingRules()
        params = init_lm(jax.random.PRNGKey(0), cfg)
        opt = adamw_init(params)
        step = steps_mod.make_train_step(
            cfg, AdamWConfig(lr=1e-3), steps_mod.StepConfig(remat="none"),
            mesh, rules)
        p_sh = tree_shardings(param_specs(params, rules, mesh), mesh)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                          cfg.vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                                          cfg.vocab),
        }
        with mesh:
            params = jax.device_put(params, p_sh)
            jstep = jax.jit(step)
            p2, o2, m = jstep(params, opt, batch)
            p3, o3, m2 = jstep(p2, o2, batch)
        assert np.isfinite(float(m["loss"]))
        assert float(m2["loss"]) < float(m["loss"]) + 1.0
        print("OK", float(m["loss"]), float(m2["loss"]))
    """)
    assert "OK" in out


def test_fixed_mask_value_allreduce_equals_dense():
    """The beyond-paper value-only all-reduce must equal the paper's
    densify->allreduce->resparsify result when masks match."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.layouts import FixedMaskTensor
        from repro.dist.collectives import (densify_allreduce_resparsify,
                                            fixed_mask_value_allreduce)
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(8, 1)
        val = jax.random.normal(jax.random.PRNGKey(0), (16, 16))
        mask = jax.random.bernoulli(jax.random.PRNGKey(1), 0.25, (16, 16))
        g = FixedMaskTensor(val, mask)
        with mesh:
            a = fixed_mask_value_allreduce(g, mesh, "data")
            b = densify_allreduce_resparsify(g, mesh, "data")
        np.testing.assert_allclose(np.asarray(a.to_dense()),
                                   np.asarray(b.to_dense()), rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_topk_compressed_allreduce():
    """Top-k + error feedback: compressed exchange approximates the dense
    all-reduce and the residual shrinks what is lost."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.compression import (compressed_allreduce, ef_step)
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(8, 1)
        g = jax.random.normal(jax.random.PRNGKey(0), (32, 32))
        mem = jnp.zeros_like(g)
        (vals, idx), mem2 = ef_step(g, mem, k_fraction=0.25)
        with mesh:
            approx = compressed_allreduce(vals, idx, g.shape, mesh, "data")
        # every replica contributed the same (replicated) compressed grad
        dense_topk = jnp.zeros(g.size).at[idx].add(vals).reshape(g.shape)
        np.testing.assert_allclose(np.asarray(approx),
                                   np.asarray(dense_topk), rtol=1e-5)
        # error feedback holds the complement
        np.testing.assert_allclose(np.asarray(mem2 + dense_topk),
                                   np.asarray(g), rtol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_mini_dryrun_8dev():
    """End-to-end mini dry-run: lower+compile the smoke config on an 8-dev
    mesh and check the structural analyzer returns sane numbers."""
    out = run_sub("""
        import jax, jax.numpy as jnp, json
        from repro.configs import get_smoke
        from repro.dist.sharding import ShardingRules, param_specs, \\
            tree_shardings
        from repro.launch import steps as steps_mod
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.launch.mesh import make_host_mesh
        from repro.models import init_lm
        from repro.optim import AdamWConfig, adamw_init
        import functools

        cfg = get_smoke("bert-base-sten")
        mesh = make_host_mesh(2, 4)
        rules = ShardingRules()
        key = jax.random.PRNGKey(0)
        p_shapes = jax.eval_shape(functools.partial(init_lm, cfg=cfg), key)
        o_shapes = jax.eval_shape(adamw_init, p_shapes)
        step = steps_mod.make_train_step(
            cfg, AdamWConfig(), steps_mod.StepConfig(), mesh, rules)
        p_sh = tree_shardings(param_specs(p_shapes, rules, mesh), mesh)
        batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        with mesh:
            comp = jax.jit(step, in_shardings=(p_sh, None, None)).lower(
                p_shapes, o_shapes, batch).compile()
        r = analyze_hlo(comp.as_text())
        assert r["flops"] > 1e6, r
        assert r["collectives"]["total"] > 0, r
        assert r["max_trip"] >= cfg.n_layers
        print("OK", json.dumps({k: r[k] for k in ("flops", "max_trip")}))
    """)
    assert "OK" in out

"""Differential tests for the decode megakernels (kernels/nmg_fused.py).

The fusion contract is *bitwise* equivalence, not allclose: the fused QKV
launch runs the identical per-chunk accumulation the per-projection gemv
runs (same kernel body over row-concatenated operands), and the fused
gated-FFN epilogue replays the sequential cast/split/act/multiply ops
exactly.  Any kernel change that reorders the arithmetic breaks these
tests on purpose.

Three layers of evidence:

* fused ≡ sequential bitwise per dtype (f32 accumulation pinned), on both
  the Pallas-interpret and XLA backends, plus allclose vs the ``ref.py``
  oracles (the trivially-auditable implementations);
* ``kernel_counters`` proof that the fused route is **one** launch per
  decode step (the sequential per-projection counters stay silent);
* a hypothesis property that routing — table vetoes vs shipped defaults —
  never changes outputs, only which kernel computed them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.nmg_fused import (fusable_ffn, fusable_qkv,
                                     nmg_ffn_pallas, nmg_qkv_pallas)
from repro.kernels.nmg_gemv import nmg_gemv_pallas
from repro.tune import routing
from repro.tune.table import TuningTable

from tests._hypothesis_compat import given, settings, st

KEY = jax.random.PRNGKey(7)
FMT = (1, 4, 8, 64)  # the fig11 serving format
D = 256


def _proj(key, R, dtype=jnp.float32, fmt=FMT):
    n, m, g, gr = fmt
    w = jax.random.normal(key, (D, R)).astype(dtype)
    return nmg.dense_to_grouped_nm(w, n=n, m=m, g=g, gr=gr, sparse_dim=0)


def _qkv_group(dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (_proj(ks[0], 256, dtype), _proj(ks[1], 128, dtype),
            _proj(ks[2], 128, dtype))


# ---------------------------------------------------------------------------
# bitwise: fused == sequential per dtype, both backends
# ---------------------------------------------------------------------------


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_qkv_pallas_bitwise_equals_sequential(out_dtype):
    """One megakernel launch == three gemv launches, bit for bit (shared
    kernel body over concatenated operands; f32 accumulation pinned by the
    bf16 case, whose epilogue rounds once)."""
    ws = _qkv_group()
    b = jax.random.normal(jax.random.PRNGKey(1), (D, 4))
    fused = nmg_qkv_pallas(ws, b, out_dtype=out_dtype, interpret=True)
    for w, f in zip(ws, fused):
        s = nmg_gemv_pallas(w, b, out_dtype=out_dtype, interpret=True)
        assert f.dtype == jnp.dtype(out_dtype)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(s))


@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_qkv_xla_bitwise_equals_sequential(out_dtype):
    ws = _qkv_group()
    b = jax.random.normal(jax.random.PRNGKey(1), (D, 4))
    fused = kops.nmg_qkv_xla(ws, b, out_dtype=out_dtype)
    for w, f in zip(ws, fused):
        s = kops.nmg_gemv_xla(w, b, out_dtype=out_dtype)
        np.testing.assert_array_equal(np.asarray(f), np.asarray(s))


def test_fused_qkv_matches_oracle():
    ws = _qkv_group()
    b = jax.random.normal(jax.random.PRNGKey(1), (D, 4))
    want = kref.nmg_qkv_ref(ws, b)
    for backend in (True, False):  # pallas, xla
        got = kops.nmg_qkv(ws, b, use_pallas=backend)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=1e-4, atol=1e-4)


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("act", ["silu", "gelu"])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ffn_pallas_bitwise_equals_sequential(act, out_dtype):
    """Projection + split + act + gate in one launch == the sequential
    ops: the kernel epilogue casts the two f32 accumulators to the
    activation dtype *first* and gates *second*, exactly the order the
    model path runs them.  silu is pinned **bitwise** (the logistic
    lowers to one codegen-stable primitive); approximate-gelu's tanh
    polynomial compiles to ulp-different code depending on what XLA fuses
    it with, so gelu pins tight allclose instead."""
    wi = _proj(KEY, 2 * 128)                   # packed [D, 2F]
    b = jax.random.normal(jax.random.PRNGKey(2), (D, 4))
    hh = nmg_gemv_pallas(wi, b, out_dtype=out_dtype, interpret=True)
    u, v = jnp.split(hh.T, 2, axis=-1)         # the model splits [M, 2F]
    if act == "silu":
        seq = (jax.nn.silu(u) * v).T
    else:
        seq = (jax.nn.gelu(u, approximate=True) * v).T
    fused = nmg_ffn_pallas(wi, b, act=act, out_dtype=out_dtype,
                           interpret=True)
    assert fused.dtype == jnp.dtype(out_dtype)
    if act == "silu":
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))
    else:
        np.testing.assert_allclose(
            np.asarray(fused).astype(np.float32),
            np.asarray(seq).astype(np.float32), rtol=1e-4, atol=1e-4,
        )


@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_fused_ffn_matches_oracle(act):
    wi = _proj(KEY, 2 * 128)
    b = jax.random.normal(jax.random.PRNGKey(2), (D, 4))
    want = np.asarray(kref.nmg_ffn_ref(wi, b, act=act))
    for backend in (True, False):
        got = kops.nmg_ffn(wi, b, act=act, use_pallas=backend)
        np.testing.assert_allclose(np.asarray(got), want,
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# eligibility and launch-count evidence
# ---------------------------------------------------------------------------


def test_fusability_checks():
    wq, wk, wv = _qkv_group()
    assert fusable_qkv((wq, wk, wv))
    other_fmt = _proj(KEY, 128, fmt=(2, 4, 8, 64))
    assert not fusable_qkv((wq, other_fmt))    # mixed formats
    assert not fusable_qkv((wq, jnp.zeros((D, 128))))  # dense member
    assert not fusable_qkv(())
    wi = _proj(KEY, 2 * 128)
    assert fusable_ffn(wi, 128)
    assert not fusable_ffn(wi, 64)             # wrong F
    assert not fusable_ffn(jnp.zeros((D, 256)), 128)


def test_fused_route_is_single_launch_per_step():
    """kernel_counters: a fused decode step traces one ("nmg_qkv",
    "fused[...]") route and NO per-projection nmg_gemv/nmg_linear routes —
    the megakernel claim is exactly 'one launch where three were'."""
    ws = _qkv_group()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, D))  # decode-shaped
    kops.reset_kernel_counters()
    ys = kops.maybe_fused_qkv(x, ws)
    assert ys is not None
    counts = kops.kernel_counters()
    assert counts.get(("nmg_qkv", "fused[default]")) == 1, counts
    assert not any(k[0] in ("nmg_gemv", "nmg_linear") for k in counts), counts

    kops.reset_kernel_counters()
    y = kops.maybe_fused_ffn(x, _proj(KEY, 2 * 128), act="silu")
    assert y is not None and y.shape == (4, 128)
    counts = kops.kernel_counters()
    assert counts.get(("nmg_ffn", "fused[default]")) == 1, counts
    assert not any(k[0] in ("nmg_gemv", "nmg_linear") for k in counts), counts


def test_prefill_shaped_x_declines_fusion():
    """Wide x (prefill regime) must fall back (None) so the SpMM path
    keeps serving the large-M shapes it wins."""
    ws = _qkv_group()
    x = jax.random.normal(jax.random.PRNGKey(3), (kops.DECODE_M_MAX + 1, D))
    assert kops.maybe_fused_qkv(x, ws) is None
    assert kops.maybe_fused_ffn(x, _proj(KEY, 2 * 128), act="silu") is None


def test_table_veto_falls_back_bitwise():
    """A table that vetoes fusion changes the launch structure, not one
    bit of output."""
    ws = _qkv_group()
    x = jax.random.normal(jax.random.PRNGKey(3), (4, D))
    fused = kops.maybe_fused_qkv(x, ws)
    assert fused is not None
    tab = TuningTable.for_device()
    tab.entries["fused_qkv"] = False
    routing.set_active_table(tab)
    try:
        kops.reset_kernel_counters()
        assert kops.maybe_fused_qkv(x, ws) is None
        counts = kops.kernel_counters()
        assert counts.get(("nmg_qkv", "sequential[table]")) == 1, counts
        seq = tuple(kops.nmg_linear(x, w) for w in ws)
    finally:
        routing.clear_active_table()
    for f, s in zip(fused, seq):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(s))


# ---------------------------------------------------------------------------
# property: routing never changes outputs
# ---------------------------------------------------------------------------

_WS_CACHE = {}


def _cached_group(dtype_name):
    if dtype_name not in _WS_CACHE:
        _WS_CACHE[dtype_name] = _qkv_group(jnp.dtype(dtype_name))
    return _WS_CACHE[dtype_name]


@settings(max_examples=20, deadline=None)
@given(
    m_rows=st.integers(min_value=1, max_value=8),
    fuse_qkv=st.booleans(),
    fuse_ffn=st.booleans(),
    thr=st.sampled_from([None, 4, 16]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_routing_never_changes_outputs(m_rows, fuse_qkv, fuse_ffn, thr, seed):
    """Hypothesis property: for any table (fusion vetoes, decode_m_max
    overrides) the linear-level results equal the default-routed results
    bitwise.  Routing picks kernels; kernels agree."""
    ws = _cached_group("float32")
    wi = ws[0]  # square [D, D] packed weight doubles as a 2F=256 gated pair
    x = jax.random.normal(jax.random.PRNGKey(seed), (m_rows, D))

    def run_all():
        qkv = kops.maybe_fused_qkv(x, ws)
        if qkv is None:
            qkv = tuple(kops.nmg_linear(x, w) for w in ws)
        ffn = kops.maybe_fused_ffn(x, wi, act="silu")
        if ffn is None:
            hh = kops.nmg_linear(x, wi)
            u, v = jnp.split(hh, 2, axis=-1)
            ffn = jax.nn.silu(u) * v
        return [np.asarray(a) for a in (*qkv, ffn)]

    routing.clear_active_table()
    want = run_all()

    tab = TuningTable.for_device()
    tab.entries["fused_qkv"] = fuse_qkv
    tab.entries["fused_ffn"] = fuse_ffn
    if thr is not None:
        tab.entries["decode_m_max"] = thr
    routing.set_active_table(tab)
    try:
        got = run_all()
    finally:
        routing.clear_active_table()

    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)

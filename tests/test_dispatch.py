"""Operator dispatch: registration, conversion chain, dense fallback,
patching API, sparsified_op, and the paper's extensibility example."""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import sten
from repro.core.dispatch import SparseFallbackWarning, _find_impl
from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    SparsityLayout,
    register_layout,
)
from repro.core.sparsifiers import (
    KeepAll,
    RandomFractionSparsifier,
    ScalarFractionSparsifier,
    ScalarThresholdSparsifier,
    register_sparsifier_implementation,
)

KEY = jax.random.PRNGKey(0)


def sparse(x, frac=0.6, layout=CsrTensor):
    return sten.apply_sparsifier(ScalarFractionSparsifier(frac), x, layout)


def test_csr_dense_matmul_dispatch():
    a = sparse(jax.random.normal(KEY, (8, 12)))
    b = jax.random.normal(jax.random.PRNGKey(1), (12, 5))
    c = sten.matmul(a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a.to_dense() @ b),
                               rtol=1e-5)


def test_dense_csr_matmul_dispatch():
    a = jax.random.normal(KEY, (5, 12))
    b = sparse(jax.random.normal(jax.random.PRNGKey(1), (12, 8)))
    c = sten.matmul(a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(a @ b.to_dense()),
                               rtol=1e-5)


def test_conversion_chain_coo_to_csr():
    """No (COO, Dense) matmul impl exists; dispatch must losslessly convert
    COO -> CSR and use the CSR implementation."""
    x = jax.random.normal(KEY, (8, 12))
    a = CooTensor.from_dense(x)
    b = jax.random.normal(jax.random.PRNGKey(1), (12, 5))
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)  # no fallback!
        c = sten.matmul(a, b)
    np.testing.assert_allclose(np.asarray(c), np.asarray(x @ b), rtol=1e-5)


def test_dense_fallback_warns():
    a = sparse(jax.random.normal(KEY, (4, 4)))
    with pytest.warns(SparseFallbackWarning):
        out = sten.relu(a)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(jax.nn.relu(a.to_dense())), rtol=1e-6
    )


def test_all_dense_short_circuit():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        out = sten.matmul(jnp.ones((2, 3)), jnp.ones((3, 2)))
    np.testing.assert_allclose(out, 3 * np.ones((2, 2)))


def test_coo_keepall_add_union():
    """Paper §3.3: keep-all sparse add = union of nonzeros."""
    x1 = jnp.zeros((4, 4)).at[0, 0].set(1.0)
    x2 = jnp.zeros((4, 4)).at[3, 3].set(2.0)
    c = sten.add(CooTensor.from_dense(x1), CooTensor.from_dense(x2))
    assert isinstance(c, CooTensor)
    np.testing.assert_allclose(np.asarray(c.to_dense()), np.asarray(x1 + x2))


def test_sparsified_op():
    sparse_add = sten.sparsified_op(
        jnp.add,
        sten.OutFormat(KeepAll(), DenseTensor,
                       RandomFractionSparsifier(0.5), CsrTensor),
    )
    out = sparse_add(jnp.ones((8, 8)), jnp.ones((8, 8)),
                     key=jax.random.PRNGKey(3))
    assert isinstance(out, CsrTensor)
    assert 0.2 < out.density() < 0.8
    d = np.asarray(out.to_dense())
    assert set(np.unique(d)) <= {0.0, 2.0}


def test_fused_inline_sparsifier():
    """matmul + ScalarThreshold registered as a fused kernel implementation:
    dispatch must pick it (no fallback) and produce a FixedMaskTensor."""
    a = jax.random.normal(KEY, (16, 32))
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    op = sten.sparsified_op(
        "matmul",
        sten.OutFormat(ScalarThresholdSparsifier(1.0), FixedMaskTensor,
                       KeepAll(), FixedMaskTensor),
        dense_fn=jnp.matmul,
    )
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)
        out = op(a, b)
    assert isinstance(out, FixedMaskTensor)
    ref = np.asarray(a @ b)
    ref = ref * (np.abs(ref) >= 1.0)
    np.testing.assert_allclose(np.asarray(out.to_dense()), ref, rtol=1e-4,
                               atol=1e-5)


def test_patched_op_api():
    """Paper §4.4: patching an arbitrary external callable into the
    dispatcher."""
    def external_lib_scale(x, factor=2.0):
        return x * factor

    patched = sten.register_patched_op(external_lib_scale, "external_scale")
    # dense: passes straight through
    np.testing.assert_allclose(patched(jnp.ones(3)), 2 * np.ones(3))
    # sparse: routed into the dispatcher, which densifies with a warning
    a = sparse(jax.random.normal(KEY, (4, 4)))
    with pytest.warns(SparseFallbackWarning):
        out = patched(a)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a.to_dense() * 2.0), rtol=1e-6)


def test_extensibility_paper_example():
    """Paper §3.1: a user-defined CSC layout + one sparsifier registration
    enables full use (here including dispatch fallback)."""

    @register_layout
    class CscTensor(SparsityLayout):
        def __init__(self, data, indices, indptr, dense_shape):
            self.data, self.indices, self.indptr = data, indices, indptr
            self.dense_shape = dense_shape

        @property
        def shape(self):
            return tuple(self.dense_shape)

        @property
        def dtype(self):
            return self.data.dtype

        def to_dense(self):
            # CSC of X == CSR of X^T
            return CsrTensor(self.data, self.indices, self.indptr,
                             (self.dense_shape[1], self.dense_shape[0])
                             ).to_dense().T

        def tree_flatten(self):
            return (self.data, self.indices, self.indptr), (self.dense_shape,)

        @classmethod
        def tree_unflatten(cls, aux, children):
            return cls(*children, *aux)

    @register_sparsifier_implementation(
        RandomFractionSparsifier, DenseTensor, CscTensor)
    def dense_to_csc_random_fraction(sp, x, key=None):
        dense = x.to_dense() if hasattr(x, "to_dense") else x
        mask = sp.mask(dense, key or jax.random.PRNGKey(0))
        t = CsrTensor.from_dense((dense * mask).T)
        return CscTensor(t.data, t.indices, t.indptr,
                         (dense.shape[0], dense.shape[1]))

    x = jax.random.normal(KEY, (6, 10))
    t = sten.apply_sparsifier(RandomFractionSparsifier(0.5), x, CscTensor)
    assert isinstance(t, CscTensor)
    d = np.asarray(t.to_dense())
    kept = d != 0
    np.testing.assert_allclose(d[kept], np.asarray(x)[kept], rtol=1e-6)
    # matmul is covered without any CSC-specific registration: the
    # dispatcher losslessly converts (Csc->Dense, Dense->CSR) to reach a
    # registered implementation — no warning, exact result (paper §4.4)
    y = sten.matmul(t, jnp.eye(10))
    np.testing.assert_allclose(np.asarray(y), d, rtol=1e-5, atol=1e-6)
    # ops with no conversion path use the dense fallback and warn
    with pytest.warns(SparseFallbackWarning):
        z = sten.relu(t)
    np.testing.assert_allclose(np.asarray(z), np.maximum(d, 0), rtol=1e-6)


def test_register_op_impl_records_dense_reference():
    """Registering an impl under a callable op records that callable as the
    dense reference, so signatures with no sparse impl (and no conversion
    path) fall back to it instead of raising (regression: the branch was
    dead and the fallback raised NotImplementedError)."""
    from repro.core.dispatch import dispatch, register_op_impl
    from repro.core.layouts import GroupedNMTensor

    def triple_ref_op(x):
        return x * 3.0

    @register_op_impl(triple_ref_op, inp=(GroupedNMTensor,))
    def _nmg_triple(a):  # pragma: no cover - never reached in this test
        return a.to_dense() * 3.0

    # CSR cannot losslessly become GroupedNM, so the only route is the
    # dense reference recorded at registration time (with a warning)
    a = sparse(jax.random.normal(KEY, (4, 4)))
    with pytest.warns(SparseFallbackWarning):
        out = dispatch("triple_ref_op", a)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(a.to_dense() * 3.0), rtol=1e-6)


def test_dense_tensor_wrappers_do_not_warn_on_fallback():
    """Densifying a DenseTensor wrapper costs nothing — no fallback warning
    (mm's fused-inline path wraps dense operands to reach fused impls)."""
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)
        out = sten.relu(DenseTensor(jnp.asarray([-1.0, 2.0])))
    np.testing.assert_allclose(np.asarray(out), [0.0, 2.0])


def test_find_impl_prefers_fewest_conversions():
    impl, sig = _find_impl("matmul", (CsrTensor, DenseTensor), None)
    assert impl is not None and sig is None  # exact match, no conversion


def test_fallback_warning_dedupes_per_signature():
    """The dense-fallback *warning* fires once per (op, signature) per
    process — a scan-over-layers model that falls back retraces the same
    signature n_layers times and must not emit n_layers identical lines —
    while the counter keeps counting every trace (the telemetry half)."""
    import importlib

    disp = importlib.import_module("repro.core.dispatch")
    a = sparse(jax.random.normal(KEY, (4, 4)))
    with pytest.warns(SparseFallbackWarning):
        sten.relu(a)
    # same (op, sig) again: counted, not re-warned
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)
        sten.relu(a)
    counts = disp.dispatch_counters()
    key = ("dense_fallback", "relu", ("CsrTensor",))
    assert counts.get(key) == 2
    # a different signature still warns fresh
    with pytest.warns(SparseFallbackWarning):
        sten.relu(CooTensor.from_dense(jax.random.normal(KEY, (4, 4))))
    # and the conftest reset (reset_dispatch_counters) re-arms the dedupe,
    # so pytest.warns-based tests stay order-independent
    disp.reset_dispatch_counters()
    with pytest.warns(SparseFallbackWarning):
        sten.relu(a)

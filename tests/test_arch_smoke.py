"""Per-architecture reduced-config smoke tests (assignment deliverable (f)):
instantiate each family at small scale, run one forward/train step on CPU,
assert output shapes + finite values; plus a prefill+decode step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import decode_step, init_lm, loss_fn, prefill
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    value_and_grad_sparse

B, S = 2, 32
ARCH_IDS = [a for a in ARCHS if a != "bert-base-sten"]


def make_batch(cfg, key):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(99), (B, S), 0,
                                     cfg.vocab),
    }
    if cfg.vision_prefix:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.vision_prefix, cfg.d_model), cfg.jdtype)
    if cfg.n_enc_layers:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, 16, cfg.d_model), cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    batch = make_batch(cfg, key)
    (loss, aux), grads = value_and_grad_sparse(
        lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    assert float(loss) > 0.5  # not degenerate
    # one optimizer step keeps everything finite
    state = adamw_init(params)
    new_p, new_s, m = adamw_update(grads, state, params, AdamWConfig(lr=1e-3))
    for leaf in jax.tree_util.tree_leaves(new_p):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = get_smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    batch = make_batch(cfg, key)
    logits, cache = prefill(params, cfg, batch["tokens"], cache_len=S + 4,
                            enc_embeds=batch.get("enc_embeds"),
                            prefix_embeds=batch.get("prefix_embeds"))
    assert logits.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = decode_step(params, cfg, tok, cache, jnp.asarray(S))
    assert logits2.shape == (B, cfg.vocab)
    assert np.isfinite(np.asarray(logits2, dtype=np.float32)).all()

"""Golden + differential tests for the n:m:g matmul kernels.

``kernels/nmg_spmm.py`` (interpret mode on CPU) is swept against the
densify-then-matmul oracle in ``kernels/ref.py`` across a grid of
(n, m, g, gr) formats and shapes with explicit tolerances, plus a golden
exact-arithmetic case and a regression assertion on the output dtype
(the kernel contract is an f32 accumulator regardless of input dtype).

The decode-optimized GEMV path gets the same treatment: an M-sweep
asserting ``gemv == spmm == oracle`` across formats and right-operand
widths (including the shape-router boundary), the dtype-preserving
epilogue contract, and plan-caching properties (a precomputed SpmmPlan
changes nothing but the work saved).
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.core.layouts import nm_patterns
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.nmg_gemv import nmg_gemv_pallas
from repro.kernels.nmg_spmm import nmg_spmm_pallas

KEY = jax.random.PRNGKey(42)

# (n, m, g, gr) format grid: paper CPU format (gr=1), TPU row-shared
# formats, single-pattern n=m corner, and wide-m patterns
FORMATS = [
    (1, 4, 1, 1),
    (1, 4, 4, 2),
    (2, 4, 2, 1),
    (2, 4, 2, 4),
    (2, 4, 16, 8),
    (3, 6, 1, 2),
    (1, 2, 8, 8),
    (2, 6, 2, 1),
]

# (R, K, N) including non-multiples of the chunk extent (padding paths)
SHAPES = [(8, 96, 32), (16, 192, 64), (5, 100, 33)]

TOL = {jnp.dtype(jnp.float32): 1e-4, jnp.dtype(jnp.bfloat16): 5e-2}


@pytest.mark.parametrize("fmt", FORMATS,
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.pallas_interpret
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_nmg_spmm_grid_vs_ref(fmt, shape):
    n, m, g, gr = fmt
    R, K, N = shape
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    ref = kref.nmg_spmm_ref(t, b)
    out = nmg_spmm_pallas(t, b, interpret=True)
    assert out.shape == (R, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nmg_spmm_output_dtype_regression(dtype):
    """Contract: the kernel accumulates and returns f32 for every input
    dtype (bf16 inputs must NOT demote the output)."""
    x = jax.random.normal(KEY, (8, 96)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 32)).astype(dtype)
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=4)
    out = nmg_spmm_pallas(t, b, interpret=True)
    assert out.dtype == jnp.float32, (
        f"kernel output demoted to {out.dtype} for {dtype} inputs"
    )
    tol = TOL[jnp.dtype(dtype)]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kref.nmg_spmm_ref(t, b)),
                               rtol=tol, atol=tol)


@pytest.mark.pallas_interpret
def test_nmg_spmm_golden_exact():
    """Golden case in exact f32 arithmetic: a matrix that is already
    2:4-sparse with small-integer values, multiplied by an identity-padded
    B, must reproduce the canonical dense view bit-exactly."""
    n, m, g = 2, 4, 2
    C = math.comb(m, n)
    R, K = 4, m * C * g  # one chunk per row fiber
    x = np.zeros((R, K), np.float32)
    rng = np.random.default_rng(0)
    pats = nm_patterns(n, m)
    for r in range(R):
        # each pattern used exactly g times per chunk — the format's
        # capacity constraint — in a shuffled block order, so the layout
        # is lossless by construction
        order = rng.permutation(np.repeat(np.arange(C), g))
        for blk, pat in enumerate(order):
            x[r, blk * m + pats[pat]] = rng.integers(1, 8, size=n)
    t = nmg.dense_to_grouped_nm(jnp.asarray(x), n=n, m=m, g=g)
    # lossless by construction
    np.testing.assert_array_equal(np.asarray(t.to_dense()), x)
    out = nmg_spmm_pallas(t, jnp.eye(K, dtype=jnp.float32), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), x)


# ---------------------------------------------------------------------------
# decode GEMV path: gemv == spmm == oracle across the M sweep
# ---------------------------------------------------------------------------

# right-operand widths: decode batches (1..8), the router boundary (16),
# and a prefill-shaped width (128) to pin both sides of the crossover
M_SWEEP = (1, 2, 4, 8, 16, 128)


@pytest.mark.parametrize("fmt", [(1, 4, 4, 2), (2, 4, 2, 4), (2, 4, 16, 8),
                                 (3, 6, 1, 2)],
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.parametrize("M", M_SWEEP)
def test_nmg_gemv_matches_spmm_and_oracle(fmt, M):
    n, m, g, gr = fmt
    R, K = 16, 192
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, M))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    oracle = np.asarray(kref.nmg_spmm_ref(t, b))
    spmm = np.asarray(kops.nmg_spmm_xla(t, b))
    gemv = np.asarray(kops.nmg_gemv_xla(t, b))
    assert gemv.shape == spmm.shape == (R, M)
    # same contraction order in f32 => the two XLA paths agree tightly
    np.testing.assert_allclose(gemv, spmm, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(gemv, oracle, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", [(1, 4, 4, 2), (2, 4, 2, 4)],
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.pallas_interpret
def test_nmg_gemv_pallas_interpret_matches_oracle(fmt):
    n, m, g, gr = fmt
    x = jax.random.normal(KEY, (8, 96))
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 4))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    out = nmg_gemv_pallas(t, b, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kref.nmg_spmm_ref(t, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nmg_gemv_dtype_preserving_epilogue(dtype):
    """Contract: accumulation is f32, but the epilogue emits the requested
    dtype — the serving path asks for the activation dtype and must not get
    a silent f32 round-trip (and default stays f32, the SpMM contract)."""
    x = jax.random.normal(KEY, (8, 96)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 4)).astype(dtype)
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=4)
    assert kops.nmg_gemv_xla(t, b).dtype == jnp.float32
    assert kops.nmg_gemv_xla(t, b, out_dtype=dtype).dtype == dtype
    assert nmg_gemv_pallas(t, b, out_dtype=dtype, interpret=True).dtype \
        == dtype
    tol = TOL[jnp.dtype(dtype)]
    np.testing.assert_allclose(
        np.asarray(kops.nmg_gemv_xla(t, b, out_dtype=jnp.float32)),
        np.asarray(kref.nmg_spmm_ref(t, b)), rtol=tol, atol=tol,
    )


def test_nmg_linear_dtype_and_value_both_regimes():
    """nmg_linear keeps x.dtype on both the decode (gemv) and prefill
    (spmm) routes and matches the densified product."""
    w = jax.random.normal(KEY, (96, 64))
    wt = nmg.dense_to_grouped_nm(w, n=2, m=4, g=2, gr=4, sparse_dim=0)
    for rows, dtype in [(4, jnp.bfloat16), (4, jnp.float32),
                        (64, jnp.bfloat16), (64, jnp.float32)]:
        x = jax.random.normal(jax.random.PRNGKey(2), (rows, 96)).astype(dtype)
        y = kops.nmg_linear(x, wt)
        assert y.dtype == dtype, (rows, dtype, y.dtype)
        tol = 1e-3 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(
            np.asarray(y.astype(jnp.float32)),
            np.asarray(x.astype(jnp.float32) @ wt.to_dense()),
            rtol=tol, atol=tol,
        )


def test_nmg_matmul_shape_routing():
    """The router sends decode-shaped right operands to the GEMV path and
    wide ones to the SpMM path (trace-time counters as evidence)."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    kops.reset_kernel_counters()
    kops.nmg_matmul(t, jnp.ones((96, kops.DECODE_M_MAX)), use_pallas=False)
    kops.nmg_matmul(t, jnp.ones((96, kops.DECODE_M_MAX + 1)),
                    use_pallas=False)
    counts = kops.kernel_counters()
    assert counts.get(("nmg_gemv", "xla")) == 1
    assert counts.get(("nmg_spmm", "xla")) == 1


# ---------------------------------------------------------------------------
# SpmmPlan caching properties
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS,
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
def test_spmm_plan_planned_equals_plan_free(fmt):
    """A conversion-time plan is pure caching: stripping it changes no
    result bit (the kernels re-derive identical indices from blk_idx)."""
    n, m, g, gr = fmt
    x = jax.random.normal(KEY, (8, 96))
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 4))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    assert t.plan is not None
    bare = dataclasses.replace(t, plan=None)
    assert bare.plan is None
    # derived plan == stored plan
    np.testing.assert_array_equal(np.asarray(bare.gather_plan().cols),
                                  np.asarray(t.plan.cols))
    # identical results on every path, bitwise
    np.testing.assert_array_equal(np.asarray(kops.nmg_gemv_xla(t, b)),
                                  np.asarray(kops.nmg_gemv_xla(bare, b)))
    np.testing.assert_array_equal(np.asarray(kops.nmg_spmm_xla(t, b)),
                                  np.asarray(kops.nmg_spmm_xla(bare, b)))
    np.testing.assert_array_equal(np.asarray(t.to_dense()),
                                  np.asarray(bare.to_dense()))


def test_spmm_plan_survives_pytree_roundtrip():
    """The plan rides along through flatten/unflatten (jit/scan boundary)
    and the layout roundtrip is unaffected by its presence."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=2)
    leaves, treedef = jax.tree_util.tree_flatten(t)
    t2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert t2.plan is not None
    np.testing.assert_array_equal(np.asarray(t2.plan.cols),
                                  np.asarray(t.plan.cols))
    np.testing.assert_array_equal(np.asarray(t2.plan.pat_onehot),
                                  np.asarray(t.plan.pat_onehot))
    np.testing.assert_array_equal(np.asarray(t2.to_dense()),
                                  np.asarray(t.to_dense()))


@pytest.mark.pallas_interpret
def test_nmg_spmm_zero_and_ones_b():
    """B = 0 gives exactly 0; B = ones gives per-row sums of kept values
    (catches accumulator-init and index-offset bugs independently of the
    oracle)."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    z = nmg_spmm_pallas(t, jnp.zeros((96, 16)), interpret=True)
    np.testing.assert_array_equal(np.asarray(z), np.zeros((8, 16)))
    o = nmg_spmm_pallas(t, jnp.ones((96, 16)), interpret=True)
    want = np.asarray(t.to_dense()).sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(o), np.broadcast_to(want, (8, 16)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# tail shapes: nothing aligned to anything
# ---------------------------------------------------------------------------

# (R, K, N) where R is not a gr multiple, K is not a chunk-extent multiple,
# and N is not a lane/tile multiple — the aligned grid above never exercises
# the padding/crop paths where Pallas index bugs hide
TAIL_SHAPES = [
    (7, 100, 129),
    (13, 52, 31),
    (33, 200, 257),
    (1, 96, 1),
]


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("fmt", [(1, 4, 4, 2), (2, 4, 2, 4), (2, 4, 16, 8)],
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.parametrize("shape", TAIL_SHAPES,
                         ids=lambda s: "x".join(map(str, s)))
def test_nmg_spmm_tail_shapes_both_schedules(fmt, shape):
    """Unaligned R/K/N through both Pallas schedules: each matches the
    oracle, and streamed == grid **bitwise** (identical chunk accumulation
    order is the schedule contract)."""
    n, m, g, gr = fmt
    R, K, N = shape
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    ref = np.asarray(kref.nmg_spmm_ref(t, b))
    grid = nmg_spmm_pallas(t, b, interpret=True, stream=False)
    strm = nmg_spmm_pallas(t, b, interpret=True, stream=True)
    assert grid.shape == strm.shape == (R, N)
    np.testing.assert_array_equal(np.asarray(strm), np.asarray(grid))
    np.testing.assert_allclose(np.asarray(strm), ref, rtol=1e-4, atol=1e-4)


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("shape", TAIL_SHAPES,
                         ids=lambda s: "x".join(map(str, s)))
def test_nmg_gemv_tail_shapes(shape):
    """Unaligned R/K through the decode kernel (narrow B): padding rows
    must be cropped, not leak into the product."""
    R, K, _ = shape
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, 3))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    out = nmg_gemv_pallas(t, b, interpret=True)
    assert out.shape == (R, 3)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kref.nmg_spmm_ref(t, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("delta", [-1, 0, 1])
def test_nmg_linear_straddles_decode_m_max(delta):
    """M = decode_m_max - 1 / exactly / + 1: the route flips at the
    boundary but values and dtype never change."""
    w = jax.random.normal(KEY, (96, 64))
    wt = nmg.dense_to_grouped_nm(w, n=2, m=4, g=2, gr=4, sparse_dim=0)
    rows = kops.DECODE_M_MAX + delta
    x = jax.random.normal(jax.random.PRNGKey(2), (rows, 96))
    kops.reset_kernel_counters()
    y = kops.nmg_linear(x, wt)
    counts = kops.kernel_counters()
    path = "spmm" if delta > 0 else "gemv"
    assert counts.get(("nmg_linear", f"{path}[default]")) == 1, counts
    assert y.dtype == x.dtype and y.shape == (rows, 64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ wt.to_dense()),
                               rtol=1e-3, atol=1e-3)

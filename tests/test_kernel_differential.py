"""Golden + differential tests for the n:m:g Pallas SpMM kernel.

``kernels/nmg_spmm.py`` (interpret mode on CPU) is swept against the
densify-then-matmul oracle in ``kernels/ref.py`` across a grid of
(n, m, g, gr) formats and shapes with explicit tolerances, plus a golden
exact-arithmetic case and a regression assertion on the output dtype
(the kernel contract is an f32 accumulator regardless of input dtype).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.core.layouts import nm_patterns
from repro.kernels import ref as kref
from repro.kernels.nmg_spmm import nmg_spmm_pallas

KEY = jax.random.PRNGKey(42)

# (n, m, g, gr) format grid: paper CPU format (gr=1), TPU row-shared
# formats, single-pattern n=m corner, and wide-m patterns
FORMATS = [
    (1, 4, 1, 1),
    (1, 4, 4, 2),
    (2, 4, 2, 1),
    (2, 4, 2, 4),
    (2, 4, 16, 8),
    (3, 6, 1, 2),
    (1, 2, 8, 8),
    (2, 6, 2, 1),
]

# (R, K, N) including non-multiples of the chunk extent (padding paths)
SHAPES = [(8, 96, 32), (16, 192, 64), (5, 100, 33)]

TOL = {jnp.dtype(jnp.float32): 1e-4, jnp.dtype(jnp.bfloat16): 5e-2}


@pytest.mark.parametrize("fmt", FORMATS,
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.parametrize("shape", SHAPES, ids=lambda s: "x".join(map(str, s)))
def test_nmg_spmm_grid_vs_ref(fmt, shape):
    n, m, g, gr = fmt
    R, K, N = shape
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    ref = kref.nmg_spmm_ref(t, b)
    out = nmg_spmm_pallas(t, b, interpret=True)
    assert out.shape == (R, N)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nmg_spmm_output_dtype_regression(dtype):
    """Contract: the kernel accumulates and returns f32 for every input
    dtype (bf16 inputs must NOT demote the output)."""
    x = jax.random.normal(KEY, (8, 96)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 32)).astype(dtype)
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=4)
    out = nmg_spmm_pallas(t, b, interpret=True)
    assert out.dtype == jnp.float32, (
        f"kernel output demoted to {out.dtype} for {dtype} inputs"
    )
    tol = TOL[jnp.dtype(dtype)]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kref.nmg_spmm_ref(t, b)),
                               rtol=tol, atol=tol)


def test_nmg_spmm_golden_exact():
    """Golden case in exact f32 arithmetic: a matrix that is already
    2:4-sparse with small-integer values, multiplied by an identity-padded
    B, must reproduce the canonical dense view bit-exactly."""
    n, m, g = 2, 4, 2
    C = math.comb(m, n)
    R, K = 4, m * C * g  # one chunk per row fiber
    x = np.zeros((R, K), np.float32)
    rng = np.random.default_rng(0)
    pats = nm_patterns(n, m)
    for r in range(R):
        # each pattern used exactly g times per chunk — the format's
        # capacity constraint — in a shuffled block order, so the layout
        # is lossless by construction
        order = rng.permutation(np.repeat(np.arange(C), g))
        for blk, pat in enumerate(order):
            x[r, blk * m + pats[pat]] = rng.integers(1, 8, size=n)
    t = nmg.dense_to_grouped_nm(jnp.asarray(x), n=n, m=m, g=g)
    # lossless by construction
    np.testing.assert_array_equal(np.asarray(t.to_dense()), x)
    out = nmg_spmm_pallas(t, jnp.eye(K, dtype=jnp.float32), interpret=True)
    np.testing.assert_array_equal(np.asarray(out), x)


def test_nmg_spmm_zero_and_ones_b():
    """B = 0 gives exactly 0; B = ones gives per-row sums of kept values
    (catches accumulator-init and index-offset bugs independently of the
    oracle)."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    z = nmg_spmm_pallas(t, jnp.zeros((96, 16)), interpret=True)
    np.testing.assert_array_equal(np.asarray(z), np.zeros((8, 16)))
    o = nmg_spmm_pallas(t, jnp.ones((96, 16)), interpret=True)
    want = np.asarray(t.to_dense()).sum(axis=1, keepdims=True)
    np.testing.assert_allclose(np.asarray(o), np.broadcast_to(want, (8, 16)),
                               rtol=1e-5, atol=1e-5)

"""Property tests for GMP schedules (optim/gmp.py).

Invariants every schedule must satisfy, regardless of parameters:
monotone sparsity on the ramp, exact target by end_step, a pattern
recompute at (or before) the moment the ramp tops out — including
non-divisible cadence spans (regression for the end_step bug) — full layer
coverage by end_step, and host/traced spelling agreement.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import GMPSchedule, gmp_sparsity

from tests._hypothesis_compat import given, settings, st

schedules = st.builds(
    GMPSchedule,
    mode=st.sampled_from(["one_shot", "iterative", "layer_wise"]),
    target_sparsity=st.floats(0.05, 0.95),
    begin_step=st.integers(0, 50),
    end_step=st.integers(51, 400),
    recompute_every=st.integers(1, 60),
    num_layers=st.integers(1, 24),
)


@settings(deadline=None, max_examples=50)
@given(schedules)
def test_sparsity_monotone_on_ramp(s):
    vals = [gmp_sparsity(s, t) for t in range(0, s.end_step + 20)]
    assert all(b >= a - 1e-12 for a, b in zip(vals, vals[1:]))


@settings(deadline=None, max_examples=50)
@given(schedules)
def test_reaches_exact_target_by_end_step(s):
    assert gmp_sparsity(s, s.end_step) == pytest.approx(s.target_sparsity)
    assert gmp_sparsity(s, s.end_step + 123) == pytest.approx(
        s.target_sparsity)
    if s.mode == "one_shot":
        assert gmp_sparsity(s, s.begin_step) == s.target_sparsity


@settings(deadline=None, max_examples=50)
@given(schedules)
def test_final_recompute_fires(s):
    """A recompute happens at the step the ramp reaches target, so training
    can never freeze short of target_sparsity (the end_step bugfix)."""
    if s.mode == "one_shot":
        assert s.recompute_at(s.begin_step)
    else:
        assert s.recompute_at(s.end_step)


@settings(deadline=None, max_examples=50)
@given(schedules)
def test_layers_all_pruned_by_end_step(s):
    assert s.layers_pruned_at(s.end_step) == s.num_layers
    assert s.layers_pruned_at(s.end_step + 7) == s.num_layers


@settings(deadline=None, max_examples=30)
@given(schedules)
def test_traced_spellings_agree_with_host(s):
    steps = np.arange(0, s.end_step + 10, dtype=np.int32)
    host_rec = np.array([s.recompute_at(int(t)) for t in steps])
    traced_rec = np.asarray(s.recompute_at_traced(jnp.asarray(steps)))
    np.testing.assert_array_equal(host_rec, traced_rec)

    host_sp = np.array([gmp_sparsity(s, int(t)) for t in steps],
                       dtype=np.float32)
    traced_sp = np.asarray(s.sparsity_at_traced(jnp.asarray(steps)))
    np.testing.assert_allclose(host_sp, traced_sp, rtol=1e-5, atol=1e-6)


def test_recompute_non_divisible_span_regression():
    """--steps 90 shape from the issue: begin=9, end=72, every=4 — the last
    cadence hit is step 69; without the fix the ramp never reaches target."""
    s = GMPSchedule(mode="iterative", target_sparsity=0.9, begin_step=9,
                    end_step=72, recompute_every=4)
    assert (72 - 9) % 4 != 0
    fired = [t for t in range(0, 120) if s.recompute_at(t)]
    assert fired[-1] == 72  # final recompute exactly at end_step
    assert 69 in fired      # cadence hits unchanged
    assert gmp_sparsity(s, fired[-1]) == pytest.approx(0.9)
    # nothing fires past the ramp
    assert not any(s.recompute_at(t) for t in range(73, 200))

"""Chunked attention vs naive oracle: causal, sliding-window (incl. the
block-skipping fast path), prefix-LM, softcap, GQA grouping, tile sizes."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import chunked_attention, decode_attention

B, S, H, KV, hd = 2, 64, 4, 2, 16
KEY = jax.random.PRNGKey(0)
Q = jax.random.normal(KEY, (B, S, H, hd))
K = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, hd))
V = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, hd))


def naive(q, k, v, *, causal=True, window=None, prefix_len=0, softcap=None):
    G = H // KV
    qf = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bckh->bqkgc", qf,
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = jnp.ones((S, S), bool)
    if causal:
        m &= j <= i
    if window is not None:
        m &= j > i - window
    if prefix_len:
        m |= jnp.arange(S)[None, :] < prefix_len
    s = jnp.where(m[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, KV * G, hd)  # kv-major head order


@pytest.mark.parametrize("cq,ck", [(8, 8), (16, 8), (64, 64), (8, 32)])
def test_causal_matches_naive(cq, ck):
    got = chunked_attention(Q, K, V, causal=True, chunk_q=cq, chunk_k=ck)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(naive(Q, K, V)), rtol=2e-3,
                               atol=2e-3)


@pytest.mark.parametrize("window,cq,ck", [(16, 8, 8), (24, 8, 8),
                                          (16, 16, 8), (40, 8, 16)])
def test_window_block_skip_matches_naive(window, cq, ck):
    got = chunked_attention(Q, K, V, causal=True, window=window,
                            chunk_q=cq, chunk_k=ck)
    want = naive(Q, K, V, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_prefix_lm():
    got = chunked_attention(Q, K, V, causal=True, prefix_len=10,
                            chunk_q=8, chunk_k=8)
    want = naive(Q, K, V, prefix_len=10)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_softcap():
    got = chunked_attention(Q, K, V, causal=True, softcap=5.0,
                            chunk_q=16, chunk_k=16)
    want = naive(Q, K, V, softcap=5.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_non_causal():
    got = chunked_attention(Q, K, V, causal=False, chunk_q=16, chunk_k=16)
    want = naive(Q, K, V, causal=False)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)


def test_ragged_seq_padding():
    q = Q[:, :50]
    got = chunked_attention(q, K[:, :50], V[:, :50], causal=True,
                            chunk_q=16, chunk_k=16)
    assert got.shape == (B, 50, H, hd)
    assert np.isfinite(np.asarray(got, np.float32)).all()


def test_bf16_compute_dtype_close():
    got = chunked_attention(Q, K, V, causal=True, chunk_q=16, chunk_k=16,
                            compute_dtype=jnp.bfloat16)
    want = naive(Q, K, V)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=5e-2, atol=5e-2)


def test_decode_matches_last_row_of_naive():
    # cache holds S entries; decode of the last position must equal the
    # last row of full attention
    q_last = Q[:, -1:][:, :, :, :]
    got = decode_attention(q_last, K, V, jnp.asarray(S))
    want = naive(Q, K, V)[:, -1:]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-3, atol=2e-3)

"""Device-resident training fast path (launch/train.py).

The jitted multi-step trainer must be equivalent, step for step, to the
host-driven reference loop — including GMP pattern recomputes, which the
fast path runs *inside* jit via the traced ``recompute_pattern`` path of
``sparse_aware_update`` while the reference retargets on the host.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.core.dispatch import SparseFallbackWarning, sparse_op_table
from repro.core.layouts import (
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
)
from repro.core.sparsifiers import (
    GroupedNMSparsifier,
    ScalarThresholdSparsifier,
)
from repro.data import DataConfig, SyntheticLMPipeline
from repro.launch.train import (
    build_sparse_params,
    make_multi_step,
    make_train_step,
    retarget_sparsity,
    stack_batches,
)
from repro.models import init_lm, loss_fn
from repro.models.common import mm
from repro.optim import AdamWConfig, GMPSchedule, adamw_init

KEY = jax.random.PRNGKey(0)
STEPS = 18
# non-divisible ramp span: (end - begin) % every == (14 - 2) % 5 == 2, so
# the final recompute relies on the end_step bugfix in GMPSchedule
GMP = GMPSchedule(mode="iterative", target_sparsity=0.6, begin_step=2,
                  end_step=14, recompute_every=5, num_layers=2)


def _setup(cfg):
    params = build_sparse_params(init_lm(KEY, cfg), GMP.sparsity_at(0))
    data = SyntheticLMPipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                          global_batch=2, seed=3))
    return params, adamw_init(params), data


def _mask_leaves(params):
    return [np.asarray(l.mask) for l in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, FixedMaskTensor))
        if isinstance(l, FixedMaskTensor)]


def _run_both(cfg, gmp, steps, chunks):
    """Run host reference and fast path over the same schedule; return
    (ref_losses, ref_masks, fast_losses, fast_masks)."""
    opt_cfg = AdamWConfig(lr=1e-3)

    # -- host-driven reference ------------------------------------------
    params, state, data = _setup(cfg)
    step_fn = make_train_step(cfg, opt_cfg)
    ref_losses = []
    for s in range(steps):
        if gmp.recompute_at(s):
            params = retarget_sparsity(params, gmp.sparsity_at(s))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        params, state, m = step_fn(params, state, batch)
        ref_losses.append(float(m["loss"]))
    ref_masks = _mask_leaves(params)

    # -- device-resident fast path (in-jit recomputes) -------------------
    params, state, data = _setup(cfg)
    if gmp.recompute_at(0):
        params = retarget_sparsity(params, gmp.sparsity_at(0))
    fast_losses = []
    step = 0
    for n in chunks:
        multi = make_multi_step(cfg, opt_cfg, gmp, n)
        params, state, metrics = multi(params, state,
                                       stack_batches(data, step, step + n),
                                       jnp.int32(step), jnp.int32(steps))
        fast_losses.extend(np.asarray(metrics["loss"]).tolist())
        step += n
    assert step == steps
    return ref_losses, ref_masks, fast_losses, _mask_leaves(params)


def test_multi_step_matches_host_loop():
    """Loss trajectory + final masks of the fast path == host reference
    (chunk sizes deliberately unaligned with the GMP cadence)."""
    cfg = get_smoke("bert-base-sten")
    ref_losses, ref_masks, fast_losses, fast_masks = _run_both(
        cfg, GMP, STEPS, chunks=(7, 7, 4))
    np.testing.assert_allclose(fast_losses, ref_losses, rtol=1e-4, atol=1e-5)
    for got, ref in zip(fast_masks, ref_masks):
        assert np.array_equal(got, ref)


def test_no_spurious_recompute_past_stop():
    """A run ending exactly on a cadence step must not retarget for the
    never-executed next step: final masks still equal the host reference
    (which stops before the step-``stop`` retarget)."""
    cfg = get_smoke("bert-base-sten")
    gmp = GMPSchedule(mode="iterative", target_sparsity=0.6, begin_step=2,
                      end_step=20, recompute_every=5, num_layers=2)
    steps = 12  # recompute_at(12) fires mid-ramp; the run stops there
    assert gmp.recompute_at(steps)
    ref_losses, ref_masks, fast_losses, fast_masks = _run_both(
        cfg, gmp, steps, chunks=(12,))
    np.testing.assert_allclose(fast_losses, ref_losses, rtol=1e-4, atol=1e-5)
    for got, ref in zip(fast_masks, ref_masks):
        assert np.array_equal(got, ref)


def test_in_jit_recompute_reaches_target_sparsity():
    """The traced end-of-ramp recompute hits target_sparsity even on a
    non-divisible span (the recompute_at end_step bugfix, in-jit)."""
    cfg = get_smoke("bert-base-sten")
    params, state, data = _setup(cfg)
    multi = make_multi_step(cfg, AdamWConfig(lr=1e-3), GMP, STEPS)
    params, state, _ = multi(params, state, stack_batches(data, 0, STEPS),
                             jnp.int32(0), jnp.int32(STEPS))
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, FixedMaskTensor)):
        if isinstance(leaf, FixedMaskTensor):
            density = float(np.asarray(leaf.mask).mean())
            # top-k keeps exactly round(N * (1 - target)) entries (+ ties)
            assert density == pytest.approx(1.0 - GMP.target_sparsity,
                                            abs=2e-3)


def test_nmg_training_forward_no_densify_no_fallback():
    """Fixed-pattern sparse training step with GroupedNM weights dispatches
    to the registered nmg kernels: the dispatch table covers the signature
    and the forward raises no SparseFallbackWarning (= no weight densify)."""
    table = sparse_op_table()
    assert ("linear", (DenseTensor, GroupedNMTensor), None) in table

    cfg = get_smoke("bert-base-sten")
    params = init_lm(KEY, cfg)

    def to_nmg(leaf):
        # per-layer n:m:g conversion of the scan-stacked MLP up-projection
        parts = [GroupedNMTensor.from_dense(leaf[i], 2, 4, 2, sparse_dim=0)
                 for i in range(leaf.shape[0])]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *parts)

    params["layers"]["mlp"]["wi"] = to_nmg(params["layers"]["mlp"]["wi"])
    batch = {
        "tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
    }
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)
        loss, _ = loss_fn(params, cfg, batch, remat="none")
    assert np.isfinite(float(loss))


def test_mm_fused_inline_threshold():
    """mm's fused-inline option reaches the matmul_threshold kernel (no
    fallback) and equals matmul + threshold."""
    x = jax.random.normal(KEY, (3, 8, 32))
    w = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    with warnings.catch_warnings():
        warnings.simplefilter("error", SparseFallbackWarning)
        y = mm(x, w, inline=ScalarThresholdSparsifier(0.5))
    ref = np.asarray(x.reshape(-1, 32) @ w)
    ref = (ref * (np.abs(ref) >= 0.5)).reshape(3, 8, 16)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-5)


def test_mlp_inline_threshold_config_forward():
    """The ModelConfig knob routes the MLP up-projection through the fused
    inline sparsifier without breaking the forward."""
    import dataclasses

    cfg = get_smoke("bert-base-sten")
    cfg = dataclasses.replace(cfg, mlp_inline_threshold=0.05)
    params = init_lm(KEY, cfg)
    batch = {
        "tokens": jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (2, 16), 0, cfg.vocab),
    }
    loss, _ = loss_fn(params, cfg, batch, remat="none")
    assert np.isfinite(float(loss))


def test_decode_with_sparse_output_projection():
    """Sparse attn.wo now works on the decode path too (mm-dispatched)."""
    from repro.core.sparsifiers import ScalarFractionSparsifier
    from repro.models import decode_step, prefill

    cfg = get_smoke("bert-base-sten")
    params = init_lm(KEY, cfg)

    def to_fixed(leaf):
        sp = ScalarFractionSparsifier(0.5)
        parts = []
        for i in range(leaf.shape[0]):
            mask = sp.mask(leaf[i]).astype(jnp.bool_)
            parts.append(FixedMaskTensor(leaf[i] * mask, mask, sp))
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *parts)

    params["layers"]["attn"]["wo"] = to_fixed(params["layers"]["attn"]["wo"])
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab)
    logits, cache = prefill(params, cfg, tokens, cache_len=16)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    logits2, _ = decode_step(params, cfg, tok, cache, jnp.int32(8))
    assert np.isfinite(np.asarray(logits2)).all()

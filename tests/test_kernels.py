"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.kernels import ops as kops
from repro.kernels import ref as kref

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("n,m,g,gr", [
    (2, 4, 1, 1), (2, 4, 2, 4), (1, 4, 4, 2), (3, 6, 1, 2), (1, 2, 8, 8),
])
@pytest.mark.pallas_interpret
@pytest.mark.parametrize("shape", [(16, 96, 64), (8, 192, 128)])
def test_nmg_spmm_pallas_allclose(n, m, g, gr, shape):
    R, K, N = shape
    x = jax.random.normal(KEY, (R, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    ref = kref.nmg_spmm_ref(t, b)
    out = kops.nmg_spmm(t, b, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_nmg_spmm_dtypes(dtype):
    x = jax.random.normal(KEY, (8, 96)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (96, 64)).astype(dtype)
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=4)
    ref = kref.nmg_spmm_ref(t, b)
    out = kops.nmg_spmm(t, b, use_pallas=True)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.pallas_interpret
def test_nmg_spmm_xla_matches_pallas():
    x = jax.random.normal(KEY, (16, 192))
    b = jax.random.normal(jax.random.PRNGKey(1), (192, 64))
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, gr=4)
    np.testing.assert_allclose(
        np.asarray(kops.nmg_spmm_xla(t, b)),
        np.asarray(kops.nmg_spmm(t, b, use_pallas=True)),
        rtol=1e-4, atol=1e-4,
    )


def test_nmg_linear_orientation():
    """Serving path: weight [K, N] sparse along input axis."""
    w = jax.random.normal(KEY, (96, 64))
    wt = nmg.dense_to_grouped_nm(w, n=2, m=4, g=2, gr=4, sparse_dim=0)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 96))
    np.testing.assert_allclose(
        np.asarray(kops.nmg_linear(x, wt)),
        np.asarray(x @ wt.to_dense()),
        rtol=1e-3, atol=1e-4,
    )


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("n,m", [(1, 4), (2, 4), (2, 8), (3, 6), (1, 10)])
@pytest.mark.parametrize("shape", [(32, 64), (7, 130), (256, 520)])
def test_nm_mask_kernel_allclose(n, m, shape):
    x = jax.random.normal(KEY, shape)
    got = kops.nm_mask(x, n, m, use_pallas=True)
    want = kref.nm_mask_ref(x, n, m)
    assert bool(jnp.all(got == want))


@pytest.mark.pallas_interpret
def test_nm_mask_tie_breaking():
    """Exact tie-break agreement with top_k (lowest index wins)."""
    x = jnp.ones((4, 16))
    got = kops.nm_mask(x, 2, 4, use_pallas=True)
    want = kref.nm_mask_ref(x, 2, 4)
    assert bool(jnp.all(got == want))


@pytest.mark.pallas_interpret
@pytest.mark.parametrize("shape", [(32, 48, 40), (64, 64, 64), (33, 70, 9)])
@pytest.mark.parametrize("threshold", [0.5, 2.0])
def test_fused_matmul_threshold_allclose(shape, threshold):
    M, K, N = shape
    a = jax.random.normal(KEY, (M, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    v_p, m_p = kops.matmul_threshold(a, b, threshold, use_pallas=True)
    v_r, m_r = kref.matmul_threshold_ref(a, b, threshold)
    np.testing.assert_allclose(np.asarray(v_p), np.asarray(v_r),
                               rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(m_p == m_r))


def test_kernel_grad_through_xla_path():
    """The serving op is differentiable w.r.t. the stored values (STen's
    transparent backprop for custom formats)."""
    x = jax.random.normal(KEY, (4, 96))
    w = jax.random.normal(jax.random.PRNGKey(1), (96, 32))
    wt = nmg.dense_to_grouped_nm(w, n=2, m=4, g=2, sparse_dim=0)

    def loss(t):
        return jnp.sum(kops.nmg_spmm_xla(t, jnp.ones((96, 32))) ** 2)

    g = jax.grad(loss, allow_int=True)(wt)
    assert g.val.shape == wt.val.shape
    assert np.isfinite(np.asarray(g.val)).all()

"""Sparsifier taxonomy semantics (paper Table 1) + builder integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.layouts import CsrTensor, DenseTensor, FixedMaskTensor
from repro.core.sparsifiers import (
    BLOCKING,
    MATERIALIZING,
    STREAMING,
    BlockwiseFractionSparsifier,
    GroupedNMSparsifier,
    KeepAll,
    NMSparsifier,
    RandomFractionSparsifier,
    SameFormatSparsifier,
    ScalarFractionSparsifier,
    ScalarThresholdSparsifier,
    apply_sparsifier,
)

KEY = jax.random.PRNGKey(0)


def test_taxonomy_matches_table1():
    assert KeepAll().kind == STREAMING and KeepAll().passes == 1
    assert RandomFractionSparsifier().kind == STREAMING
    assert ScalarThresholdSparsifier().kind == STREAMING
    assert NMSparsifier().kind == BLOCKING and NMSparsifier().passes == 2
    assert GroupedNMSparsifier().kind == BLOCKING
    assert ScalarFractionSparsifier().kind == MATERIALIZING
    assert BlockwiseFractionSparsifier().kind == MATERIALIZING


def test_keep_all_identity():
    x = jax.random.normal(KEY, (8, 8))
    out = apply_sparsifier(KeepAll(), x, DenseTensor)
    np.testing.assert_allclose(out.to_dense(), x)


@given(frac=st.floats(0.1, 0.9), seed=st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_scalar_fraction_prunes_exact_fraction(frac, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 32))
    m = ScalarFractionSparsifier(frac).mask(x)
    kept = float(jnp.mean(m.astype(jnp.float32)))
    assert abs(kept - (1 - frac)) < 2.0 / x.size + 1e-3


def test_scalar_fraction_keeps_largest():
    x = jnp.asarray([[1.0, -5.0, 0.1, 3.0]])
    m = np.asarray(ScalarFractionSparsifier(0.5).mask(x))
    assert m[0, 1] and m[0, 3] and not m[0, 0] and not m[0, 2]


def test_threshold_streaming_semantics():
    x = jnp.asarray([0.5, -2.0, 1.5, -0.1])
    m = np.asarray(ScalarThresholdSparsifier(1.0).mask(x))
    np.testing.assert_array_equal(m, [False, True, True, False])


def test_random_fraction_rate():
    x = jnp.ones((64, 64))
    m = RandomFractionSparsifier(0.3).mask(x, jax.random.PRNGKey(5))
    assert abs(float(jnp.mean(m.astype(jnp.float32))) - 0.7) < 0.05


def test_blockwise_drops_whole_blocks():
    x = jax.random.normal(KEY, (4, 32))
    m = np.asarray(BlockwiseFractionSparsifier(0.5, block=4).mask(x))
    blocks = m.reshape(4, 8, 4)
    per_block = blocks.sum(-1)
    assert set(np.unique(per_block)) <= {0, 4}  # all-or-nothing


def test_same_format_fixed_mask():
    x = jax.random.normal(KEY, (8, 8))
    t = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    x2 = x * 2.0
    t2 = SameFormatSparsifier(fixed_pattern=True).resparsify(t, x2)
    assert np.array_equal(np.asarray(t2.mask), np.asarray(t.mask))
    np.testing.assert_allclose(
        np.asarray(t2.to_dense()),
        np.asarray(x2 * t.mask.astype(x2.dtype)), rtol=1e-6)


def test_same_format_csr_capacity_preserved():
    x = jax.random.normal(KEY, (8, 8))
    t = apply_sparsifier(ScalarFractionSparsifier(0.5), x, CsrTensor)
    t2 = SameFormatSparsifier().resparsify(t, x)
    assert t2.nnz_cap == t.nnz_cap
    np.testing.assert_allclose(np.asarray(t2.to_dense()),
                               np.asarray(t.to_dense()), rtol=1e-6)


def test_sparsifier_to_fixed_mask_and_csr_agree():
    x = jax.random.normal(KEY, (16, 16))
    sp = ScalarFractionSparsifier(0.7)
    a = apply_sparsifier(sp, x, FixedMaskTensor).to_dense()
    b = apply_sparsifier(sp, x, CsrTensor).to_dense()
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

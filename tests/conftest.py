# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and benches
# must see the real single CPU device.  Distributed tests spawn subprocesses
# that set --xla_force_host_platform_device_count themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

import importlib

import pytest

_disp = importlib.import_module("repro.core.dispatch")
_kops = importlib.import_module("repro.kernels.ops")
_routing = importlib.import_module("repro.tune.routing")
_conv = importlib.import_module("repro.core.convert")
_obs_trace = importlib.import_module("repro.obs.trace")
_obs_registry = importlib.import_module("repro.obs.registry")


@pytest.fixture(autouse=True)
def _reset_routing_state():
    """Counter/table hygiene: every test starts with empty dispatch and
    kernel counters, an empty conversion log, no active tuning table, an
    empty telemetry registry, and the flight recorder off and empty, so a
    test asserting exact counts (or default routing) can never be
    poisoned by whatever traced before it — see
    tests/test_counter_hygiene.py for the regressions pinning this.
    ``REGISTRY.reset()`` clears metric objects *in place*, so
    module-held references (dispatch/kernel counter families, engine
    stats mirrors) stay live across the reset."""
    _disp.reset_dispatch_counters()
    _kops.reset_kernel_counters()
    _routing.clear_active_table()
    _conv.reset_conversion_log()
    _obs_registry.REGISTRY.reset()
    _obs_trace.reset()
    yield

# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and benches
# must see the real single CPU device.  Distributed tests spawn subprocesses
# that set --xla_force_host_platform_device_count themselves.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)

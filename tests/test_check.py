"""repro.check behavior: clean entries pass, every seeded rule fixture
fails, route prediction matches the runtime, and the CLI round-trips."""

import importlib
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.check import Report, Severity, run_check
from repro.check.__main__ import main as check_main
from repro.check.fixtures import FIXTURES
from repro.check.rules import all_rules, run_rules
from repro.core import nmg
from repro.core.layouts import CsrTensor, DenseTensor, GroupedNMTensor

kops = importlib.import_module("repro.kernels.ops")
disp = importlib.import_module("repro.core.dispatch")


# ---------------------------------------------------------------------------
# rule fixtures: trigger fails, clean passes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule_id", sorted(FIXTURES))
def test_trigger_fixture_fails_strict(rule_id):
    prog = FIXTURES[rule_id]["trigger"]()
    report = Report(run_rules(prog, rules=[rule_id]))
    hits = [d for d in report.diagnostics if d.rule == rule_id]
    assert hits, f"{rule_id} trigger fixture produced no {rule_id} diagnostic"
    assert report.exit_code(strict=True) != 0
    # severity matches the registry, and the diagnostic is fully typed
    rule = all_rules()[rule_id]
    for d in hits:
        assert d.severity == rule.severity
        assert d.entry and d.message
        assert d.rule == rule_id


@pytest.mark.parametrize("rule_id", sorted(FIXTURES))
def test_clean_fixture_passes(rule_id):
    prog = FIXTURES[rule_id]["clean"]()
    assert not [d for d in run_rules(prog) if d.rule == rule_id], (
        f"{rule_id} clean fixture still trips {rule_id}"
    )


def test_error_rules_fail_even_without_strict():
    prog = FIXTURES["R1"]["trigger"]()
    report = Report(run_rules(prog, rules=["R1"]))
    assert report.exit_code(strict=False) != 0


def test_warning_rules_fail_only_under_strict():
    prog = FIXTURES["R2"]["trigger"]()
    report = Report(run_rules(prog, rules=["R2"]))
    assert report.exit_code(strict=False) == 0
    assert report.exit_code(strict=True) != 0


def test_ignore_suppresses_rule():
    prog = FIXTURES["R2"]["trigger"]()
    report = Report(run_rules(prog, rules=["R2"]))
    assert report.filtered(["R2"]).exit_code(strict=True) == 0
    # entry-scoped suppression only hits matching entries
    assert report.filtered(["R2:nomatch-*"]).exit_code(strict=True) != 0
    assert report.filtered(["R2:fixture/*"]).exit_code(strict=True) == 0


# ---------------------------------------------------------------------------
# real entries: the clean repo passes
# ---------------------------------------------------------------------------


def test_serve_entry_clean():
    report = run_check(("serve",), arch="bert-base-sten", hlo=False)
    assert report.render() == ""
    assert report.exit_code(strict=True) == 0
    assert any(":decode" in p for p in report.programs)
    assert any(":prefill" in p for p in report.programs)


def test_train_entry_clean():
    report = run_check(("train",), arch="bert-base-sten", hlo=False)
    assert report.exit_code(strict=True) == 0


def test_cli_json_report(tmp_path):
    out = tmp_path / "report.json"
    rc = check_main(["--entry", "decode", "--no-hlo", "--json", str(out),
                     "--strict"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["errors"] == 0
    assert doc["programs"]
    assert isinstance(doc["diagnostics"], list)


# ---------------------------------------------------------------------------
# predict_route: dispatch level
# ---------------------------------------------------------------------------


def _gnm(R=8, K=96, fmt=(1, 4, 4), gr=2):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(R, K)), jnp.float32)
    n, m, g = fmt
    return nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)


def test_dispatch_predict_route_impl():
    got = disp.predict_route("linear", (DenseTensor, GroupedNMTensor))
    assert got["outcome"] == "impl"
    assert got["sig"] == ("DenseTensor", "GroupedNMTensor")
    assert got["conversions"] == ()


def test_dispatch_predict_route_conversion():
    from repro.core.layouts import CooTensor

    got = disp.predict_route("matmul", (CooTensor, DenseTensor))
    assert got["outcome"] == "impl"
    assert got["conversions"] == (("CooTensor", "CsrTensor"),)
    assert got["target_sig"] == ("CsrTensor", "DenseTensor")


def test_dispatch_predict_route_fallback_and_no_counter_pollution():
    before = disp.dispatch_counters()
    got = disp.predict_route("definitely_not_registered",
                             (CsrTensor, DenseTensor))
    assert got["outcome"] == "dense_fallback"
    assert got["warns"] is True
    # prediction is side-effect-free: counters unchanged
    assert disp.dispatch_counters() == before


def test_dispatch_predict_route_accepts_instances():
    t = _gnm()
    got = disp.predict_route("linear", (jnp.ones((4, 96)), t))
    assert got["sig"] == ("DenseTensor", "GroupedNMTensor")


# ---------------------------------------------------------------------------
# predict_route: kernel level, cross-checked against the real router
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("M", [2, 64])
def test_kernels_predict_route_matches_runtime(M):
    t = _gnm()
    predicted = set(map(tuple, kops.predict_route(
        "nmg_linear", t, M=M, dtype=jnp.float32, use_pallas=False)))
    kops.reset_kernel_counters()
    kops.nmg_linear(jnp.ones((M, 96), jnp.float32), t, use_pallas=False)
    observed = set(kops.kernel_counters())
    assert predicted == observed


def test_kernels_predict_route_is_table_sensitive():
    from repro.tune.routing import set_active_table
    from repro.tune.table import TuningTable, device_kind

    t = _gnm()
    # crossover forced below M=4: the same call flips gemv -> spmm
    tab = TuningTable(device=device_kind(), entries={"decode_m_max": 2})
    set_active_table(tab)
    keys = kops.predict_route("nmg_linear", t, M=4, dtype=jnp.float32,
                              use_pallas=False)
    assert ("nmg_linear", "spmm[table]") in keys


def test_kernels_predict_route_unknown_op():
    with pytest.raises(ValueError, match="unknown op"):
        kops.predict_route("nope", _gnm(), M=4, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# differential mode: static prediction vs the live engine's counters
# ---------------------------------------------------------------------------


def test_differential_static_vs_runtime_agree():
    from repro.check.differential import differential_check

    diags, detail = differential_check()
    assert detail["agree"], "\n".join(d.render() for d in diags)
    assert detail["predicted"] == detail["observed"]
    # the quick warmup straddles the gemv/spmm crossover, so both routed
    # paths are part of the comparison surface
    assert any("gemv" in k for k in detail["observed"])
    assert any("spmm" in k for k in detail["observed"])


# ---------------------------------------------------------------------------
# table-load provenance reaches the checker's world
# ---------------------------------------------------------------------------


def test_vmem_estimates_carry_table_provenance():
    from repro.check.program import build_program
    from repro.tune.routing import clear_active_table, set_active_table
    from repro.tune.table import TuningTable, device_kind

    t = _gnm()
    tab = TuningTable(device=device_kind(),
                      entries={"gemv_pallas": {"tm": 8, "target_depth": 64}})
    set_active_table(tab)
    try:
        prog = build_program("t/prov", lambda x: x, (jnp.ones((2, 96)),),
                             model_dtype=jnp.float32,
                             sparse_weights={"w": t}, decode_m=2)
    finally:
        clear_active_table()
    (est,) = prog.vmem_estimates
    assert est["source"] == "table"
    assert est["config"]["tm"] == 8
    assert est["bytes"] <= est["budget"]

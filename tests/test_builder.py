"""SparsityBuilder: weight rules, intermediate tags, tracing (paper §3.4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import SparsityBuilder, tag, trace_intermediates
from repro.core.dispatch import OutFormat
from repro.core.layouts import FixedMaskTensor, GroupedNMTensor
from repro.core.sparsifiers import (
    GroupedNMSparsifier,
    KeepAll,
    ScalarFractionSparsifier,
    ScalarThresholdSparsifier,
)

KEY = jax.random.PRNGKey(0)


def tiny_model_params():
    k1, k2 = jax.random.split(KEY)
    return {
        "net": {
            "w1": jax.random.normal(k1, (16, 32)),
            "w2": jax.random.normal(k2, (32, 8)),
            "bias": jnp.zeros((8,)),
        }
    }


def tiny_apply(params, x):
    # models route weight ops through the sparse-aware mm (DESIGN.md §2:
    # JAX has no implicit operator interception; our model zoo does this)
    from repro.models.common import mm

    h = mm(x, params["net"]["w1"])
    h = tag("net.gelu", jax.nn.gelu(h))
    return mm(h, params["net"]["w2"]) + params["net"]["bias"]


def test_set_weight_exact_and_glob():
    sb = SparsityBuilder()
    sb.set_weight("net.w1", ScalarFractionSparsifier(0.5), FixedMaskTensor)
    p = sb.sparsify_params(tiny_model_params())
    assert isinstance(p["net"]["w1"], FixedMaskTensor)
    assert not isinstance(p["net"]["w2"], FixedMaskTensor)

    sb2 = SparsityBuilder()
    sb2.set_weight("net.w*", ScalarFractionSparsifier(0.5), FixedMaskTensor)
    p2 = sb2.sparsify_params(tiny_model_params())
    assert isinstance(p2["net"]["w1"], FixedMaskTensor)
    assert isinstance(p2["net"]["w2"], FixedMaskTensor)
    assert not isinstance(p2["net"]["bias"], FixedMaskTensor)


def test_get_sparse_model_runs_and_sparsifies_interm():
    sb = SparsityBuilder()
    sb.set_weight("net.w1", ScalarFractionSparsifier(0.9), FixedMaskTensor)
    sb.set_interm("net.gelu",
                  inline_sparsifier=ScalarThresholdSparsifier(0.5))
    params = tiny_model_params()
    sp, apply = sb.get_sparse_model(params, tiny_apply)
    x = jax.random.normal(KEY, (4, 16))
    y_sparse = apply(sp, x)
    assert y_sparse.shape == (4, 8)
    # the threshold actually dropped activations: recompute manually
    h = x @ sp["net"]["w1"].to_dense()
    h = jax.nn.gelu(h)
    h = h * (jnp.abs(h) >= 0.5)
    want = h @ sp["net"]["w2"] + sp["net"]["bias"]
    np.testing.assert_allclose(np.asarray(y_sparse), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_tag_is_identity_without_plan():
    x = jax.random.normal(KEY, (4, 4))
    np.testing.assert_allclose(tag("anything", x), x)


def test_trace_intermediates():
    params = tiny_model_params()
    x = jnp.zeros((4, 16))
    sites = trace_intermediates(lambda p, x: tiny_apply(p, x), params, x)
    names = [s[0] for s in sites]
    assert "net.gelu" in names
    shape = dict((s[0], s[1]) for s in sites)["net.gelu"]
    assert shape == (4, 32)


def test_grad_formats_collected():
    sb = SparsityBuilder()
    fmt = OutFormat(KeepAll(), FixedMaskTensor,
                    ScalarFractionSparsifier(0.5), FixedMaskTensor)
    sb.set_weight("net.w1", ScalarFractionSparsifier(0.5), FixedMaskTensor,
                  grad_fmt=fmt)
    assert sb.grad_formats() == {"net.w1": fmt}


def test_stacked_weight_sparsification():
    """Scan-stacked [L, D, F] weights sparsify per layer (local pruning)."""
    w = jax.random.normal(KEY, (3, 16, 32))
    sb = SparsityBuilder()
    sb.set_weight("w", GroupedNMSparsifier(2, 4, 2, sparse_dim=0),
                  GroupedNMTensor)
    p = sb.sparsify_params({"w": w})
    t = p["w"]
    assert isinstance(t, GroupedNMTensor)
    assert t.val.shape[0] == 3  # stacked leading dim
    # slicing layer 1 out (as lax.scan does) gives a valid 2-D layout
    t1 = jax.tree_util.tree_map(lambda l: l[1], t)
    d = np.asarray(t1.to_dense())
    assert d.shape == (16, 32)
    nnz = (d.T.reshape(32, -1, 4) != 0).sum(-1)
    assert nnz.max() <= 2

"""SLO control-loop tests: tier specs, the cadence watchdog, hysteresis
over the degradation ladder, priority/deadline scheduling and shedding in
the queue, the typed serve-error family, SLO metrics, atomic JSON writes,
and the recompile-free tier-switch guarantee (trace counters stay flat
across ``set_tier`` after ``warm_tiers``)."""

import dataclasses
import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.ioutil import atomic_write_json
from repro.models import init_lm
from repro.serve import (
    CadenceWatchdog,
    DeadlineExceededError,
    EngineOverloadError,
    LatencyModel,
    PromptTooLongError,
    Request,
    RequestOutput,
    RequestQueue,
    ServeEngine,
    ServeError,
    SLOConfig,
    SLOController,
    TierSpec,
    build_tiers,
    raise_for_output,
    summarize,
    trace_events,
)
from repro.tune import routing
from repro.tune.table import TuningTable, bucket, shape_key

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    yield cfg, params
    from repro.serve import cache as _cache, engine as _engine
    for mod in (_cache, _engine):
        for fn in vars(mod).values():
            clear = getattr(fn, "cache_clear", None)
            if clear is not None:
                clear()
    jax.clear_caches()


def make_prompt(length, seed=0, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (length,), 0, vocab, jnp.int32
    ))


# ---------------------------------------------------------------------------
# tier specs
# ---------------------------------------------------------------------------


def test_tier_spec_parse():
    d = TierSpec.parse("dense")
    assert d.fmt is None and d.density == 1.0 and d.name == "dense"
    nm = TierSpec.parse("2:4")
    assert nm.fmt == (2, 4, 4) and nm.gr == 64 and nm.density == 0.5
    g = TierSpec.parse("1:4:8-gr32")
    assert g.fmt == (1, 4, 8) and g.gr == 32
    assert g.name == "1:4:8-gr32" and g.density == 0.25


@pytest.mark.parametrize("bad", ["4:2", "0:4", "1:4:2", "junk", "1:2:3:4"])
def test_tier_spec_parse_rejects(bad):
    with pytest.raises(ValueError):
        TierSpec.parse(bad)


def test_build_tiers_rejects_empty_and_duplicates(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="at least one"):
        build_tiers(params, [])
    with pytest.raises(ValueError, match="duplicate"):
        build_tiers(params, ["dense", "dense"])


# ---------------------------------------------------------------------------
# cadence watchdog
# ---------------------------------------------------------------------------


def test_cadence_watchdog_trips_on_sustained_slowdown():
    wd = CadenceWatchdog(window=4, n_windows=6, min_windows=3, ratio=2.0)
    for _ in range(3 * 4):           # three healthy windows at 1ms
        wd.observe(1e-3)
    assert not wd.slow()
    for _ in range(4):               # one collapsed window at 10ms
        wd.observe(10e-3)
    assert wd.slow()
    assert wd.recent() == pytest.approx(10e-3)


def test_cadence_watchdog_ignores_single_token_jitter():
    wd = CadenceWatchdog(window=4, n_windows=6, min_windows=3, ratio=2.0)
    for i in range(4 * 4):
        # one 50ms spike per window; the window median stays 1ms
        wd.observe(50e-3 if i % 4 == 0 else 1e-3)
    assert not wd.slow()


def test_cadence_watchdog_silent_before_min_windows():
    wd = CadenceWatchdog(window=2, n_windows=4, min_windows=3, ratio=2.0)
    wd.observe(1e-3), wd.observe(1e-3)   # 1 window
    wd.observe(99.0), wd.observe(99.0)   # 2 windows, still below min
    assert not wd.slow()


# ---------------------------------------------------------------------------
# hysteresis controller
# ---------------------------------------------------------------------------


def _controller(**over):
    kw = dict(tpot_ms=10.0, escalate_dwell=2, deescalate_dwell=3)
    kw.update(over)
    return SLOController(SLOConfig(**kw), n_tiers=2, max_slots=4)


def test_controller_escalates_after_dwell_and_maps_ladder():
    c = _controller()
    deep = c.queue_high() + 1
    assert c.begin_step(0.0, deep) == 0          # hot streak 1
    assert c.begin_step(0.0, deep) == 1          # dwell reached
    assert c.tier_index == 0                     # level 1: still tier 0
    assert c.admission_budget(3) == 1            # deferred admissions
    assert c.decode_chunk(8) == 4                # shrunk chunk
    c.begin_step(0.0, deep), c.begin_step(0.0, deep)
    assert c.level == 2 and c.tier_index == 1    # sparser tier
    c.begin_step(0.0, deep), c.begin_step(0.0, deep)
    assert c.level == 3 and c.should_shed(deep)
    assert c.tier_index == 1                     # clamped to the ladder
    assert c.counters["escalations"] == 3


def test_controller_needs_queue_to_shed():
    # hot via the watchdog (cadence collapse) but with an *empty* queue:
    # the ladder stops at level 2 — shedding nothing buys nothing
    c = _controller(watchdog_window=2, watchdog_n_windows=4,
                    watchdog_min_windows=2, watchdog_ratio=2.0)
    for _ in range(6):
        c.observe_decode(1e-3, 1)
    for _ in range(2):
        c.observe_decode(1.0, 1)                 # latest window collapsed
    for _ in range(10):
        c.begin_step(0.0, 0)
    assert c.level == 2
    assert not c.should_shed(0)


def test_controller_deescalates_slowly_and_band_holds():
    c = _controller()
    deep = c.queue_high() + 1
    for _ in range(4):
        c.begin_step(0.0, deep)
    assert c.level == 2
    c.begin_step(0.0, 0)                         # cool streak 1
    c.begin_step(0.0, 0)                         # 2
    assert c.level == 2                          # dwell=3 not yet reached
    c.begin_step(0.0, 0)
    assert c.level == 1
    assert c.counters["deescalations"] == 1
    # a hot step resets the cool streak
    c.begin_step(0.0, 0), c.begin_step(0.0, 0)
    c.begin_step(0.0, deep)
    c.begin_step(0.0, 0), c.begin_step(0.0, 0)
    assert c.level == 1


def test_controller_watchdog_trip_is_hot():
    c = _controller(watchdog_window=2, watchdog_n_windows=4,
                    watchdog_min_windows=2, watchdog_ratio=2.0)
    for _ in range(6):
        c.observe_decode(1e-3, 1)
    for _ in range(2):
        c.observe_decode(1.0, 1)                 # cadence collapse
    c.begin_step(0.0, 0), c.begin_step(0.0, 0)
    assert c.counters["watchdog_trips"] >= 1
    assert c.level == 1


# ---------------------------------------------------------------------------
# latency model + tuning-table seeding
# ---------------------------------------------------------------------------


def test_latency_model_ewma_and_dense_fallback(setup):
    cfg, params = setup
    lm = LatencyModel(params, cfg, max_slots=4)   # dense: no sparse leaves
    assert lm.table_step_s(4) is None
    assert math.isnan(lm.tpot_s())
    lm.observe_step(0.08, 8)
    assert lm.tpot_s() == pytest.approx(0.01)
    lm.observe_prefill(16, 0.2)
    assert lm.prefill_s(16) == pytest.approx(0.2)
    # same bucket: plen 12 shares bucket(12)=16
    assert lm.prefill_s(12) == pytest.approx(0.2)
    assert lm.request_s(16, 10) == pytest.approx(0.2 + 10 * 0.01)


def test_latency_model_seeds_from_table(setup):
    cfg, params = setup
    tiers = build_tiers(params, ["1:4:8-gr64"])
    lm = LatencyModel(tiers[0].params, cfg, max_slots=4)
    assert lm._weights                            # sparse leaves found
    # no table -> no prediction (matmul_latency has no shipped default)
    assert lm.table_step_s(4) is None
    table = TuningTable.for_device()
    for ctx, _mult in lm._weights:
        key = shape_key("matmul_latency", **ctx) + f"/M{bucket(4)}"
        table.put(key, 100.0)                     # 100us per matmul
    routing.set_active_table(table)
    want = 1e-4 * sum(m for _, m in lm._weights)
    assert lm.table_step_s(4) == pytest.approx(want)
    assert lm.tpot_s() == pytest.approx(want)     # table seeds cold start
    lm.observe_step(0.5, 1)
    assert lm.tpot_s() == pytest.approx(0.5)      # observation takes over


def test_matmul_latency_us_lookup_and_default():
    kw = dict(K=256, R=512, fmt=(1, 4, 8), gr=64, dtype="float32")
    us, src = routing.matmul_latency_us(M=4, **kw)
    assert us is None and src == "default"
    table = TuningTable.for_device()
    table.put(shape_key("matmul_latency", **kw) + f"/M{bucket(4)}", 37.5)
    routing.set_active_table(table)
    us, src = routing.matmul_latency_us(M=3, **kw)   # bucket(3) == 4
    assert us == 37.5 and src == "table"


# ---------------------------------------------------------------------------
# queue: priorities, deadlines, shedding
# ---------------------------------------------------------------------------


def _req(uid, *, prio=0, t=0.0, deadline=None):
    return Request(uid=uid, prompt=np.array([1, 2, 3]), max_new_tokens=4,
                   arrival_time=t, priority=prio, deadline_s=deadline)


def test_pop_ready_prefers_priority_then_deadline():
    q = RequestQueue()
    q.push(_req(0, prio=0))
    q.push(_req(1, prio=2, deadline=9.0))
    q.push(_req(2, prio=2, deadline=1.0))
    q.push(_req(3, prio=1))
    assert q.pop_ready(0.0).uid == 2    # highest prio, earliest deadline
    assert q.pop_ready(0.0).uid == 1
    assert q.pop_ready(0.0).uid == 3
    assert q.pop_ready(0.0).uid == 0


def test_expired_removes_past_deadline_only():
    q = RequestQueue()
    q.push(_req(0, t=0.0, deadline=1.0))
    q.push(_req(1, t=0.0, deadline=5.0))
    q.push(_req(2, t=0.0))
    dead = q.expired(2.0)
    assert [r.uid for r in dead] == [0]
    assert len(q) == 2


def test_shed_drops_lowest_priority_newest_first():
    q = RequestQueue()
    q.push(_req(0, prio=1, t=0.0))
    q.push(_req(1, prio=0, t=1.0))
    q.push(_req(2, prio=0, t=2.0))
    q.push(_req(3, prio=2, t=3.0))
    victims = q.shed(keep=2)
    assert sorted(r.uid for r in victims) == [1, 2]   # the prio-0 pair
    # within a priority the newest sheds first
    assert victims[0].uid in (1, 2)
    q2 = RequestQueue()
    for uid, t in ((0, 0.0), (1, 1.0), (2, 2.0)):
        q2.push(_req(uid, prio=0, t=t))
    assert {r.uid for r in q2.shed(keep=2)} == {2}
    assert q2.shed(keep=5) == []


# ---------------------------------------------------------------------------
# typed error family
# ---------------------------------------------------------------------------


def test_error_family_shape():
    from repro.serve import InjectedFaultError
    assert issubclass(PromptTooLongError, ServeError)
    assert issubclass(PromptTooLongError, ValueError)   # compat spelling
    assert issubclass(DeadlineExceededError, ServeError)
    assert issubclass(EngineOverloadError, ServeError)
    assert not issubclass(InjectedFaultError, ServeError)


def test_raise_for_output():
    def out(reason):
        return RequestOutput(uid=1, prompt_len=3, tokens=[],
                             finish_reason=reason, arrival_time=0.0,
                             admitted_time=float("nan"), finish_time=1.0,
                             token_times=[])
    with pytest.raises(EngineOverloadError):
        raise_for_output(out("shed"))
    with pytest.raises(DeadlineExceededError):
        raise_for_output(out("timeout"))
    with pytest.raises(PromptTooLongError):
        raise_for_output(out("rejected"))
    raise_for_output(out("length"))     # served: no-op


def test_submit_raises_typed_errors(setup):
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=16,
                      max_queue=1)
    with pytest.raises(PromptTooLongError):
        eng.submit(Request(uid=0, prompt=make_prompt(20, vocab=cfg.vocab),
                           max_new_tokens=4))
    eng.submit(Request(uid=1, prompt=make_prompt(4, vocab=cfg.vocab),
                       max_new_tokens=4, arrival_time=99.0))
    with pytest.raises(EngineOverloadError):
        eng.submit(Request(uid=2, prompt=make_prompt(4, vocab=cfg.vocab),
                           max_new_tokens=4, arrival_time=99.0))


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def _served(uid, t0=0.0):
    return RequestOutput(uid=uid, prompt_len=4, tokens=[1, 2, 3],
                         finish_reason="length", arrival_time=t0,
                         admitted_time=t0 + 0.01, finish_time=t0 + 0.05,
                         token_times=[t0 + 0.02, t0 + 0.03, t0 + 0.05])


def test_summarize_counts_unserved_and_attainment():
    outs = [
        _served(0),
        RequestOutput(uid=1, prompt_len=4, tokens=[], finish_reason="shed",
                      arrival_time=0.0, admitted_time=float("nan"),
                      finish_time=0.5, token_times=[]),
        RequestOutput(uid=2, prompt_len=4, tokens=[],
                      finish_reason="timeout", arrival_time=0.0,
                      admitted_time=float("nan"), finish_time=0.5,
                      token_times=[]),
    ]
    met = summarize(outs, wall_time=1.0, slo_tpot_s=1.0)
    assert met.num_requests == 1          # unserved excluded
    assert met.num_shed == 1 and met.num_timeout == 1
    assert met.slo_attainment == pytest.approx(1 / 3)   # unserved miss SLO
    rep = met.report()
    assert "shed 1" in rep and "timeout 1" in rep
    assert "SLO" in rep


def test_report_renders_nan_as_dashes():
    met = summarize([], wall_time=1.0)
    rep = met.report()
    assert "--" in rep and "nan" not in rep
    assert math.isnan(met.slo_attainment)   # no SLO given -> no line
    assert "SLO" not in rep


def test_atomic_write_json(tmp_path):
    path = os.path.join(tmp_path, "out.json")
    atomic_write_json(path, {"a": 1})
    atomic_write_json(path, {"a": 2, "b": [1, 2]})
    with open(path) as f:
        assert json.load(f) == {"a": 2, "b": [1, 2]}
    assert os.listdir(tmp_path) == ["out.json"]   # no tmp litter


# ---------------------------------------------------------------------------
# recompile-free tier switches (the tentpole guarantee)
# ---------------------------------------------------------------------------


def test_tier_switches_are_recompile_free(setup):
    cfg, params = setup
    # tiers without a controller: manual set_tier persists (with an SLO
    # controller attached, the ladder level owns the tier choice and
    # would swap back to tier 0 while healthy)
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=24,
                      decode_chunk=4, tiers=["dense", "1:4:8-gr64"])
    eng.warm_tiers(prompt_lens=(8,))
    before = dict(trace_events())

    def burst(uids):
        return [Request(uid=u, prompt=make_prompt(8, seed=u,
                                                  vocab=cfg.vocab),
                        max_new_tokens=6) for u in uids]

    outs = eng.run(burst(range(3)))
    eng.set_tier(1)
    outs += eng.run(burst(range(3, 6)))
    eng.set_tier(0)
    outs += eng.run(burst(range(6, 9)))
    assert trace_events() == before       # zero retraces after warmup
    assert eng.stats["tier_switches"] == 2
    assert all(o.finish_reason == "length" for o in outs)
    assert eng.tokens_by_tier["dense"] > 0
    assert eng.tokens_by_tier["1:4:8-gr64"] > 0

"""Paged KV cache: differential equivalence against the slot cache (tokens
and bitwise KV contents), allocator invariants (property-based plus seeded
randomized fallbacks), copy-on-write prefix sharing, and compaction.

The load-bearing guarantee: the paged engine is *observationally
identical* to the slot engine — same tokens for every request under any
admission/eviction order — because decode runs the unchanged
``decode_step`` over a gathered slot-major view of the page pool.  These
tests pin that down at both the cache layer (bitwise KV rows) and the
engine layer (token streams under oversubscription, sharing, preemption).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.configs import get_smoke
from repro.models import init_lm
from repro.serve import (
    PageAllocator,
    PagedKVCache,
    PromptTooLongError,
    Request,
    ServeEngine,
    SlotKVCache,
    prefix_hashes,
)
from repro.serve.engine import _jit_decode, _jit_paged_decode

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    yield cfg, params
    # This module compiles many engine variants (page sizes x widths x
    # chunk lengths).  The tier-1 suite runs ~400 tests in one process;
    # dropping this module's executables keeps late XLA compiles from
    # running against a process full of retained programs.
    from repro.serve import cache as _cache, engine as _engine
    for mod in (_cache, _engine):
        for fn in vars(mod).values():
            clear = getattr(fn, "cache_clear", None)
            if clear is not None:
                clear()
    jax.clear_caches()


def make_prompt(length, seed=0, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (length,), 0, vocab, jnp.int32
    ))


def run_tokens(engine, reqs):
    return [(o.uid, o.tokens, o.finish_reason) for o in engine.run(reqs)]


def seq_rows(tree, slot, n):
    """The first ``n`` valid seq rows (and the state row) of one slot, as
    numpy — the bitwise comparison unit.  Seq leaves are [L, B, S, ...]
    (ndim >= 3 with the seq axis at 2); state leaves are [L, B, ...]."""
    out = []
    for leaf in jax.tree_util.tree_leaves(tree):
        a = np.asarray(leaf)
        out.append(a[:, slot, :n] if a.ndim >= 3 else a[:, slot])
    return out


# ---------------------------------------------------------------------------
# differential: paged == slot, tokens and bitwise KV
# ---------------------------------------------------------------------------


def _mixed_trace(vocab, n=6, base_seed=0):
    """Prompt-length mix spanning sub-page, page-aligned, and multi-page."""
    lens = [3, 8, 13, 16, 21, 5][:n]
    return [Request(uid=i, prompt=make_prompt(L, seed=base_seed + i,
                                              vocab=vocab),
                    max_new_tokens=4 + i % 3)
            for i, L in enumerate(lens)]


@pytest.mark.parametrize("page_size", [4, 8, 16])
def test_paged_matches_slot_tokens(setup, page_size):
    """Every request's token stream is identical between the slot cache
    and the paged cache, across page sizes and a prompt-length mix that
    exercises partial tail pages and multi-page prompts."""
    cfg, params = setup
    want = run_tokens(
        ServeEngine(params, cfg, max_slots=3, max_seq_len=32,
                    decode_chunk=4),
        _mixed_trace(cfg.vocab))
    got = run_tokens(
        ServeEngine(params, cfg, max_slots=3, max_seq_len=32,
                    decode_chunk=4, paged=True, page_size=page_size),
        _mixed_trace(cfg.vocab))
    assert got == want


def test_paged_matches_slot_across_eviction_orders(setup):
    """Slot reuse (more requests than slots) and mixed stop conditions:
    admission/eviction interleavings differ between runs but outputs do
    not."""
    cfg, params = setup
    def trace():
        reqs = _mixed_trace(cfg.vocab, base_seed=50)
        # an immediate finisher forces an extra early eviction + slot reuse
        reqs.append(Request(uid=9, prompt=make_prompt(7, seed=59,
                                                      vocab=cfg.vocab),
                            max_new_tokens=1))
        return reqs
    want = run_tokens(ServeEngine(params, cfg, max_slots=2, max_seq_len=32,
                                  decode_chunk=1), trace())
    got = run_tokens(ServeEngine(params, cfg, max_slots=2, max_seq_len=32,
                                 decode_chunk=1, paged=True, page_size=8),
                     trace())
    assert got == want


def test_paged_single_and_chunked_decode_agree(setup):
    """The paged chunked decode (scan over the gathered view, one commit)
    equals the per-token paged loop — the paged analogue of the slot
    engine's chunk-equivalence guarantee."""
    cfg, params = setup
    a = run_tokens(ServeEngine(params, cfg, max_slots=2, max_seq_len=32,
                               decode_chunk=1, paged=True, page_size=8),
                   _mixed_trace(cfg.vocab, base_seed=9))
    b = run_tokens(ServeEngine(params, cfg, max_slots=2, max_seq_len=32,
                               decode_chunk=4, paged=True, page_size=8),
                   _mixed_trace(cfg.vocab, base_seed=9))
    assert a == b


@pytest.mark.parametrize("page_size", [4, 8])
def test_paged_kv_bitwise_equals_slot(setup, page_size):
    """Drive both caches through admission + decode and compare the valid
    KV rows *bitwise*: the paged pool, read back through its page table,
    must hold exactly the bytes the slot cache holds."""
    cfg, params = setup
    S0, S1 = 11, 6
    p0 = jnp.asarray(make_prompt(S0, seed=1, vocab=cfg.vocab)[None])
    p1 = jnp.asarray(make_prompt(S1, seed=2, vocab=cfg.vocab)[None])

    sk = SlotKVCache(cfg, 2, 32)
    pk = PagedKVCache(cfg, 2, 32, page_size=page_size)
    lg_s0 = sk.write_prefill(params, p0, 0)
    lg_p0 = pk.admit(params, p0, 0)
    sk.write_prefill(params, p1, 1)
    pk.admit(params, p1, 1)
    np.testing.assert_array_equal(np.asarray(lg_s0), np.asarray(lg_p0))

    # greedy-decode both for a few steps with identical per-slot positions
    dec_s = _jit_decode(cfg)
    dec_p = _jit_paged_decode(cfg, pk.page_size, pk.num_pages)
    tok_s = np.asarray(
        [int(jnp.argmax(lg_s0[0]))] * 2, np.int32)  # slot 1 junk is masked
    tok_p = tok_s.copy()
    pos = np.asarray([S0, S1], np.int32)
    for step in range(5):
        assert pk.ensure_writable_range(0, int(pos[0]), 1)
        assert pk.ensure_writable_range(1, int(pos[1]), 1)
        ls, sk.data = dec_s(params, jnp.asarray(tok_s[:, None]), sk.data,
                            jnp.asarray(pos))
        lp, pk.data = dec_p(params, jnp.asarray(tok_p[:, None]), pk.data,
                            pk.device_table(), jnp.asarray(pos))
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lp))
        tok_s = np.asarray(jnp.argmax(ls, -1), np.int32)
        tok_p = np.asarray(jnp.argmax(lp, -1), np.int32)
        pos = pos + 1

    view = pk.logical_view()
    for slot, valid in ((0, S0 + 5), (1, S1 + 5)):
        for a, b in zip(seq_rows(sk.data, slot, valid),
                        seq_rows(view, slot, valid)):
            np.testing.assert_array_equal(a, b)


def test_admission_order_does_not_leak_between_slots(setup):
    """Admitting request B after A (into a pool where A's pages are
    interleaved with B's) leaves A's rows bitwise untouched — the paged
    reuse of the slot-isolation guarantee."""
    cfg, params = setup
    pk = PagedKVCache(cfg, 3, 32, page_size=4)
    pa = jnp.asarray(make_prompt(10, seed=3, vocab=cfg.vocab)[None])
    pb = jnp.asarray(make_prompt(7, seed=4, vocab=cfg.vocab)[None])
    pk.admit(params, pa, 0)
    before = seq_rows(pk.logical_view(), 0, 10)
    pk.admit(params, pb, 1)
    pk.release_slot(1)
    pk.admit(params, pb, 2)  # reuses slot-1's just-freed pages
    after = seq_rows(pk.logical_view(), 0, 10)
    for a, b in zip(before, after):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# prefix sharing + copy-on-write
# ---------------------------------------------------------------------------


def test_shared_prefix_outputs_identical_to_unshared(setup):
    """Requests with a common prompt prefix served with sharing on must
    produce exactly the outputs of the sharing-off engine, while actually
    sharing pages (shared_tokens > 0, fewer pages used)."""
    cfg, params = setup
    prefix = make_prompt(12, seed=30, vocab=cfg.vocab)
    def trace():
        return [Request(uid=i,
                        prompt=np.concatenate(
                            [prefix, make_prompt(3 + i, seed=60 + i,
                                                 vocab=cfg.vocab)]),
                        max_new_tokens=4) for i in range(4)]
    on = ServeEngine(params, cfg, max_slots=4, max_seq_len=32,
                     decode_chunk=4, paged=True, page_size=4)
    off = ServeEngine(params, cfg, max_slots=4, max_seq_len=32,
                      decode_chunk=4, paged=True, page_size=4,
                      prefix_sharing=False)
    got_on = run_tokens(on, trace())
    got_off = run_tokens(off, trace())
    assert got_on == got_off
    assert on.kv.stats["shared_tokens"] > 0
    assert off.kv.stats["shared_tokens"] == 0
    assert (on.kv.stats["peak_pages_in_use"]
            < off.kv.stats["peak_pages_in_use"])


def test_decode_write_into_shared_page_copies_on_write(setup):
    """Two identical prompts share every page including the partial tail;
    the second slot's first decode-range write must CoW the tail page and
    leave the sibling's pages bitwise untouched."""
    cfg, params = setup
    prompt = jnp.asarray(make_prompt(10, seed=31, vocab=cfg.vocab)[None])
    pk = PagedKVCache(cfg, 2, 32, page_size=4)
    pk.admit(params, prompt, 0)
    pk.admit(params, prompt, 1)
    tail = 10 // 4  # logical page of the partial tail
    assert int(pk.table[0, tail]) == int(pk.table[1, tail])
    assert pk.alloc.refcount[int(pk.table[1, tail])] == 2
    before = seq_rows(pk.logical_view(), 0, 10)

    assert pk.ensure_writable_range(1, 10, 2)
    assert pk.stats["cow_copies"] == 1
    assert int(pk.table[0, tail]) != int(pk.table[1, tail])
    # sibling bitwise untouched; sharer's copy holds identical valid rows
    after0 = seq_rows(pk.logical_view(), 0, 10)
    after1 = seq_rows(pk.logical_view(), 1, 10)
    for a, b, c in zip(before, after0, after1):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)


def test_evicting_one_sharer_keeps_the_others_pages(setup):
    """Releasing one of two prefix-sharers must free nothing the survivor
    references; releasing the survivor then frees everything."""
    cfg, params = setup
    prompt = jnp.asarray(make_prompt(9, seed=32, vocab=cfg.vocab)[None])
    pk = PagedKVCache(cfg, 2, 32, page_size=4)
    pk.admit(params, prompt, 0)
    pk.admit(params, prompt, 1)
    survivor_pages = [p for _, p in pk.slot_pages(1)]
    before = seq_rows(pk.logical_view(), 1, 9)
    assert pk.release_slot(0) == []          # all pages still referenced
    for p in survivor_pages:
        assert pk.alloc.refcount[p] == 1
    for a, b in zip(before, seq_rows(pk.logical_view(), 1, 9)):
        np.testing.assert_array_equal(a, b)
    assert sorted(pk.release_slot(1)) == sorted(survivor_pages)
    assert pk.alloc.pages_in_use() == 0


def test_prefix_hash_chain_semantics():
    """Page j's digest commits to pages 0..j (chained), so a prompt that
    diverges at page k shares digests for pages < k only; the partial tail
    digest commits to the whole prompt (exact-match sharing only)."""
    a = np.arange(20, dtype=np.int32)
    b = a.copy(); b[9] = 999          # diverge inside page 2 (ps=4)
    ha, hb = prefix_hashes(a, 4), prefix_hashes(b, 4)
    assert [h for h, _ in ha[:2]] == [h for h, _ in hb[:2]]
    assert all(x != y for (x, _), (y, _) in zip(ha[2:], hb[2:]))
    assert [n for _, n in ha] == [4, 8, 12, 16, 20]
    # partial tail: covered_len is the full prompt length
    ht = prefix_hashes(a[:18], 4)
    assert [n for _, n in ht] == [4, 8, 12, 16, 18]
    # tail digest differs from the full-page digest of a longer prompt
    assert ht[-1][0] != ha[-1][0]


# ---------------------------------------------------------------------------
# allocator invariants — hypothesis properties + seeded fallbacks
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 16), st.lists(st.integers(0, 5), max_size=30))
def test_alloc_never_double_allocates(num_pages, sizes):
    """Property: across arbitrary alloc sequences, no live page is ever
    handed out twice, and failed allocs leave the pool untouched."""
    al = PageAllocator(num_pages)
    live = set()
    for n in sizes:
        free_before = al.num_free
        got = al.alloc(n)
        if got is None:
            assert n > free_before
            assert al.num_free == free_before
            continue
        assert len(got) == n and not (set(got) & live)
        live |= set(got)
        assert al.num_free + al.pages_in_use() == num_pages


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 12), st.data())
def test_refcount_frees_exactly_at_zero(num_pages, data):
    """Property: decref frees a page exactly when its model refcount hits
    zero — never before (shared pages survive) and never after."""
    al = PageAllocator(num_pages)
    model = {}
    for _ in range(40):
        op = data.draw(st.sampled_from(["alloc", "incref", "decref"]))
        if op == "alloc":
            got = al.alloc(1)
            if got is not None:
                model[got[0]] = 1
        elif op == "incref" and model:
            p = data.draw(st.sampled_from(sorted(model)))
            al.incref(p)
            model[p] += 1
        elif op == "decref" and model:
            p = data.draw(st.sampled_from(sorted(model)))
            model[p] -= 1
            freed = al.decref(p)
            assert freed == (model[p] == 0)
            if freed:
                del model[p]
        assert al.pages_in_use() == len(model)


def test_allocator_randomized_invariants():
    """Seeded randomized equivalent of the hypothesis properties above —
    runs in environments without hypothesis so the invariants are always
    exercised."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        num_pages = int(rng.integers(1, 16))
        al = PageAllocator(num_pages)
        model = {}
        for _ in range(60):
            op = rng.choice(["alloc", "incref", "decref", "burst"])
            if op in ("alloc", "burst"):
                n = 1 if op == "alloc" else int(rng.integers(0, 6))
                free_before = al.num_free
                got = al.alloc(n)
                if got is None:
                    assert n > free_before and al.num_free == free_before
                else:
                    assert len(set(got)) == n
                    assert not (set(got) & set(model))
                    for p in got:
                        model[p] = 1
            elif op == "incref" and model:
                p = int(rng.choice(sorted(model)))
                al.incref(p)
                model[p] += 1
            elif op == "decref" and model:
                p = int(rng.choice(sorted(model)))
                model[p] -= 1
                assert al.decref(p) == (model[p] == 0)
                if model[p] == 0:
                    del model[p]
            assert al.pages_in_use() == len(model)
            assert al.num_free + al.pages_in_use() == num_pages
        for p in sorted(model):
            for _ in range(model[p] - 1):
                assert not al.decref(p)
            assert al.decref(p)
        assert al.pages_in_use() == 0 and al.num_free == num_pages


def test_prefix_index_never_resurrects_freed_pages():
    """A prefix-hash entry dies with its page: after free + realloc, the
    old digest must not resolve to the recycled page."""
    al = PageAllocator(1)  # single page: realloc must recycle it
    (p,) = al.alloc(1)
    al.register_prefix(b"digest-a", p)
    assert al.lookup_prefix(b"digest-a") == p
    assert al.decref(p)
    assert al.lookup_prefix(b"digest-a") is None
    (q,) = al.alloc(1)  # recycles the same physical page
    assert q == p and al.lookup_prefix(b"digest-a") is None


def test_compaction_preserves_live_page_contents(setup):
    """Compacting a fragmented pool packs live pages to the front while
    every slot's logical rows stay bitwise identical, the allocator's
    refcounts follow the move, and prefix sharing still works after."""
    cfg, params = setup
    pk = PagedKVCache(cfg, 4, 16, page_size=4)
    prompts = [jnp.asarray(make_prompt(6 + 3 * i, seed=70 + i,
                                       vocab=cfg.vocab)[None])
               for i in range(4)]
    for i, p in enumerate(prompts):
        pk.admit(params, p, i)
    pk.release_slot(0)
    pk.release_slot(2)  # fragment the pool
    lens = {1: prompts[1].shape[1], 3: prompts[3].shape[1]}
    before = {s: seq_rows(pk.logical_view(), s, n) for s, n in lens.items()}
    used_before = pk.alloc.pages_in_use()

    pk.compact()

    assert pk.alloc.pages_in_use() == used_before
    live = sorted(p for s in (1, 3) for _, p in pk.slot_pages(s))
    assert live == list(range(used_before))  # packed to the front
    for s, n in lens.items():
        for a, b in zip(before[s], seq_rows(pk.logical_view(), s, n)):
            np.testing.assert_array_equal(a, b)
    # the prefix index survived the renumbering: an identical prompt
    # re-admitted after compaction shares the survivor's pages
    pk.admit(params, prompts[1], 0)
    assert pk.stats["shared_tokens"] >= prompts[1].shape[1]


# ---------------------------------------------------------------------------
# typed admission errors
# ---------------------------------------------------------------------------


def test_prompt_too_long_raises_typed_error(setup):
    """Both caches raise PromptTooLongError (a ValueError, not a bare
    AssertionError) for over-capacity prompts — the regression for the
    admission assert that used to kill the serve loop."""
    cfg, params = setup
    long = jnp.asarray(make_prompt(40, seed=80, vocab=cfg.vocab)[None])
    with pytest.raises(PromptTooLongError):
        SlotKVCache(cfg, 2, 32).write_prefill(params, long, 0)
    with pytest.raises(PromptTooLongError):
        PagedKVCache(cfg, 2, 32, page_size=8).admit(params, long, 0)
    assert issubclass(PromptTooLongError, ValueError)


def test_hypothesis_marker():
    """Record (not assert) whether the property tests above ran under real
    hypothesis or as skipped stubs — visible in -v output either way."""
    assert HAVE_HYPOTHESIS in (True, False)

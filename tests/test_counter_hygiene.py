"""Counter hygiene: the autouse conftest fixture must isolate the
trace-time telemetry (``dispatch_counters`` / ``kernel_counters``), the
active tuning table, the unified telemetry registry, and the flight
recorder between tests.

The ``test_*_bleed_*`` twins are the regression proper: each performs one
counted operation and asserts the *exact total* count.  If the fixture
ever stops resetting, whichever twin runs second sees the first twin's
counts and fails — i.e. two counter-asserting tests cannot bleed into
each other in either execution order.
"""

import importlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import nmg
from repro.kernels import ops as kops
from repro.obs import trace as obs
from repro.obs.registry import REGISTRY
from repro.tune import TuningTable, routing

disp = importlib.import_module("repro.core.dispatch")

KEY = jax.random.PRNGKey(7)


def _one_routed_matmul():
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    kops.nmg_matmul(t, jnp.ones((96, 4)), use_pallas=False)


def _one_sparse_dispatch():
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    disp.dispatch("matmul", t, jnp.ones((96, 4)))


def test_counter_bleed_first_twin():
    """One routed matmul => exactly one gemv trace counted (would see 2 if
    the other twin's counts leaked in)."""
    _one_routed_matmul()
    counts = kops.kernel_counters()
    assert sum(v for (kern, _), v in counts.items()
               if kern == "nmg_gemv") == 1, counts


def test_counter_bleed_second_twin():
    """Identical to the first twin; passing in both execution orders is
    the no-bleed evidence."""
    _one_routed_matmul()
    counts = kops.kernel_counters()
    assert sum(v for (kern, _), v in counts.items()
               if kern == "nmg_gemv") == 1, counts


def test_dispatch_counter_bleed_first_twin():
    _one_sparse_dispatch()
    counts = disp.dispatch_counters()
    assert sum(v for k, v in counts.items() if k[0] == "impl") == 1, counts


def test_dispatch_counter_bleed_second_twin():
    _one_sparse_dispatch()
    counts = disp.dispatch_counters()
    assert sum(v for k, v in counts.items() if k[0] == "impl") == 1, counts


def test_fixture_clears_active_tuning_table_first():
    """Install a table; the fixture must have removed it by the next test
    (twin below asserts the default state)."""
    assert routing.active_table() is None
    routing.set_active_table(TuningTable.for_device())
    assert routing.active_table() is not None


def test_fixture_clears_active_tuning_table_second():
    assert routing.active_table() is None
    # and the dispatcher's cost-model hook was unwired with it
    assert disp.conversion_cost_model() is None


def test_registry_bleed_first_twin():
    """One inc on a registry counter => exactly 1.  The fixture's
    ``REGISTRY.reset()`` is what keeps the twins order-independent."""
    REGISTRY.counter("hygiene_probe", help="twin-test probe").inc()
    assert REGISTRY.snapshot()["hygiene_probe"] == 1


def test_registry_bleed_second_twin():
    REGISTRY.counter("hygiene_probe", help="twin-test probe").inc()
    assert REGISTRY.snapshot()["hygiene_probe"] == 1


def test_registry_reset_keeps_module_references_live():
    """``REGISTRY.reset()`` zeroes in place: the family objects dispatch
    and ops hold at module level must stay the registered instances, so
    post-reset increments land in the registry snapshot."""
    _one_routed_matmul()
    _one_sparse_dispatch()
    snap = REGISTRY.snapshot()
    assert sum(snap["kernel_routes"].values()) >= 1, snap
    assert sum(snap["dispatch"].values()) >= 1, snap
    REGISTRY.reset()
    assert REGISTRY.snapshot()["kernel_routes"] == {}
    mod = importlib.import_module("repro.kernels.ops")
    assert mod._KERNEL_COUNTS is REGISTRY.family("kernel_routes")


def test_recorder_bleed_first_twin():
    """The recorder starts disabled and empty; one recorded event is
    exactly one record (the fixture's ``obs.reset()`` pins both)."""
    assert not obs.enabled() and obs.records() == []
    obs.enable()
    obs.event("hygiene_probe", "engine")
    assert len(obs.records()) == 1


def test_recorder_bleed_second_twin():
    assert not obs.enabled() and obs.records() == []
    obs.enable()
    obs.event("hygiene_probe", "engine")
    assert len(obs.records()) == 1


def test_reset_helpers_clear_everything():
    """The reset functions themselves (what the fixture calls) empty the
    counters."""
    _one_routed_matmul()
    _one_sparse_dispatch()
    assert kops.kernel_counters() and disp.dispatch_counters()
    kops.reset_kernel_counters()
    disp.reset_dispatch_counters()
    assert kops.kernel_counters() == {}
    assert disp.dispatch_counters() == {}


def test_counted_results_unaffected_by_counters():
    """Sanity: counting is pure telemetry — the routed result equals the
    reference regardless of counter state."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, n=1, m=4, g=4, gr=2)
    b = jax.random.normal(jax.random.PRNGKey(8), (96, 4))
    want = np.asarray(t.to_dense() @ b)
    for _ in range(2):  # second call: counters already non-empty
        got = np.asarray(kops.nmg_matmul(t, b, use_pallas=False))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

"""Gradient semantics through sparse layouts (paper §4.5 + §3.4)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autograd import dense_grad_of, masked_grad, sparsify_grads
from repro.core.dispatch import OutFormat
from repro.core.layouts import FixedMaskTensor, GroupedNMTensor
from repro.core.sparsifiers import (
    KeepAll,
    ScalarFractionSparsifier,
    apply_sparsifier,
)
from repro.core import nmg
from repro.optim import value_and_grad_sparse

KEY = jax.random.PRNGKey(0)


def test_grad_through_fixed_mask():
    x = jax.random.normal(KEY, (8, 8))
    w = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    (val, grads) = value_and_grad_sparse(
        lambda p: jnp.sum(p.to_dense() ** 2))(w)
    assert isinstance(grads, FixedMaskTensor)
    np.testing.assert_allclose(
        np.asarray(grads.val),
        np.asarray(2 * w.val * w.mask), rtol=1e-5)


def test_grad_through_nmg_values():
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, 2, 4, 2)
    _, g = value_and_grad_sparse(lambda p: jnp.sum(p.to_dense() ** 2))(t)
    assert g.val.shape == t.val.shape
    np.testing.assert_allclose(np.asarray(g.val), np.asarray(2 * t.val),
                               rtol=1e-5)
    # integer metadata gets no gradient
    assert g.blk_idx is None or g.blk_idx.dtype != jnp.float32


def test_dense_grad_of_fixed_mask():
    x = jax.random.normal(KEY, (4, 4))
    w = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    _, g = value_and_grad_sparse(lambda p: jnp.sum(p.to_dense()))(w)
    d = dense_grad_of(w, g)
    np.testing.assert_allclose(np.asarray(d),
                               np.asarray(w.mask.astype(jnp.float32)))


def test_masked_grad_convention():
    g = jnp.ones((4, 4))
    m = jnp.eye(4, dtype=bool)
    out = masked_grad(g, m)
    assert float(out.sum()) == 4.0


def test_sparsify_grads_preserves_origin_treedef():
    """Grad-format round trip: the cotangent treedef (including the static
    ``origin`` aux) must keep mirroring the primal params, or the optimizer's
    flatten-by-params-treedef desyncs (regression: origin was dropped)."""
    x = jax.random.normal(KEY, (8, 8))
    w = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    assert w.origin is not None
    params = {"w": w}
    _, grads = value_and_grad_sparse(
        lambda p: jnp.sum(p["w"].to_dense() ** 2))(params)
    fmts = {"w": OutFormat(KeepAll(), None,
                           ScalarFractionSparsifier(0.75), FixedMaskTensor)}
    out = sparsify_grads(grads, fmts)
    assert out["w"].origin is w.origin
    # the round trip leaves the cotangent treedef untouched ...
    assert (jax.tree_util.tree_structure(out)
            == jax.tree_util.tree_structure(grads))
    # ... so the optimizer's flatten-by-params-treedef still accepts it
    # (this raises on origin-aux desync — the regression)
    treedef = jax.tree_util.tree_structure(params)
    flat = treedef.flatten_up_to(out)
    assert len(flat) == len(jax.tree_util.tree_leaves(params))


def test_sparsify_grads_by_format():
    """Paper §3.4 set_weight_grad: named gradients re-sparsified before the
    optimizer."""
    grads = {"w": jax.random.normal(KEY, (8, 8)),
             "b": jnp.ones((8,))}
    fmts = {"w": OutFormat(KeepAll(), None,
                           ScalarFractionSparsifier(0.75), FixedMaskTensor)}
    out = sparsify_grads(grads, fmts)
    d = np.asarray(out["w"])
    assert (d == 0).mean() > 0.5  # sparsified
    np.testing.assert_allclose(np.asarray(out["b"]), 1.0)  # untouched


def test_loss_grad_through_sparse_linear_op():
    """End-to-end: grad of a loss through sten.linear with an n:m:g
    weight reaches the compressed values."""
    from repro.core import ops as sten_ops

    x = jax.random.normal(KEY, (4, 96))
    w = nmg.dense_to_grouped_nm(
        jax.random.normal(jax.random.PRNGKey(1), (96, 32)), 2, 4, 2,
        sparse_dim=0)

    def loss(w):
        y = sten_ops.linear(x, w)
        return jnp.sum(y ** 2)

    _, g = value_and_grad_sparse(loss)(w)
    assert np.isfinite(np.asarray(g.val)).all()
    assert float(np.abs(np.asarray(g.val)).sum()) > 0

"""ShardingRules edge cases not covered by the integration dist tests:
empty rules, rank-mismatched leaves, divisibility/dedup guards, and the
sparse-leaf (FixedMaskTensor) value/mask co-sharding invariant."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.layouts import FixedMaskTensor
from repro.dist.sharding import (
    ShardingRules,
    batch_spec,
    param_specs,
    tree_shardings,
)

EMPTY = ShardingRules(batch=None, seq=None, embed=None, heads=None,
                      ff=None, vocab=None, expert=None)


class FakeMesh:
    """Mesh stand-in for pure spec logic (param_specs/batch_spec only use
    axis_names and shape); lets unit tests exercise >1-sized axes without
    the subprocess device-count harness."""

    def __init__(self, **shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


MESH_2x4 = FakeMesh(data=2, model=4)


def spec_leaves(tree):
    return jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, P))


def test_resolve_filters_to_available_axes():
    r = ShardingRules()
    assert r.resolve("batch", {"data", "model"}) == "data"
    assert r.resolve("batch", {"pod", "data", "model"}) == ("pod", "data")
    assert r.resolve("heads", {"data"}) is None
    assert r.resolve("no_such_logical_axis", {"data", "model"}) is None


def test_resolve_comma_string():
    # the CLI hillclimb form: --opt heads=data,model
    r = ShardingRules(heads="data,model", ff="model")
    assert r.resolve("heads", {"data", "model"}) == ("data", "model")
    assert r.resolve("ff", {"data", "model"}) == "model"
    assert ShardingRules(ff="").resolve("ff", {"model"}) is None


def test_empty_rules_replicate_everything():
    params = {
        "embedding": jnp.zeros((16, 8)),
        "layers": {"mlp": {"wi": jnp.zeros((2, 8, 32)),
                           "wo": jnp.zeros((2, 32, 8))}},
    }
    specs = param_specs(params, EMPTY, MESH_2x4)
    for s in spec_leaves(specs):
        assert s == P(*([None] * len(s)))
    assert batch_spec(jnp.zeros((8, 4)), EMPTY, MESH_2x4) == P(None, None)


def test_rank_mismatched_leaves_never_crash():
    # leaves whose rank is below what the name-pattern rule expects must
    # degrade to replicated, not index out of range
    params = {
        "embedding": jnp.zeros((16,)),          # rule wants 2 dims
        "layers": {"mlp": {"wi": jnp.zeros((32,)),
                           "wo": jnp.zeros(())},  # scalar
                   "attn": {"wo": jnp.zeros((8,))}},
    }
    specs = param_specs(params, ShardingRules(), MESH_2x4)
    assert specs["layers"]["mlp"]["wo"] == P()
    # embedding [16]: vocab rule targets dim -2 (absent); embed dim -1 is
    # None by default -> fully replicated
    assert specs["embedding"] == P(None)
    assert specs["layers"]["attn"]["wo"] == P(None)


def test_non_divisible_dims_fall_back_to_replicated():
    params = {"layers": {"mlp": {"wi": jnp.zeros((2, 8, 30))}}}  # 30 % 4 != 0
    specs = param_specs(params, ShardingRules(), MESH_2x4)
    assert specs["layers"]["mlp"]["wi"] == P(None, None, None)
    # batch dim not divisible by the dp axis -> replicated
    assert batch_spec(jnp.zeros((3, 4)), ShardingRules(), MESH_2x4) == \
        P(None, None)


def test_mesh_axis_never_used_twice_per_leaf():
    # moe wi [E, D, F']: expert and ff both resolve to "model"; only the
    # expert dim may take it
    params = {"layers": {"moe": {"wi": jnp.zeros((4, 8, 16))}}}
    specs = param_specs(params, ShardingRules(), MESH_2x4)
    assert specs["layers"]["moe"]["wi"] == P("model", None, None)


def test_fixed_mask_value_and_mask_shard_identically():
    val = jnp.ones((8, 16))
    mask = jnp.ones((8, 16), bool)
    params = {"layers": {"mlp": {"wi": FixedMaskTensor(val, mask)}}}
    specs = param_specs(params, ShardingRules(), MESH_2x4)
    node = specs["layers"]["mlp"]["wi"]
    assert isinstance(node, FixedMaskTensor)
    assert node.val == node.mask == P(None, "model")


def test_sparse_leaf_shardings_round_trip_device_put():
    # on a real (1-device) mesh the spec tree must match the params treedef
    # exactly: tree_shardings + device_put round-trips sparse leaves
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    val = jnp.arange(32, dtype=jnp.float32).reshape(4, 8)
    mask = (val % 2 == 0)
    params = {"layers": {"mlp": {"wi": FixedMaskTensor(val, mask)}},
              "final_norm": jnp.zeros((8,))}
    sh = tree_shardings(param_specs(params, ShardingRules(), mesh), mesh)
    node = sh["layers"]["mlp"]["wi"]
    assert isinstance(node.val, NamedSharding)
    assert node.val.spec == node.mask.spec
    placed = jax.device_put(params, sh)
    np.testing.assert_array_equal(
        np.asarray(placed["layers"]["mlp"]["wi"].to_dense()),
        np.asarray(val * mask))

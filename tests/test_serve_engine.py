"""Continuous-batching engine tests: slot admission/eviction, mid-stream
arrival, stop conditions, sparse-weight serving, and the serving-equivalence
guarantee (engine output == the classic one-shot prefill+decode loop) that
guards the ``prefill``/``decode_step`` slot refactor."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import decode_step, init_lm, prefill
from repro.serve import (
    Request,
    RequestQueue,
    SamplingParams,
    ServeEngine,
    compare_dense_sparse,
    sample_token,
)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    yield cfg, params
    # drop this module's compiled engine variants (same rationale as
    # test_paged_cache.py: keep the long single-process suite from
    # accumulating executables)
    from repro.serve import cache as _cache, engine as _engine
    for mod in (_cache, _engine):
        for fn in vars(mod).values():
            clear = getattr(fn, "cache_clear", None)
            if clear is not None:
                clear()
    jax.clear_caches()


def make_prompt(length, seed=0, vocab=512):
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (length,), 0, vocab, jnp.int32
    ))


def oneshot_greedy(params, cfg, prompt, gen_len):
    """The pre-engine serving loop: prefill + scalar-pos greedy decode."""
    S = prompt.size
    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None],
                            cache_len=S + gen_len)
    tok = int(jnp.argmax(logits, -1)[0])
    out = [tok]
    for i in range(gen_len - 1):
        logits, cache = decode_step(
            params, cfg, jnp.asarray([[tok]], jnp.int32), cache,
            jnp.asarray(S + i),
        )
        tok = int(jnp.argmax(logits, -1)[0])
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# serving equivalence — the refactor guard
# ---------------------------------------------------------------------------


def test_engine_matches_oneshot_single_request(setup):
    """A single greedy request through the slot engine must reproduce the
    one-shot loop token for token (pinned seed)."""
    cfg, params = setup
    prompt = make_prompt(12, seed=7, vocab=cfg.vocab)
    want = oneshot_greedy(params, cfg, prompt, gen_len=6)

    eng = ServeEngine(params, cfg, max_slots=4, max_seq_len=18)
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=6)])
    assert len(outs) == 1
    assert outs[0].tokens == want
    assert outs[0].finish_reason == "length"


def test_engine_matches_oneshot_under_batching(setup):
    """Slot isolation: a request's tokens are identical whether it is served
    alone or alongside unrelated traffic in other slots."""
    cfg, params = setup
    prompt = make_prompt(10, seed=3, vocab=cfg.vocab)
    want = oneshot_greedy(params, cfg, prompt, gen_len=5)

    others = [Request(uid=10 + i, prompt=make_prompt(6 + i, seed=100 + i,
                                                     vocab=cfg.vocab),
                      max_new_tokens=7) for i in range(3)]
    eng = ServeEngine(params, cfg, max_slots=4, max_seq_len=16)
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=5)] + others)
    got = next(o for o in outs if o.uid == 0)
    assert got.tokens == want


def test_chunked_decode_matches_per_token_reference(setup):
    """The jitted multi-token decode chunk (on-device greedy sampling, one
    host fetch per chunk) produces exactly the tokens of the per-token
    host-paced loop, across mixed budgets, stop tokens, and slot reuse."""
    cfg, params = setup
    prompt = make_prompt(10, seed=5, vocab=cfg.vocab)
    stop = oneshot_greedy(params, cfg, prompt, gen_len=6)[2]
    reqs = lambda: [  # noqa: E731
        Request(uid=0, prompt=make_prompt(12, seed=7, vocab=cfg.vocab),
                max_new_tokens=9),
        Request(uid=1, prompt=make_prompt(6, seed=8, vocab=cfg.vocab),
                max_new_tokens=3),
        Request(uid=2, prompt=prompt, max_new_tokens=6,
                stop_tokens=(stop,)),
        Request(uid=3, prompt=make_prompt(5, seed=9, vocab=cfg.vocab),
                max_new_tokens=7),
    ]
    ref = ServeEngine(params, cfg, max_slots=2, max_seq_len=24,
                      decode_chunk=1).run(reqs())
    got = ServeEngine(params, cfg, max_slots=2, max_seq_len=24,
                      decode_chunk=4).run(reqs())
    assert [(o.uid, o.tokens, o.finish_reason) for o in got] == \
        [(o.uid, o.tokens, o.finish_reason) for o in ref]


def test_non_greedy_requests_take_host_path(setup):
    """A non-greedy request in the batch falls back to the per-token loop,
    keeping seeded sampling reproducible under chunked engines."""
    cfg, params = setup
    prompt = make_prompt(8, seed=11, vocab=cfg.vocab)
    sp = SamplingParams(greedy=False, temperature=0.7, top_k=8, seed=42)
    mk = lambda: [Request(uid=0, prompt=prompt, max_new_tokens=5,  # noqa: E731
                          sampling=sp),
                  Request(uid=1, prompt=make_prompt(6, seed=12,
                                                    vocab=cfg.vocab),
                          max_new_tokens=5)]
    a = ServeEngine(params, cfg, max_slots=2, max_seq_len=14,
                    decode_chunk=8).run(mk())
    b = ServeEngine(params, cfg, max_slots=2, max_seq_len=14,
                    decode_chunk=1).run(mk())
    assert [o.tokens for o in a] == [o.tokens for o in b]


# ---------------------------------------------------------------------------
# scheduling: admission, eviction, mid-stream arrival
# ---------------------------------------------------------------------------


def test_more_requests_than_slots(setup):
    """8 requests through 2 slots: all finish, slots are reused (evicted
    and overwritten), outputs keep their request identity."""
    cfg, params = setup
    reqs = [Request(uid=i, prompt=make_prompt(6 + i % 3, seed=i,
                                              vocab=cfg.vocab),
                    max_new_tokens=4) for i in range(8)]
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=16)
    outs = eng.run(reqs)
    assert [o.uid for o in outs] == list(range(8))
    assert all(len(o.tokens) == 4 for o in outs)
    assert eng.num_active == 0 and len(eng.free_slots()) == 2


def test_slot_reset_does_not_change_results(setup):
    """Explicit slot zeroing between occupants (reset_freed_slots) must not
    change any request's output — proving freed-slot garbage is never
    read."""
    cfg, params = setup
    reqs = [Request(uid=i, prompt=make_prompt(5 + i % 2, seed=40 + i,
                                              vocab=cfg.vocab),
                    max_new_tokens=5) for i in range(6)]
    ref = ServeEngine(params, cfg, max_slots=2, max_seq_len=12).run(reqs)
    got = ServeEngine(params, cfg, max_slots=2, max_seq_len=12,
                      reset_freed_slots=True).run(reqs)
    assert [o.tokens for o in got] == [o.tokens for o in ref]


def test_mid_stream_arrival(setup):
    """A request that arrives while others are decoding is admitted into a
    free slot mid-stream and still matches its solo output."""
    cfg, params = setup
    late_prompt = make_prompt(8, seed=77, vocab=cfg.vocab)
    want = oneshot_greedy(params, cfg, late_prompt, gen_len=4)

    # deterministic virtual clock: each call advances 1ms, so the late
    # arrival lands after several decode steps
    t = {"now": 0.0}

    def clock():
        t["now"] += 1e-3
        return t["now"]

    early = [Request(uid=i, prompt=make_prompt(6, seed=i, vocab=cfg.vocab),
                     max_new_tokens=12) for i in range(2)]
    late = Request(uid=9, prompt=late_prompt, max_new_tokens=4,
                   arrival_time=0.02)
    eng = ServeEngine(params, cfg, max_slots=3, max_seq_len=20, clock=clock)
    outs = eng.run(early + [late])
    got = next(o for o in outs if o.uid == 9)
    assert got.tokens == want
    assert got.admitted_time > outs[0].admitted_time  # genuinely later


# ---------------------------------------------------------------------------
# stop conditions and sampling
# ---------------------------------------------------------------------------


def test_stop_token_ends_generation(setup):
    """Generation ends at the first stop token.  Discover what greedy
    decoding produces, then re-serve with that token as a stop."""
    cfg, params = setup
    prompt = make_prompt(10, seed=5, vocab=cfg.vocab)
    free = oneshot_greedy(params, cfg, prompt, gen_len=6)
    stop = free[2]  # stop at the third generated token

    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=16)
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=6,
                            stop_tokens=(stop,))])
    assert outs[0].finish_reason == "stop"
    assert outs[0].tokens == free[:3]


def test_max_new_tokens_clamped_to_cache(setup):
    """A budget larger than the slot capacity finishes with 'length' at
    exactly the cache-capacity token count."""
    cfg, params = setup
    prompt = make_prompt(8, seed=9, vocab=cfg.vocab)
    eng = ServeEngine(params, cfg, max_slots=1, max_seq_len=12)
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=100)])
    # S + N - 1 <= max_seq_len  =>  N = 12 - 8 + 1 = 5
    assert len(outs[0].tokens) == 5
    assert outs[0].finish_reason == "length"


def test_sampling_reproducible_and_stop_immediate(setup):
    """Per-request seeded sampling is reproducible across runs; a
    max_new_tokens=1 request finishes straight from prefill."""
    cfg, params = setup
    prompt = make_prompt(8, seed=11, vocab=cfg.vocab)
    sp = SamplingParams(greedy=False, temperature=0.7, top_k=8, seed=123)
    req = lambda: Request(uid=0, prompt=prompt, max_new_tokens=6,  # noqa: E731
                          sampling=sp)
    a = ServeEngine(params, cfg, max_slots=2, max_seq_len=16).run([req()])
    b = ServeEngine(params, cfg, max_slots=2, max_seq_len=16).run([req()])
    assert a[0].tokens == b[0].tokens

    one = ServeEngine(params, cfg, max_slots=2, max_seq_len=16).run(
        [Request(uid=1, prompt=prompt, max_new_tokens=1)]
    )
    assert len(one[0].tokens) == 1 and one[0].finish_reason == "length"


def test_sample_token_top_k():
    rng = np.random.default_rng(0)
    logits = np.array([0.0, 5.0, 4.0, -1.0], np.float32)
    # top_k=1 degenerates to argmax regardless of temperature
    for _ in range(5):
        assert sample_token(logits, SamplingParams(greedy=False,
                                                   temperature=2.0, top_k=1),
                            rng) == 1
    # greedy ignores rng entirely
    assert sample_token(logits, SamplingParams(greedy=True), rng) == 1


def test_request_queue_arrival_order():
    q = RequestQueue()
    q.push(Request(uid=0, prompt=np.array([1]), arrival_time=0.5))
    q.push(Request(uid=1, prompt=np.array([1]), arrival_time=1.5))
    assert q.pop_ready(0.0) is None
    assert q.next_arrival() == 0.5
    assert q.pop_ready(1.0).uid == 0
    assert q.pop_ready(1.0) is None  # uid=1 not yet due
    assert q.pop_ready(2.0).uid == 1
    assert len(q) == 0


def test_request_queue_out_of_order_submission():
    """A due request is handed out even when it was submitted behind a
    not-yet-due one, and next_arrival reports the true minimum."""
    q = RequestQueue()
    q.push(Request(uid=0, prompt=np.array([1]), arrival_time=10.0))
    q.push(Request(uid=1, prompt=np.array([1]), arrival_time=0.0))
    assert q.next_arrival() == 0.0
    assert q.pop_ready(0.0).uid == 1
    assert q.pop_ready(0.0) is None
    assert q.pop_ready(10.0).uid == 0


# ---------------------------------------------------------------------------
# sparse path + metrics
# ---------------------------------------------------------------------------


def test_sparse_engine_serves_and_reports(setup):
    """The engine serves GroupedNMTensor params end to end and the
    dense-vs-sparse comparison yields valid side-by-side metrics."""
    cfg, params = setup
    reqs = [Request(uid=i, prompt=make_prompt(6, seed=i, vocab=cfg.vocab),
                    max_new_tokens=3) for i in range(3)]
    results = compare_dense_sparse(
        params, cfg, reqs, nm=(1, 4, 16),
        engine_kwargs=dict(max_slots=2, max_seq_len=10),
    )
    for label in ("dense", "sparse"):
        outs, met = results[label]
        assert len(outs) == 3
        assert met.num_tokens == 9
        assert met.tok_latency_p50 >= 0.0
        assert np.isfinite(met.throughput_tok_s)
        d = met.to_dict()
        assert {"ttft_p50", "ttft_p99", "tok_latency_p50",
                "tok_latency_p99", "throughput_tok_s"} <= set(d)
    # sparse serving really decoded different weights but same scheduler
    assert [o.prompt_len for o in results["dense"][0]] == \
        [o.prompt_len for o in results["sparse"][0]]


# ---------------------------------------------------------------------------
# slot-write semantics: offsets, ring alignment, frozen clocks
# ---------------------------------------------------------------------------


def test_write_slot_leaf_offset_and_ring():
    """Unit contract of the slot cache writer: seq leaves land at
    (offset + position) % S_cache — identity for full-size caches, tail
    kept and wrap-aligned for ring (sliding-window) caches — and state
    leaves are overwritten wholesale."""
    from repro.models.transformer import _write_slot_leaf

    src = jnp.arange(2 * 1 * 4 * 3, dtype=jnp.float32).reshape(2, 1, 4, 3)
    # full-size cache, nonzero offset: rows offset..offset+3
    dst = jnp.zeros((2, 3, 8, 3))
    out = np.asarray(_write_slot_leaf(dst, src, slot=1, offset=2,
                                      is_seq=True))
    np.testing.assert_array_equal(out[:, 1, 2:6], np.asarray(src[:, 0]))
    assert (out[:, 0] == 0).all() and (out[:, 2] == 0).all()
    assert (out[:, 1, :2] == 0).all() and (out[:, 1, 6:] == 0).all()

    # ring cache (S_cache=4) with a 6-long contribution at offset 0: the
    # tail (absolute positions 2..5) lands at rows 2,3,0,1
    src6 = jnp.arange(2 * 1 * 6 * 3, dtype=jnp.float32).reshape(2, 1, 6, 3)
    ring = jnp.full((2, 2, 4, 3), -1.0)
    out = np.asarray(_write_slot_leaf(ring, src6, slot=0, offset=0,
                                      is_seq=True))
    np.testing.assert_array_equal(out[:, 0, 2], np.asarray(src6[:, 0, 2]))
    np.testing.assert_array_equal(out[:, 0, 3], np.asarray(src6[:, 0, 3]))
    np.testing.assert_array_equal(out[:, 0, 0], np.asarray(src6[:, 0, 4]))
    np.testing.assert_array_equal(out[:, 0, 1], np.asarray(src6[:, 0, 5]))
    assert (out[:, 1] == -1.0).all()  # other slot untouched

    # seq leaf whose contribution exactly fills the cache still honors the
    # offset (rotation) — the case a shape-based state/seq test would
    # silently misplace
    full = jnp.zeros((2, 2, 4, 3))
    out = np.asarray(_write_slot_leaf(full, src, slot=0, offset=1,
                                      is_seq=True))
    np.testing.assert_array_equal(out[:, 0, 1:], np.asarray(src[:, 0, :3]))
    np.testing.assert_array_equal(out[:, 0, 0], np.asarray(src[:, 0, 3]))

    # state leaf (no extra seq axis in dst): wholesale overwrite at slot
    state = jnp.zeros((2, 3, 4, 3))
    out = np.asarray(_write_slot_leaf(state, src, slot=2, offset=0,
                                      is_seq=False))
    np.testing.assert_array_equal(out[:, 2], np.asarray(src[:, 0]))
    assert (out[:, :2] == 0).all()

    # the structural classifier distinguishes seq from state leaves
    from repro.configs import get_smoke
    from repro.models.transformer import _seq_leaf_kinds

    kinds = _seq_leaf_kinds(get_smoke("hymba-1.5b"), 0)
    flat = jax.tree_util.tree_flatten_with_path(kinds)[0]
    by_name = {path[-1].key: v for path, v in flat}
    assert by_name["k"] is True and by_name["v"] is True
    assert by_name["conv"] is False and by_name["ssm"] is False


def test_engine_ring_cache_window_model():
    """alt_local_global (ring local caches): the engine matches the
    one-shot loop when the ring alignment assumption holds, and matches
    the from-scratch parallel forward even when the prompt is longer than
    the window (where slot admission must wrap-align its writes)."""
    cfg = dataclasses.replace(get_smoke("gemma2-9b"), dtype="float32")
    params = init_lm(jax.random.PRNGKey(1), cfg)
    W = cfg.local_window

    # prompt shorter than the window: plain equivalence vs one-shot
    prompt = make_prompt(W - 4, seed=21, vocab=cfg.vocab)
    want = oneshot_greedy(params, cfg, prompt, gen_len=4)
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=W + 4)
    outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
    assert outs[0].tokens == want

    # prompt 1.5x the window: ground truth is greedy re-decode with the
    # full parallel forward (no cache at all)
    from repro.models import forward, logits_of

    long_prompt = make_prompt(W + W // 2, seed=22, vocab=cfg.vocab)
    G = 3
    seq = list(long_prompt)
    want = []
    for _ in range(G):
        h, _ = forward(params, cfg, jnp.asarray(seq, jnp.int32)[None],
                       remat="none")
        tok = int(jnp.argmax(logits_of(params, cfg, h[:, -1:])[:, 0], -1)[0])
        want.append(tok)
        seq.append(tok)
    eng = ServeEngine(params, cfg, max_slots=2,
                      max_seq_len=len(long_prompt) + G)
    outs = eng.run([Request(uid=0, prompt=long_prompt, max_new_tokens=G)])
    assert outs[0].tokens == want


# ---------------------------------------------------------------------------
# paged engine: admission under page pressure, preemption, rejection
# ---------------------------------------------------------------------------


def test_out_of_pages_admission_queues_not_corrupts(setup):
    """A request that cannot get pages at admission is deferred (returned
    to the queue head) and served later — the requests already decoding in
    live slots produce exactly their unconstrained outputs."""
    cfg, params = setup
    reqs = lambda: [  # noqa: E731 — the slot-isolation trace, reused
        Request(uid=0, prompt=make_prompt(10, seed=3, vocab=cfg.vocab),
                max_new_tokens=5),
        Request(uid=10, prompt=make_prompt(12, seed=100, vocab=cfg.vocab),
                max_new_tokens=7),
        Request(uid=11, prompt=make_prompt(11, seed=101, vocab=cfg.vocab),
                max_new_tokens=7),
        Request(uid=12, prompt=make_prompt(13, seed=102, vocab=cfg.vocab),
                max_new_tokens=7),
    ]
    want = [(o.uid, o.tokens) for o in
            ServeEngine(params, cfg, max_slots=4, max_seq_len=20).run(reqs())]
    # 10 pages of 4 tokens: two ~4-page requests fit, the rest must defer
    eng = ServeEngine(params, cfg, max_slots=4, max_seq_len=20,
                      paged=True, page_size=4, num_pages=10,
                      prefix_sharing=False)
    got = [(o.uid, o.tokens) for o in eng.run(reqs())]
    assert got == want
    assert eng.stats["deferred_admissions"] > 0
    assert eng.stats["rejected"] == 0
    assert eng.kv.alloc.pages_in_use() == 0  # fully drained


def test_mid_stream_eviction_under_paging(setup):
    """Decode-time page exhaustion (prompts fit, growth does not) preempts
    the youngest slot, whose request is re-served from scratch — outputs
    still match the slot engine exactly."""
    cfg, params = setup
    reqs = lambda: [  # noqa: E731
        Request(uid=i, prompt=make_prompt(7 + i, seed=200 + i,
                                          vocab=cfg.vocab),
                max_new_tokens=9) for i in range(3)
    ]
    want = [(o.uid, o.tokens) for o in
            ServeEngine(params, cfg, max_slots=3, max_seq_len=20,
                        decode_chunk=4).run(reqs())]
    # 8 pages * 3 tokens = 24 token-rows: three 7-9 token prompts admit,
    # but 9 generated tokens each cannot all fit -> mid-stream preemption
    eng = ServeEngine(params, cfg, max_slots=3, max_seq_len=21,
                      decode_chunk=4, paged=True, page_size=3, num_pages=8,
                      prefix_sharing=False)
    got = [(o.uid, o.tokens) for o in eng.run(reqs())]
    assert got == want
    assert eng.stats["preemptions"] > 0
    assert eng.kv.alloc.pages_in_use() == 0


def test_too_long_prompt_rejected_not_fatal(setup):
    """Regression for the admission assert: an over-capacity prompt is
    rejected with finish_reason='rejected' while the serve loop keeps
    running and every well-formed request completes normally — for both
    cache backends."""
    cfg, params = setup
    for kw in ({}, {"paged": True, "page_size": 4}):
        eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=12, **kw)
        outs = eng.run([
            Request(uid=0, prompt=make_prompt(6, seed=1, vocab=cfg.vocab),
                    max_new_tokens=3),
            Request(uid=1, prompt=make_prompt(30, seed=2, vocab=cfg.vocab),
                    max_new_tokens=3),
            Request(uid=2, prompt=make_prompt(7, seed=3, vocab=cfg.vocab),
                    max_new_tokens=3),
        ])
        by_uid = {o.uid: o for o in outs}
        assert by_uid[1].finish_reason == "rejected"
        assert by_uid[1].tokens == []
        assert len(by_uid[0].tokens) == len(by_uid[2].tokens) == 3
        met = eng.metrics()
        assert met.num_rejected == 1 and met.num_requests == 2
        assert np.isfinite(met.ttft_p50)


def test_frozen_clock_does_not_hang():
    """An injected clock that never advances must not hang run(): the
    engine warps virtual time to the next arrival instead of sleeping
    forever."""
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    eng = ServeEngine(params, cfg, max_slots=1, max_seq_len=10,
                      clock=lambda: 0.0)
    outs = eng.run([Request(uid=0, prompt=make_prompt(6, seed=1,
                                                      vocab=cfg.vocab),
                            max_new_tokens=2, arrival_time=5.0)])
    assert len(outs) == 1 and len(outs[0].tokens) == 2

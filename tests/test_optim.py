"""Optimizer + sparse-aware update + GMP schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layouts import FixedMaskTensor, GroupedNMTensor
from repro.core.sparsifiers import ScalarFractionSparsifier, apply_sparsifier
from repro.optim import (
    AdamWConfig,
    GMPSchedule,
    adamw_init,
    adamw_update,
    gmp_sparsity,
    value_and_grad_sparse,
)
from repro.optim.sparse_update import resparsify_params, sparse_aware_update

KEY = jax.random.PRNGKey(0)


def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    vg = value_and_grad_sparse(lambda p: jnp.sum(p["w"] ** 2))
    for _ in range(200):
        _, g = vg(params)
        params, state, _ = adamw_update(g, state, params, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip():
    params = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, m = adamw_update(g, state, params, AdamWConfig(grad_clip=1.0))
    assert float(m["gnorm"]) == pytest.approx(200.0)


def test_sparse_param_training_preserves_mask():
    """Masked sparse training: pruned entries stay zero through updates
    (SameFormatSparsifier after each step, paper Fig 2)."""
    x = jax.random.normal(KEY, (8, 8))
    w = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    params = {"w": w}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    target = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
    vg = value_and_grad_sparse(
        lambda p: jnp.sum((p["w"].to_dense() - target) ** 2))
    mask0 = np.asarray(w.mask)
    for _ in range(10):
        _, g = vg(params)
        params, state, _ = sparse_aware_update(
            lambda g_, s_, p_: adamw_update(g_, s_, p_, cfg),
            g, state, params,
        )
    d = np.asarray(params["w"].to_dense())
    assert np.array_equal(np.asarray(params["w"].mask), mask0)
    assert (d[~mask0] == 0).all()
    # and it actually learned on the kept entries
    err = np.abs(d - np.asarray(target))[mask0].mean()
    err0 = np.abs(np.asarray(x) - np.asarray(target))[mask0].mean()
    assert err < err0


def test_sparse_aware_update_nmg_param():
    x = jax.random.normal(KEY, (8, 96))
    from repro.core import nmg

    w = nmg.dense_to_grouped_nm(x, 2, 4, 2)
    params = {"w": w}
    state = adamw_init(params)
    vg = value_and_grad_sparse(lambda p: jnp.sum(p["w"].to_dense() ** 2))
    _, g = vg(params)
    new_p, _, _ = sparse_aware_update(
        lambda g_, s_, p_: adamw_update(g_, s_, p_, AdamWConfig(lr=0.1)),
        g, state, params,
    )
    t = new_p["w"]
    assert isinstance(t, GroupedNMTensor)
    assert np.array_equal(np.asarray(t.blk_idx), np.asarray(w.blk_idx))
    # structural invariant survives the update
    d = np.asarray(t.to_dense())
    nnz = (d.reshape(8, -1, 4) != 0).sum(-1)
    assert nnz.max() <= 2


def test_resparsify_recompute_changes_pattern_when_needed():
    x = jnp.asarray([[1.0, 0.0, 0.0, 0.0] * 8] * 4)
    w = FixedMaskTensor(x, x != 0)
    # values move: entry 1 becomes big but masked
    w2 = FixedMaskTensor(w.val.at[:, 1].set(10.0), w.mask)
    out = resparsify_params({"w": w2}, recompute_pattern=True)["w"]
    assert bool(out.mask[0, 1])


def test_gmp_schedules():
    s = GMPSchedule(mode="iterative", target_sparsity=0.8, begin_step=10,
                    end_step=110, recompute_every=20)
    assert gmp_sparsity(s, 0) == 0.0
    assert gmp_sparsity(s, 10) == 0.0
    assert 0 < gmp_sparsity(s, 60) < 0.8
    assert gmp_sparsity(s, 110) == pytest.approx(0.8)
    assert gmp_sparsity(s, 200) == pytest.approx(0.8)
    # cubic ramp is monotone
    vals = [gmp_sparsity(s, t) for t in range(10, 111, 10)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert s.recompute_at(10) and s.recompute_at(30)
    assert not s.recompute_at(31)

    one = GMPSchedule(mode="one_shot", target_sparsity=0.5, begin_step=5)
    assert gmp_sparsity(one, 4) == 0.0 and gmp_sparsity(one, 5) == 0.5
    assert one.recompute_at(5) and not one.recompute_at(6)

    lw = GMPSchedule(mode="layer_wise", begin_step=0, end_step=120,
                     num_layers=12)
    assert lw.layers_pruned_at(0) == 1
    assert lw.layers_pruned_at(119) == 12


def test_moments_skip_integer_leaves():
    x = jax.random.normal(KEY, (8, 8))
    w = apply_sparsifier(ScalarFractionSparsifier(0.5), x, FixedMaskTensor)
    state = adamw_init({"w": w})
    mu_leaves = jax.tree_util.tree_leaves(
        state["mu"], is_leaf=lambda z: z is None)
    assert any(l is None for l in mu_leaves)  # bool mask has no moment

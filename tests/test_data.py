"""Data pipeline: determinism, resume-by-index, elastic reshard."""

import numpy as np

from repro.data import DataConfig, SyntheticLMPipeline


def cfg(**kw):
    base = dict(vocab=1000, seq_len=32, global_batch=8, seed=42)
    base.update(kw)
    return DataConfig(**base)


def test_batch_at_deterministic():
    p1 = SyntheticLMPipeline(cfg())
    p2 = SyntheticLMPipeline(cfg())
    b1, b2 = p1.batch_at(17), p2.batch_at(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_labels_are_next_tokens():
    b = SyntheticLMPipeline(cfg()).batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_steps_differ():
    p = SyntheticLMPipeline(cfg())
    assert not np.array_equal(p.batch_at(0)["tokens"],
                              p.batch_at(1)["tokens"])


def test_shards_partition_batch():
    whole = SyntheticLMPipeline(cfg(num_shards=1, shard_id=0))
    s0 = SyntheticLMPipeline(cfg(num_shards=2, shard_id=0))
    s1 = SyntheticLMPipeline(cfg(num_shards=2, shard_id=1))
    assert s0.batch_at(3)["tokens"].shape[0] == 4
    # shards are distinct streams
    assert not np.array_equal(s0.batch_at(3)["tokens"],
                              s1.batch_at(3)["tokens"])


def test_iterator_prefetch_matches_batch_at():
    p = SyntheticLMPipeline(cfg())
    it = iter(p)
    got = [next(it) for _ in range(3)]
    p.stop()
    ref = SyntheticLMPipeline(cfg())
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"], ref.batch_at(i)["tokens"])


def test_resume_from_step():
    p = SyntheticLMPipeline(cfg(), start_step=100)
    it = iter(p)
    b = next(it)
    p.stop()
    np.testing.assert_array_equal(
        b["tokens"], SyntheticLMPipeline(cfg()).batch_at(100)["tokens"])


def test_reshard_elastic():
    p = SyntheticLMPipeline(cfg(num_shards=2, shard_id=0), start_step=50)
    q = p.reshard(num_shards=4, shard_id=3)
    assert q.cfg.num_shards == 4 and q.cfg.shard_id == 3
    assert q.step == 50
    assert q.batch_at(50)["tokens"].shape[0] == 2


def test_vocab_bounds():
    b = SyntheticLMPipeline(cfg(vocab=100)).batch_at(0)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100

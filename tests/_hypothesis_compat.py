"""Optional-dependency guard for hypothesis (test-only dep, see
pyproject.toml).

``hypothesis`` drives the property-based tests in test_layouts.py and
test_sparsifiers.py but may be absent from minimal environments.  Importing
``given``/``settings``/``st`` from here instead of from hypothesis directly
keeps collection from hard-failing: when the real package is missing, the
stand-ins mark each property test as skipped while every plain test in the
same module still runs (a module-level ``pytest.importorskip`` would drop
those too).
"""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """st.<anything>(...) placeholder; never executed, only decorates."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed"
        )(fn)

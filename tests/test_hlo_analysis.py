"""The structural HLO analyzer: known-count programs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, roofline_terms


def compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    r = analyze_hlo(compile_text(lambda x, y: x @ y, a, b))
    assert r["flops"] == 2 * 64 * 128 * 32


def test_scan_multiplies_by_trip_count():
    w = jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def f(w, x):
        def body(c, wi):
            return c @ wi, ()
        out, _ = jax.lax.scan(body, x, w)
        return out

    r = analyze_hlo(compile_text(f, w, x))
    assert r["flops"] == 10 * 2 * 4 * 32 * 32
    assert r["max_trip"] == 10 and r["num_whiles"] == 1


def test_nested_scans_compose():
    w = jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 16), jnp.float32)

    def f(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return ci @ wi, ()
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, ()
        out, _ = jax.lax.scan(outer, x, w)
        return out

    r = analyze_hlo(compile_text(f, w, x))
    assert r["flops"] == 3 * 5 * 2 * 2 * 16 * 16
    assert r["num_whiles"] == 2


def test_gather_not_charged_table():
    table = jax.ShapeDtypeStruct((100000, 64), jnp.float32)
    idx = jax.ShapeDtypeStruct((8,), jnp.int32)
    r = analyze_hlo(compile_text(lambda t, i: jnp.take(t, i, axis=0),
                                 table, idx))
    # bytes must be ~ gathered rows, not the 25 MB table
    assert r["bytes"] < 1e5, r["bytes"]


def test_roofline_dominance():
    t = roofline_terms(197e12, 100e9, 1e9)   # 1s compute, 0.12s mem
    assert t.dominant == "compute"
    t = roofline_terms(1e12, 819e9, 1e9)
    assert t.dominant == "memory"
    t = roofline_terms(1e12, 1e9, 500e9)
    assert t.dominant == "collective"
    assert t.bound_s == pytest.approx(10.0)

"""Layout round-trips and invariants (unit + hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import nmg
from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    all_layouts,
    nm_patterns,
)

KEY = jax.random.PRNGKey(0)


def rand(shape, key=KEY):
    return jax.random.normal(key, shape)


# ---------------------------------------------------------------------------
# exact round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 8), (16, 48), (7, 13), (1, 5)])
def test_csr_roundtrip(shape):
    x = rand(shape)
    np.testing.assert_allclose(CsrTensor.from_dense(x).to_dense(), x,
                               rtol=1e-6)


@pytest.mark.parametrize("shape", [(4, 8), (3, 5, 7), (16,)])
def test_coo_roundtrip(shape):
    x = rand(shape)
    np.testing.assert_allclose(CooTensor.from_dense(x).to_dense(), x,
                               rtol=1e-6)


def test_fixed_mask_roundtrip():
    x = rand((8, 16))
    t = FixedMaskTensor.from_dense(x)
    np.testing.assert_allclose(t.to_dense(), x, rtol=1e-6)


@given(
    rows=st.integers(1, 12),
    cols=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_csr_roundtrip_property(rows, cols, seed):
    x = np.random.default_rng(seed).normal(size=(rows, cols)).astype(
        np.float32)
    x[np.abs(x) < 0.5] = 0  # induce genuine sparsity
    got = np.asarray(CsrTensor.from_dense(jnp.asarray(x)).to_dense())
    np.testing.assert_allclose(got, x, rtol=1e-6)


# ---------------------------------------------------------------------------
# n:m and n:m:g structural invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,m", [(1, 4), (2, 4), (1, 2), (3, 6)])
def test_nm_block_invariant(n, m):
    x = rand((8, 48))
    d = np.asarray(NMTensor.from_dense(x, n, m).to_dense())
    k_pad = -(-48 // m) * m
    dp = np.pad(d, ((0, 0), (0, k_pad - 48)))
    nnz = (dp.reshape(8, -1, m) != 0).sum(-1)
    assert nnz.max() <= n


@pytest.mark.parametrize("n,m,g,gr", [(2, 4, 1, 1), (2, 4, 4, 1),
                                      (1, 4, 4, 2), (3, 6, 2, 1)])
def test_nmg_block_invariant(n, m, g, gr):
    x = rand((8, 96))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr)
    d = np.asarray(t.to_dense())
    assert d.shape == (8, 96)
    nnz = (d.reshape(8, -1, m) != 0).sum(-1)
    assert nnz.max() <= n
    # kept values must equal the originals at kept positions
    mask = d != 0
    np.testing.assert_allclose(d[mask], np.asarray(x)[mask], rtol=1e-6)


def test_nmg_pattern_capacity():
    """Within a chunk each pattern appears exactly g times (paper §5)."""
    n, m, g = 2, 4, 3
    import math

    C = math.comb(m, n)
    x = rand((4, m * C * g * 2))
    t = nmg.dense_to_grouped_nm(x, n=n, m=m, g=g)
    pats = nm_patterns(n, m)
    d = np.asarray(t.to_dense()).reshape(4, -1, m)
    # reconstruct each block's pattern and count per chunk
    for r in range(4):
        for c in range(2):
            counts = {}
            for b in range(C * g):
                blk = d[r, c * C * g + b]
                pat = tuple(np.nonzero(blk)[0])
                # subset of some full pattern (ties/zeros can reduce nnz)
                counts[pat] = counts.get(pat, 0) + 1
            assert sum(counts.values()) == C * g


def test_revolving_door_order():
    """Adjacent patterns differ in exactly one position (paper §5.1)."""
    for n, m in [(1, 4), (2, 4), (2, 5), (3, 6)]:
        pats = nm_patterns(n, m)
        for a, b in zip(pats[:-1], pats[1:]):
            assert len(set(a) ^ set(b)) == 2, (n, m, a, b)


def test_nmg_transposed_orientation():
    x = rand((96, 8))
    t = nmg.dense_to_grouped_nm(x, n=2, m=4, g=2, sparse_dim=0)
    d = np.asarray(t.to_dense())
    assert d.shape == (96, 8)
    nnz = (d.T.reshape(8, -1, 4) != 0).sum(-1)
    assert nnz.max() <= 2


def test_layouts_are_pytrees():
    x = rand((8, 16))
    for t in [CsrTensor.from_dense(x), CooTensor.from_dense(x),
              FixedMaskTensor.from_dense(x), NMTensor.from_dense(x, 2, 4),
              nmg.dense_to_grouped_nm(x, 2, 4, 2)]:
        leaves, treedef = jax.tree_util.tree_flatten(t)
        t2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_allclose(t2.to_dense(), t.to_dense())
        # and jit-traceable
        f = jax.jit(lambda z: z.to_dense().sum())
        f(t)


def test_registry_contains_builtins():
    names = set(all_layouts())
    assert {"DenseTensor", "CsrTensor", "CooTensor", "FixedMaskTensor",
            "NMTensor", "GroupedNMTensor"} <= names

"""MoE dispatch correctness: capacity semantics, gate weighting, dense
residual, and pjit-vs-shard_map equivalence (subprocess, 8 devices)."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import moe as moe_mod

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
KEY = jax.random.PRNGKey(0)


def test_moe_output_is_gate_weighted_expert_mix():
    cfg = get_smoke("moonshot-v1-16b-a3b")
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          cfg.jdtype)
    y, aux = moe_mod.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert float(aux) > 0.5  # load-balance loss is ~E * sum(me*ce) >= 1-ish


def test_moe_capacity_drops_tokens_gracefully():
    import dataclasses

    cfg = get_smoke("moonshot-v1-16b-a3b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.05))
    p = moe_mod.init_moe(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          cfg.jdtype)
    y, _ = moe_mod.apply_moe(p, x, cfg)  # most tokens dropped -> ~0 outputs
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_dense_residual_branch():
    cfg = get_smoke("arctic-480b")
    p = moe_mod.init_moe(KEY, cfg)
    assert "res_wi" in p and "res_wo" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          cfg.jdtype)
    y, _ = moe_mod.apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(y, np.float32)).all()


def test_shmap_matches_pjit_8dev():
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.dist.sharding import ShardingRules, use_rules
        from repro.launch.mesh import make_host_mesh
        from repro.models import moe as moe_mod

        cfg = get_smoke("moonshot-v1-16b-a3b")
        mesh = make_host_mesh(2, 4)
        p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              cfg.jdtype)
        with mesh, use_rules(mesh, ShardingRules()):
            y_ref, _ = jax.jit(lambda p, x: moe_mod.apply_moe(p, x, cfg))(p, x)
            y_sm, _ = jax.jit(
                lambda p, x: moe_mod.apply_moe_shmap(p, x, cfg))(p, x)
        # bf16-appropriate tolerance: shard_map psum vs GSPMD segment-sum
        # reduce in different orders; disagreements are single-ULP
        np.testing.assert_allclose(np.asarray(y_sm, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   rtol=2e-2, atol=0.1)
        print("OK")
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600,
                         env=env)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout

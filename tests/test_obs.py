"""repro.obs tests: flight-recorder ring-buffer properties, Chrome trace
schema/nesting validation, registry semantics (in-place reset, event
emission, the muted bulk-restore path), exporters, the nan-safe metrics
edge cases, and the engine-level guarantees the observability PR ships
on: tracing changes no tokens, and a warm engine records no new JIT
traces with the recorder on.
"""

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_lm
from repro.obs import trace as obs
from repro.obs.export import (
    phase_breakdown,
    prometheus_text,
    to_chrome_trace,
    to_jsonl,
    validate_chrome_trace,
)
from repro.obs.registry import (
    REGISTRY,
    CounterFamily,
    MirroredCounters,
    snapshot_diff,
)
from repro.serve import Request, ServeEngine, summarize, trace_events
from repro.statutil import fmt, pct

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# flight recorder: bounded ring buffer
# ---------------------------------------------------------------------------


def test_disabled_mode_records_nothing():
    """Off by default (the conftest fixture pins that): events vanish and
    span() hands back the shared no-op singleton — the zero-allocation
    fast path."""
    assert not obs.enabled()
    obs.event("x", "engine", k=1)
    with obs.span("s", "engine"):
        pass
    obs.complete("c", 0.0, 1.0)
    assert obs.records() == [] and obs.dropped() == 0
    assert obs.span("a") is obs.span("b")


def test_ring_buffer_bounded_overwrites_oldest():
    obs.enable(capacity=8)
    for i in range(20):
        obs.event(f"e{i}", "engine", i=i)
    recs = obs.records()
    assert len(recs) == 8 == obs.capacity()
    assert [r[1] for r in recs] == [f"e{i}" for i in range(12, 20)]
    assert obs.dropped() == 12


def test_span_records_complete_event_with_error_attr():
    obs.enable()
    with pytest.raises(ValueError):
        with obs.span("boom", "engine", k=3):
            raise ValueError("x")
    (ph, name, track, ts, dur, attrs), = obs.records()
    assert (ph, name, track) == ("X", "boom", "engine")
    assert ts >= 0 and dur >= 0
    assert attrs == {"k": 3, "error": "ValueError"}


def test_disable_mid_span_drops_the_record():
    obs.enable()
    with obs.span("torn", "engine"):
        obs.disable()
    assert obs.records() == []


def test_reenable_keeps_epoch_timestamps_monotonic():
    """A disable/enable cycle with held records (the fig11 overhead probe
    toggling tracing mid-run) must stay on one monotonic timeline."""
    obs.enable()
    obs.event("a", "engine")
    obs.disable()
    obs.enable()
    obs.event("b", "engine")
    ts = [r[3] for r in obs.records()]
    assert len(ts) == 2 and ts == sorted(ts)


# ---------------------------------------------------------------------------
# Chrome trace export + schema validation
# ---------------------------------------------------------------------------


def _chrome_doc():
    obs.enable()
    with obs.span("outer", "engine", a=1):
        with obs.span("inner", "engine"):
            pass
    obs.event("mark", "controller", tier="dense")
    return to_chrome_trace(obs.records(), registry_snapshot={"x": 1},
                           dropped=3)


def test_chrome_trace_schema():
    doc = _chrome_doc()
    assert validate_chrome_trace(doc) == []
    assert doc["metadata"] == {"tool": "repro.obs", "dropped_records": 3,
                               "registry": {"x": 1}}
    for ev in doc["traceEvents"]:
        assert {"ph", "ts", "pid", "tid", "name"} <= set(ev)
        assert ev["pid"] == 1
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert {e["args"]["name"] for e in meta} >= {"engine", "controller"}
    # tracks map to distinct thread rows
    by_name = {e["name"]: e for e in doc["traceEvents"] if e["ph"] != "M"}
    assert by_name["outer"]["tid"] != by_name["mark"]["tid"]
    assert by_name["mark"]["s"] == "t"
    json.dumps(doc)  # JSON-serializable end to end


def test_chrome_trace_spans_nest_properly():
    doc = _chrome_doc()
    spans = {e["name"]: e for e in doc["traceEvents"] if e["ph"] == "X"}
    o, i = spans["outer"], spans["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"]


def test_validator_flags_partial_overlap_and_missing_fields():
    bad = to_chrome_trace([("X", "a", "engine", 0, 100, None),
                           ("X", "b", "engine", 50, 100, None)])
    assert any("partially overlaps" in p for p in validate_chrome_trace(bad))
    assert validate_chrome_trace({"traceEvents": [{"ph": "X", "ts": 0}]})
    assert validate_chrome_trace({}) == ["traceEvents missing or not a list"]


def test_jsonl_and_phase_breakdown():
    obs.enable()
    with obs.span("work", "engine"):
        pass
    obs.event("mark", "engine")
    lines = [json.loads(ln) for ln in to_jsonl(obs.records()).splitlines()]
    assert [ln["name"] for ln in lines] == ["work", "mark"]
    assert "dur_us" in lines[0] and "dur_us" not in lines[1]
    pb = phase_breakdown(obs.records())
    assert list(pb) == ["work"] and pb["work"]["count"] == 1


# ---------------------------------------------------------------------------
# telemetry registry
# ---------------------------------------------------------------------------


def test_registry_constructors_idempotent_and_typed():
    c = REGISTRY.counter("obs_test_c")
    assert REGISTRY.counter("obs_test_c") is c
    with pytest.raises(TypeError):
        REGISTRY.gauge("obs_test_c")


def test_family_emits_timeline_events_only_on_increase():
    fam = REGISTRY.family("obs_test_fam", trace_as="probe", track="registry")
    fam[("a", "b")] += 1  # recorder off: counted, not recorded
    obs.enable()
    fam[("a", "b")] += 2
    recs = obs.records()
    assert len(recs) == 1
    assert recs[0][1] == "probe" and recs[0][5] == {"key": "a/b", "n": 2}
    # bulk restore (predict_route's snapshot/restore dance) stays silent
    snap = fam.copy()
    assert type(snap) is not CounterFamily
    fam.clear()
    fam.update(snap)
    assert len(obs.records()) == 1
    assert fam[("a", "b")] == 3


def test_mirrored_counters_reads_like_a_dict():
    fam = REGISTRY.family("obs_test_mirror")
    stats = MirroredCounters({"served": 0, "label": "x"}, fam)
    stats["served"] += 2
    stats["served"] += 1
    stats["label"] = "y"  # non-numeric writes pass through unmirrored
    assert dict(stats) == {"served": 3, "label": "y"}
    assert fam["served"] == 3 and "label" not in fam


def test_histogram_snapshot_cumulative_and_prometheus():
    h = REGISTRY.histogram("obs_test_hist", buckets=(0.001, 0.01))
    for v in (0.0005, 0.005, 5.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["buckets"] == {"0.001": 1, "0.01": 2, "+Inf": 3}
    assert snap["count"] == 3
    txt = prometheus_text(REGISTRY.snapshot())
    assert 'repro_obs_test_hist_bucket{le="0.001"} 1' in txt
    assert "repro_obs_test_hist_count 3" in txt


def test_registry_reset_in_place_and_snapshot_diff():
    fam = REGISTRY.family("obs_test_diff")
    before = REGISTRY.snapshot()
    fam["k"] += 2
    REGISTRY.gauge("obs_test_g").set(1.5)
    d = snapshot_diff(before, REGISTRY.snapshot())
    assert d["obs_test_diff"] == {"k": 2} and d["obs_test_g"] == 1.5
    REGISTRY.reset()
    assert REGISTRY.family("obs_test_diff") is fam and len(fam) == 0


# ---------------------------------------------------------------------------
# metrics edge cases (satellite: nan-safe summarize/report)
# ---------------------------------------------------------------------------


def test_summarize_zero_wall_time_is_nan_not_inf():
    met = summarize([], 0.0, label="empty")
    assert met.num_requests == 0
    assert np.isnan(met.throughput_tok_s)
    assert np.isnan(met.ttft_p50) and np.isnan(met.tok_latency_p99)
    # and report() renders every nan as "--" instead of raising
    rep = met.report()
    assert "--" in rep and "nan" not in rep


def test_statutil_helpers():
    assert np.isnan(pct([], 99))
    assert pct([1.0, 2.0, 3.0], 50) == 2.0
    assert fmt(float("nan")) == "--"
    assert fmt(0.0123, 1e3, 2) == "12.30"


# ---------------------------------------------------------------------------
# engine-level guarantees (token equivalence, no retrace with recorder on)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    yield cfg, params
    from repro.serve import cache as _cache, engine as _engine
    for mod in (_cache, _engine):
        for fn in vars(mod).values():
            clear = getattr(fn, "cache_clear", None)
            if clear is not None:
                clear()
    jax.clear_caches()


def _reqs(cfg, n=3, plen=8, gen=6):
    return [Request(uid=u, max_new_tokens=gen,
                    prompt=np.asarray(jax.random.randint(
                        jax.random.PRNGKey(u), (plen,), 0, cfg.vocab,
                        jnp.int32)))
            for u in range(n)]


def test_tracing_changes_no_tokens_and_emits_lifecycle_spans(setup):
    cfg, params = setup
    ekw = dict(max_slots=2, max_seq_len=24, decode_chunk=4)
    off = ServeEngine(params, cfg, **ekw).run(_reqs(cfg))
    assert obs.records() == []  # recorder off: the run left no trace
    obs.enable()
    on = ServeEngine(params, cfg, **ekw).run(_reqs(cfg))
    assert [o.tokens for o in on] == [o.tokens for o in off]
    names = {r[1] for r in obs.records()}
    assert {"queued", "prefill", "finish"} <= names
    assert "decode_chunk" in names or "decode_step" in names
    # every request got its own track row, and the export validates
    tracks = {r[2] for r in obs.records()}
    assert {f"req:{u}" for u in range(3)} <= tracks
    doc = to_chrome_trace(obs.records())
    assert validate_chrome_trace(doc) == []


def test_warm_engine_records_no_new_jit_traces_with_recorder_on(setup):
    """Recompile safety: with the flight recorder enabled, serving and
    tier switches on a warmed engine add no ``trace_events`` — tracing is
    host-side and must never perturb the JIT caches."""
    cfg, params = setup
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=24,
                      decode_chunk=4, tiers=["dense", "1:4:8-gr64"])
    eng.warm_tiers(prompt_lens=(8,))
    obs.enable()
    before = dict(trace_events())
    eng.run(_reqs(cfg))
    eng.set_tier(1)
    eng.run(_reqs(cfg))
    assert trace_events() == before
    assert not [r for r in obs.records() if r[1] == "jit_trace"]
    switches = [r for r in obs.records() if r[1] == "tier_switch"]
    assert switches and switches[-1][5]["tier_to"] == "1:4:8-gr64"

"""`repro.tune` subsystem tests.

The load-bearing guarantees:

* **Defaults-compat** — with no active table every routing answer equals
  the historical hard-coded heuristic (``DECODE_M_MAX = 16``,
  ``_SPMM_BLOCK_ELEMS = 1 << 22``, Pallas tile defaults), so behavior
  without a cache is exactly the seed behavior.
* **Bitwise differential** — a table may only change *which* kernel runs:
  over a (M, K, N, n:m:g, gr, dtype) grid, outputs under route-flipping
  tables are bitwise-equal to the heuristic outputs, on both the
  ``nmg_matmul`` and ``nmg_linear`` entry points, and for every spmm
  block size.
* **Plumbing** — table persistence/device sectioning, counter provenance
  (``[table]`` vs ``[default]``), the CLI, the dispatcher's
  conversion-cost tie-breaker, and the serving warmup hook.
"""

import dataclasses
import importlib
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.kernels import ops as kops
from repro.tune import (
    TuningTable,
    bucket,
    routing,
    shape_key,
)
from repro.tune import bench as tbench

disp = importlib.import_module("repro.core.dispatch")

KEY = jax.random.PRNGKey(11)


def _tensor(R, K, fmt, gr, *, sparse_dim=1):
    n, m, g = fmt
    x = jax.random.normal(KEY, (R, K) if sparse_dim == 1 else (K, R))
    return nmg.dense_to_grouped_nm(x, n=n, m=m, g=g, gr=gr,
                                   sparse_dim=sparse_dim)


def _flip_table(t, dtype, value):
    """A table that pins this tensor's decode_m_max bucket to ``value``."""
    tab = TuningTable.for_device()
    sd = t.sparse_dim % 2
    tab.put(shape_key("decode_m_max", K=t.dense_shape[sd],
                      R=t.dense_shape[1 - sd], fmt=(t.n, t.m, t.g),
                      gr=t.gr, dtype=dtype), value)
    return tab


# ---------------------------------------------------------------------------
# defaults-compat: no table => seed heuristics, exactly
# ---------------------------------------------------------------------------


def test_no_table_reproduces_shipped_heuristics():
    assert routing.active_table() is None
    thr, src = routing.decode_m_max(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert (thr, src) == (routing.DEFAULT_DECODE_M_MAX, "default")
    assert thr == kops.DECODE_M_MAX == 16
    blk, src = routing.spmm_block_elems()
    assert (blk, src) == (routing.DEFAULT_SPMM_BLOCK_ELEMS, "default")
    assert blk == kops._SPMM_BLOCK_ELEMS == 1 << 22
    cfg, src = routing.gemv_pallas_config(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                          dtype=jnp.float32)
    assert (cfg, src) == (routing.DEFAULT_GEMV_PALLAS, "default")
    assert disp.conversion_cost_model() is None


def test_no_table_router_boundary_matches_constant():
    """The router's decode/prefill boundary without a cache sits exactly at
    the historical DECODE_M_MAX."""
    t = _tensor(8, 96, (1, 4, 4), 2)
    kops.nmg_matmul(t, jnp.ones((96, kops.DECODE_M_MAX)), use_pallas=False)
    kops.nmg_matmul(t, jnp.ones((96, kops.DECODE_M_MAX + 1)),
                    use_pallas=False)
    counts = kops.kernel_counters()
    assert counts.get(("nmg_matmul", "gemv[default]")) == 1
    assert counts.get(("nmg_matmul", "spmm[default]")) == 1
    assert counts.get(("nmg_gemv", "xla")) == 1
    assert counts.get(("nmg_spmm", "xla")) == 1


# ---------------------------------------------------------------------------
# bitwise differential: tuned routing == heuristic routing, to the bit
# ---------------------------------------------------------------------------

FMT_GRID = [(1, 4, 4, 2), (2, 4, 2, 4), (2, 4, 16, 8), (3, 6, 1, 2)]
SHAPE_GRID = [(16, 192), (5, 100)]
M_GRID = (1, 4, 16, 17, 64)


@pytest.mark.parametrize("fmt", FMT_GRID,
                         ids=lambda f: "{}:{}:{}gr{}".format(*f))
@pytest.mark.parametrize("shape", SHAPE_GRID,
                         ids=lambda s: "x".join(map(str, s)))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_tuned_matmul_bitwise_equals_heuristic(fmt, shape, dtype):
    """Force the route the heuristic would NOT take at every M in the grid:
    the result must not change by a single bit."""
    n, m, g, gr = fmt
    R, K = shape
    t = _tensor(R, K, (n, m, g), gr)
    for M in M_GRID:
        b = jax.random.normal(jax.random.fold_in(KEY, M), (K, M)
                              ).astype(dtype)
        routing.clear_active_table()
        want = np.asarray(kops.nmg_matmul(t, b, use_pallas=False))
        # flip: everything to spmm, then everything to gemv
        for forced in (0, 4096):
            routing.set_active_table(_flip_table(t, dtype, forced))
            got = np.asarray(kops.nmg_matmul(t, b, use_pallas=False))
            np.testing.assert_array_equal(got, want)
    routing.clear_active_table()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["f32", "bf16"])
def test_tuned_linear_bitwise_equals_heuristic(dtype):
    """Same guarantee on the serving entry point (weight sparse along its
    input axis, dtype-preserving epilogue vs cast-then-transpose)."""
    w = _tensor(512, 192, (1, 4, 8), 16, sparse_dim=0)
    for rows in (1, 4, 16, 17, 64):
        x = jax.random.normal(jax.random.fold_in(KEY, rows), (rows, 192)
                              ).astype(dtype)
        routing.clear_active_table()
        want = np.asarray(kops.nmg_linear(x, w, use_pallas=False))
        for forced in (0, 4096):
            routing.set_active_table(_flip_table(w, dtype, forced))
            got = np.asarray(kops.nmg_linear(x, w, use_pallas=False))
            assert got.dtype == want.dtype == dtype
            np.testing.assert_array_equal(got, want)
    routing.clear_active_table()


def test_tuned_spmm_block_bitwise_equals_default():
    """The spmm gathered-block cap is a pure scheduling knob: every block
    size (including degenerate 1-element blocks) produces the default
    result to the bit."""
    t = _tensor(16, 192, (2, 4, 2), 4)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (192, 64))
    want = np.asarray(kops.nmg_spmm_xla(t, b, block_elems=1 << 22))
    for blk in (1, 1 << 10, 1 << 14, 1 << 26):
        np.testing.assert_array_equal(
            np.asarray(kops.nmg_spmm_xla(t, b, block_elems=blk)), want)
    # and through the table lookup
    tab = TuningTable.for_device()
    tab.put("spmm_block_elems", 1 << 10)
    routing.set_active_table(tab)
    np.testing.assert_array_equal(np.asarray(kops.nmg_spmm_xla(t, b)), want)


@pytest.mark.pallas_interpret
def test_gemv_pallas_config_sweep_exactness():
    """Pallas gemv tile configs drop or duplicate no values: on
    exact-arithmetic (small-integer) inputs every (tm, target_depth)
    config agrees bit for bit, and on real-valued inputs ``tm`` (pure
    output padding) is still bitwise-neutral while ``target_depth`` — an
    accumulation-chunking knob that reassociates the f32 sum, same caveat
    as pallas-vs-xla — stays within the kernel tolerance."""
    from repro.kernels.nmg_gemv import nmg_gemv_pallas

    rng = np.random.default_rng(0)
    xi = jnp.asarray(rng.integers(-4, 5, size=(8, 96)), jnp.float32)
    ti = nmg.dense_to_grouped_nm(xi, n=1, m=4, g=4, gr=2)
    bi = jnp.asarray(rng.integers(-4, 5, size=(96, 4)), jnp.float32)
    want_i = np.asarray(nmg_gemv_pallas(ti, bi, interpret=True))
    for tm in (8, 64, 128):
        for depth in (4, 64, 256):
            got = np.asarray(nmg_gemv_pallas(ti, bi, tm=tm,
                                             target_depth=depth,
                                             interpret=True))
            np.testing.assert_array_equal(got, want_i)

    t = _tensor(8, 96, (1, 4, 4), 2)
    b = jax.random.normal(jax.random.fold_in(KEY, 2), (96, 4))
    want = np.asarray(nmg_gemv_pallas(t, b, interpret=True))
    for tm in (8, 64):  # output-tile width: padding only, bitwise
        np.testing.assert_array_equal(
            np.asarray(nmg_gemv_pallas(t, b, tm=tm, interpret=True)), want)
    for depth in (4, 256):  # reassociation: tolerance, not bitwise
        np.testing.assert_allclose(
            np.asarray(nmg_gemv_pallas(t, b, target_depth=depth,
                                       interpret=True)),
            want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# table mechanics
# ---------------------------------------------------------------------------


def test_bucketing():
    assert bucket(1) == 1
    assert bucket(2) == 2
    assert bucket(3) == 4
    assert bucket(96) == 128
    assert bucket(1024) == 1024
    assert bucket(1025) == 2048
    k1 = shape_key("decode_m_max", K=1000, R=1024, fmt=(1, 4, 8), gr=64,
                   dtype=jnp.float32)
    k2 = shape_key("decode_m_max", K=1024, R=600, fmt=(1, 4, 8), gr=64,
                   dtype=jnp.float32)
    assert k1 == k2  # both bucket to K1024/R1024


def test_table_save_load_roundtrip(tmp_path):
    path = str(tmp_path / "table.json")
    tab = TuningTable(device="cpu:cpu", entries={"decode_m_max": 24},
                      meta={"note": "test"})
    tab.save(path)
    # another device's section must survive a read-modify-write
    other = TuningTable(device="tpu:tpu_v5e", entries={"decode_m_max": 8})
    other.save(path)
    back = TuningTable.load(path, device="cpu:cpu")
    assert back.entries == {"decode_m_max": 24}
    assert back.meta == {"note": "test"}
    assert TuningTable.load(path, device="tpu:tpu_v5e").entries == {
        "decode_m_max": 8}
    # unknown device: empty section, defaults apply
    empty = TuningTable.load(path, device="gpu:h100")
    assert len(empty) == 0
    routing.set_active_table(empty)
    thr, src = routing.decode_m_max(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert (thr, src) == (kops.DECODE_M_MAX, "default")


def test_table_schema_mismatch_rejected(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"schema": 999, "devices": {}}))
    with pytest.raises(ValueError, match="schema"):
        TuningTable.load(str(path))


def test_device_wide_override_and_bucket_precedence():
    tab = TuningTable.for_device()
    tab.put("decode_m_max", 3)  # device-wide
    routing.set_active_table(tab)
    thr, src = routing.decode_m_max(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert (thr, src) == (3, "table")
    # an exact bucket entry beats the device-wide one
    tab.put(shape_key("decode_m_max", K=96, R=8, fmt=(1, 4, 4), gr=2,
                      dtype=jnp.float32), 9)
    thr, src = routing.decode_m_max(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert (thr, src) == (9, "table")


def test_route_counters_show_table_provenance():
    t = _tensor(8, 96, (1, 4, 4), 2)
    routing.set_active_table(_flip_table(t, jnp.float32, 2))
    kops.nmg_matmul(t, jnp.ones((96, 2)), use_pallas=False)   # <= 2: gemv
    kops.nmg_matmul(t, jnp.ones((96, 8)), use_pallas=False)   # > 2: spmm
    counts = kops.kernel_counters()
    assert counts.get(("nmg_matmul", "gemv[table]")) == 1
    assert counts.get(("nmg_matmul", "spmm[table]")) == 1


# ---------------------------------------------------------------------------
# microbench harness
# ---------------------------------------------------------------------------


def test_measured_crossover():
    def rec(m, g, s):
        return [{"path": "gemv", "M": m, "us": g},
                {"path": "spmm", "M": m, "us": s}]

    recs = (rec(1, 1.0, 2.0)        # gemv wins
            + rec(8, 2.0, 2.01)     # within tolerance: tie counts as win
            + rec(32, 9.0, 3.0)     # first loss
            + rec(64, 9.0, 1.0))    # second consecutive loss: crossover
    assert tbench.measured_crossover(recs) == 8
    # spmm wins twice from the start: gemv never holds the route
    assert tbench.measured_crossover(rec(32, 9.0, 3.0)
                                     + rec(64, 9.0, 1.0)) == 0
    # one noisy loss at the narrow end must not zero the threshold while
    # gemv wins at the widths that follow
    noisy = (rec(1, 3.0, 1.0)       # noise spike
             + rec(4, 1.0, 2.0) + rec(8, 1.0, 2.0)
             + rec(16, 9.0, 3.0) + rec(32, 9.0, 3.0))
    assert tbench.measured_crossover(noisy) == 8
    # a loss closing the sweep still ends the scan
    assert tbench.measured_crossover(rec(1, 1.0, 2.0)
                                     + rec(4, 9.0, 3.0)
                                     + rec(8, 9.0, 3.0)) == 1


def test_tune_decode_threshold_writes_bucketed_entry():
    tab = TuningTable.for_device()
    got = tbench.tune_decode_threshold(tab, K=96, R=16, fmt=(1, 4, 4),
                                       gr=2, ms=(1, 4), reps=1)
    key = shape_key("decode_m_max", K=96, R=16, fmt=(1, 4, 4), gr=2,
                    dtype=jnp.float32)
    assert tab.get(key) == got
    assert got in (0, 1, 4)


def test_cli_quick_produces_consumable_table(tmp_path, monkeypatch):
    """End-to-end: the CLI writes a table whose entries drive the router
    (grids shrunk so the test stays fast; the CI tune-smoke job runs the
    real --quick grid)."""
    from repro.tune import __main__ as cli

    monkeypatch.setattr(cli, "SHAPES_QUICK", ((96, 16),))
    monkeypatch.setattr(cli, "FMTS_QUICK", ((1, 4, 4, 2),))
    monkeypatch.setattr(cli, "MS_QUICK", (1, 4, 8))
    # the real spmm-block probe is deliberately large (it must make the
    # candidate caps compile differently); shrink it for test speed
    real_tune_spmm = tbench.tune_spmm_block
    monkeypatch.setattr(
        cli.bench, "tune_spmm_block",
        lambda table, **kw: real_tune_spmm(
            table, K=96, R=16, N=16, fmt=(1, 4, 4), gr=2,
            candidates=(1 << 10, 1 << 12), reps=1),
    )
    path = str(tmp_path / "tune_table.json")
    assert cli.main(["--quick", "--skip-convert", "--out", path]) == 0

    tab = routing.load_table(path)
    key = shape_key("decode_m_max", K=96, R=16, fmt=(1, 4, 4), gr=2,
                    dtype=jnp.float32)
    assert key in tab
    assert "spmm_block_elems" in tab
    # the loaded table drives the router with "table" provenance
    t = _tensor(16, 96, (1, 4, 4), 2)
    thr, src = routing.decode_m_max(K=96, R=16, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert src == "table" and thr == tab.get(key)
    kops.nmg_matmul(t, jnp.ones((96, 4)), use_pallas=False)
    assert any(k[0] == "nmg_matmul" and k[1].endswith("[table]")
               for k in kops.kernel_counters())


def test_env_var_table_loading(tmp_path, monkeypatch):
    """$REPRO_TUNE_TABLE is honored by the CLI loader when no explicit
    path is given, and an explicit path wins over it."""
    env_path = str(tmp_path / "env_table.json")
    TuningTable(device=routing.TuningTable.for_device().device,
                entries={"decode_m_max": 5}).save(env_path)
    arg_path = str(tmp_path / "arg_table.json")
    TuningTable(device=routing.TuningTable.for_device().device,
                entries={"decode_m_max": 7}).save(arg_path)

    monkeypatch.delenv(routing.ENV_TABLE, raising=False)
    assert routing.load_table_cli(None, verbose=False) is None
    assert routing.active_table() is None

    monkeypatch.setenv(routing.ENV_TABLE, env_path)
    tab = routing.load_table_cli(None, verbose=False)
    assert tab is not None and tab.get("decode_m_max") == 5
    assert routing.active_table() is tab

    tab = routing.load_table_cli(arg_path, verbose=False)
    assert tab.get("decode_m_max") == 7

    # a corrupt or stale-schema env table warns and falls back to defaults
    # instead of crashing unrelated commands (an explicit path still raises)
    bad = tmp_path / "bad.json"
    bad.write_text("{ not json")
    monkeypatch.setenv(routing.ENV_TABLE, str(bad))
    routing.clear_active_table()
    assert routing.load_table_cli(None, verbose=False) is None
    assert routing.active_table() is None
    bad.write_text(json.dumps({"schema": 999, "devices": {}}))
    assert routing.load_table_cli(None, verbose=False) is None
    with pytest.raises(ValueError):
        routing.load_table_cli(str(bad), verbose=False)


# ---------------------------------------------------------------------------
# dispatcher conversion-cost tie-breaker
# ---------------------------------------------------------------------------


def test_dispatch_cost_model_breaks_conversion_ties():
    """Two candidate implementations each one lossless conversion away
    from a FixedMask operand: registration order wins without a cost
    model, the measured-cheaper conversion wins with one, and clearing the
    table restores registration order."""
    from repro.core.layouts import (CooTensor, CsrTensor, DenseTensor,
                                    FixedMaskTensor)

    calls = []

    @disp.register_op_impl("tune_probe_op", inp=(CsrTensor, DenseTensor))
    def _csr_impl(a, b):
        calls.append("csr")
        return jnp.zeros(())

    @disp.register_op_impl("tune_probe_op", inp=(CooTensor, DenseTensor))
    def _coo_impl(a, b):
        calls.append("coo")
        return jnp.zeros(())

    try:
        fm = FixedMaskTensor.from_dense(jnp.eye(4))
        x = jnp.ones((4, 4))
        disp.dispatch("tune_probe_op", fm, x)
        assert calls == ["csr"]  # registration order

        # partial measurement: only the Coo conversion has a cost.  Costs
        # are microseconds — comparing a measured sum against an unknown
        # is unit-nonsense, so the tie stays with registration order.
        tab = TuningTable.for_device()
        tab.put("convert_cost/FixedMaskTensor->CooTensor", 1.0)
        routing.set_active_table(tab)
        calls.clear()
        disp.dispatch("tune_probe_op", fm, x)
        assert calls == ["csr"]
        assert not any(k[0] == "cost_model_override"
                       for k in disp.dispatch_counters())

        tab.put("convert_cost/FixedMaskTensor->CsrTensor", 100.0)
        routing.set_active_table(tab)
        calls.clear()
        disp.dispatch("tune_probe_op", fm, x)
        assert calls == ["coo"]  # fully measured tie: cheaper wins
        assert any(k[0] == "cost_model_override"
                   for k in disp.dispatch_counters())

        routing.clear_active_table()
        calls.clear()
        disp.dispatch("tune_probe_op", fm, x)
        assert calls == ["csr"]
    finally:
        for k in [k for k in disp.sparse_op_table()
                  if k[0] == "tune_probe_op"]:
            del disp._OP_IMPLS[k]


# ---------------------------------------------------------------------------
# serving warmup hook
# ---------------------------------------------------------------------------


def test_warmup_hook_tunes_engine_shapes():
    """The warmup hook tunes the engine's actual weight shapes, activates
    the table, and the traces the warmup triggers route with table
    provenance — and serve the same tokens the default routing serves.

    Routing lookups (and the counters) happen at *trace* time, so the
    default-routing reference runs first at one slot count and the tuned
    engine at another: distinct decode shapes force fresh traces under
    each routing regime (reusing one shape would replay cached
    executables and show nothing).
    """
    from repro.configs import get_smoke
    from repro.models import init_lm
    from repro.serve import Request, ServeEngine
    from repro.serve.engine import sparsify_for_serving, warmup_engine

    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    sparse = sparsify_for_serving(params, n=1, m=4, g=2, gr=4)
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (8,), 0, cfg.vocab, jnp.int32))

    def serve_once(max_slots):
        eng = ServeEngine(sparse, cfg, max_slots=max_slots, max_seq_len=16,
                          decode_chunk=2)
        outs = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=4)])
        assert len(outs) == 1
        return outs[0].tokens

    # reference: default routing at max_slots=2
    assert routing.active_table() is None
    want = serve_once(2)
    routed = [k for k in kops.kernel_counters() if k[0] == "nmg_linear"]
    assert routed and all(k[1].endswith("[default]") for k in routed)

    # tune + warm at max_slots=3 (fresh decode shapes => fresh traces)
    kops.reset_kernel_counters()
    reqs = [Request(uid=9, prompt=prompt, max_new_tokens=4)]
    warmup_engine(sparse, cfg, reqs,
                  engine_kwargs=dict(max_slots=3, max_seq_len=16,
                                     decode_chunk=2),
                  tune=True, tune_reps=1)
    tab = routing.active_table()
    assert tab is not None
    # one decode_m_max entry per distinct sparse-weight shape: the smoke
    # config's wi [64, 128] and wo [128, 64]
    tuned = [k for k in tab.entries if k.startswith("decode_m_max/")]
    assert len(tuned) == 2, tab.entries
    routed = [k for k in kops.kernel_counters() if k[0] == "nmg_linear"]
    assert routed and all(k[1].endswith("[table]") for k in routed), (
        kops.kernel_counters()
    )

    # tuned serving == default-routing serving, token for token
    assert serve_once(3) == want


def test_corrupt_table_load_is_robust(tmp_path):
    """A truncated/corrupt table file must not kill the run: load_table
    warns, records ("table", "load_failed") provenance, leaves the active
    table untouched, and the process continues on shipped defaults."""
    good = tmp_path / "good.json"
    TuningTable(device=TuningTable.for_device().device,
                entries={"decode_m_max": 5}).save(str(good))
    tab = routing.load_table(str(good))
    assert tab is not None and routing.active_table() is tab
    before = routing.table_load_events()

    truncated = tmp_path / "trunc.json"
    truncated.write_text(good.read_text()[: len(good.read_text()) // 2])
    with pytest.warns(RuntimeWarning, match="shipped defaults"):
        assert routing.load_table(str(truncated)) is None
    # the previously-active table survives a failed load
    assert routing.active_table() is tab
    events = routing.table_load_events()
    assert events.get(("table", "load_failed"), 0) == \
        before.get(("table", "load_failed"), 0) + 1

    # routing still answers (from the surviving table)
    thr, src = routing.decode_m_max(K=96, R=8, fmt=(1, 4, 4), gr=2,
                                    dtype=jnp.float32)
    assert (thr, src) == (5, "table")

    # an explicit --tuning-table pointing at the corrupt file is an error
    with pytest.raises(ValueError):
        routing.load_table_cli(str(truncated), verbose=False)

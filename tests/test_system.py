"""End-to-end behaviour tests for the whole system."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_sparse_training_reduces_loss():
    """Masked sparse training on the reduced paper model actually learns."""
    import functools

    from repro.configs import get_smoke
    from repro.core.builder import SparsityBuilder
    from repro.core.layouts import FixedMaskTensor
    from repro.core.sparsifiers import ScalarFractionSparsifier
    from repro.data import DataConfig, SyntheticLMPipeline
    from repro.models import init_lm, loss_fn
    from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                             value_and_grad_sparse)
    from repro.optim.sparse_update import resparsify_params

    cfg = get_smoke("bert-base-sten")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    sb = SparsityBuilder()
    sb.set_weight("*mlp.w*", ScalarFractionSparsifier(0.5), FixedMaskTensor)
    params = sb.sparsify_params(params)
    opt_cfg = AdamWConfig(lr=2e-3)
    state = adamw_init(params)
    data = SyntheticLMPipeline(DataConfig(vocab=cfg.vocab, seq_len=48,
                                          global_batch=8, seed=1))

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(p, s, b):
        (loss, _), g = value_and_grad_sparse(
            lambda q: loss_fn(q, cfg, b, remat="none"), has_aux=True)(p)
        p2, s2, _ = adamw_update(g, s, p, opt_cfg)
        return resparsify_params(p2), s2, loss

    losses = []
    for i in range(25):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        params, state, loss = step(params, state, b)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1
    # masks held through the whole run
    from repro.core.layouts import FixedMaskTensor as FMT

    leaves = [l for l in jax.tree_util.tree_leaves(
        params, is_leaf=lambda x: isinstance(x, FMT))
        if isinstance(l, FMT)]
    assert leaves
    for l in leaves:
        d = np.asarray(l.to_dense())
        m = np.asarray(l.mask)
        assert (d[~m] == 0).all()


def test_serve_cli_dense_and_sparse():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    for extra in ([], ["--sparse"]):
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "bert-base-sten", "--smoke", "--batch", "2", "--prompt-len",
             "16", "--gen-len", "4"] + extra,
            capture_output=True, text=True, env=env, timeout=900)
        assert out.returncode == 0, out.stderr[-2000:]
        assert "ms/token" in out.stdout


def test_examples_quickstart_and_custom_layout():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    root = os.path.join(os.path.dirname(__file__), "..")
    for script in ("examples/quickstart.py", "examples/custom_layout.py"):
        out = subprocess.run([sys.executable, os.path.join(root, script)],
                             capture_output=True, text=True, env=env,
                             timeout=900)
        assert out.returncode == 0, f"{script}: {out.stderr[-2000:]}"


def test_dryrun_cli_smoke_cell():
    """The dry-run driver end-to-end on the cheapest real cell (subprocess:
    it must own the 512-device flag)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "mamba2-370m", "--shape", "decode_32k", "--out",
         "/tmp/dryrun_pytest"],
        capture_output=True, text=True, env=env, timeout=900,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"ok": true' in out.stdout
    assert '"dominant"' in out.stdout

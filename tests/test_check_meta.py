"""Meta-tests keeping the checker honest: the rule registry and the
fixture table stay in lockstep, and every Pallas kernel test carries the
``pallas_interpret`` marker (the dedicated CI job selects on it, so an
unmarked kernel test silently drops out of that job)."""

import ast
import pathlib

from repro.check.fixtures import FIXTURES
from repro.check.rules import all_rules

TESTS_DIR = pathlib.Path(__file__).parent


# ---------------------------------------------------------------------------
# rule <-> fixture lockstep
# ---------------------------------------------------------------------------


def test_every_rule_has_trigger_and_clean_fixture():
    missing = {
        rid for rid in all_rules()
        if rid not in FIXTURES
        or not callable(FIXTURES[rid].get("trigger"))
        or not callable(FIXTURES[rid].get("clean"))
    }
    assert not missing, (
        f"rules without a trigger+clean fixture pair: {sorted(missing)} — "
        f"add them to repro.check.fixtures so the rule cannot land untested"
    )


def test_every_fixture_names_a_registered_rule():
    stale = set(FIXTURES) - set(all_rules())
    assert not stale, f"fixtures for unregistered rules: {sorted(stale)}"


def test_rule_metadata_is_complete():
    for rid, rule in all_rules().items():
        assert rule.rule_id == rid
        assert rule.name and rule.description
        assert rule.detectors, f"{rid} has no detector functions"


# ---------------------------------------------------------------------------
# pallas_interpret marker hygiene
# ---------------------------------------------------------------------------


def _is_pallas_call(node: ast.Call) -> bool:
    """A direct call to a ``*_pallas`` kernel, or any call passing a
    literal ``use_pallas=True`` (the interpret-mode router override).
    Config lookups like ``gemv_pallas_config`` do not end with ``_pallas``
    and are deliberately not counted — they don't run a kernel."""
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else ""
    )
    if name.endswith("_pallas"):
        return True
    return any(
        kw.arg == "use_pallas"
        and isinstance(kw.value, ast.Constant) and kw.value.value is True
        for kw in node.keywords
    )


def _has_marker(fn_def: ast.FunctionDef, module: ast.Module) -> bool:
    for deco in fn_def.decorator_list:
        if "pallas_interpret" in ast.dump(deco):
            return True
    for stmt in module.body:     # module-level pytestmark also counts
        if (isinstance(stmt, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == "pytestmark"
                        for t in stmt.targets)
                and "pallas_interpret" in ast.dump(stmt.value)):
            return True
    return False


def test_pallas_kernel_tests_carry_interpret_marker():
    offenders = []
    for path in sorted(TESTS_DIR.glob("test_*.py")):
        module = ast.parse(path.read_text())
        for node in module.body:
            if not isinstance(node, ast.FunctionDef) \
                    or not node.name.startswith("test_"):
                continue
            runs_pallas = any(
                isinstance(sub, ast.Call) and _is_pallas_call(sub)
                for sub in ast.walk(node)
            )
            if runs_pallas and not _has_marker(node, module):
                offenders.append(f"{path.name}::{node.name}")
    assert not offenders, (
        "Pallas kernel tests missing @pytest.mark.pallas_interpret "
        f"(the dedicated CI job selects on it): {offenders}"
    )

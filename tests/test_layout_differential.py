"""Property-based differential tests for EVERY registered sparsity layout.

Three invariants, checked uniformly across ``all_layouts()``:

1. round trip — ``from_dense -> to_dense`` preserves exactly the kept
   values (and is lossless for exact layouts);
2. masks are honored — structural constraints (block nnz, explicit masks,
   capacity) hold on the densified result;
3. gradients — ``jax.grad`` through ``from_dense -> to_dense`` equals the
   dense-reference gradient masked to the kept positions (STen's
   "transparent backpropagation", §4.5).

The suite enumerates the registry, so registering a new layout without
adding it here fails loudly.  Hypothesis drives the randomized sweeps when
installed (tests/_hypothesis_compat.py); the parametrized cases below keep
full coverage without it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import nmg
from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    all_layouts,
)

# layout name -> (dense [R, K] -> layout).  Every registered layout MUST
# appear here; test_every_registered_layout_is_covered enforces it.
CONSTRUCTORS = {
    "DenseTensor": lambda x: DenseTensor(jnp.asarray(x)),
    "CsrTensor": CsrTensor.from_dense,
    "CooTensor": CooTensor.from_dense,
    "FixedMaskTensor": FixedMaskTensor.from_dense,
    "NMTensor": lambda x: NMTensor.from_dense(x, 2, 4),
    "GroupedNMTensor": lambda x: GroupedNMTensor.from_dense(x, 2, 4, g=2,
                                                            gr=1),
}

#: layouts whose from_dense keeps every nonzero (lossless on any input)
EXACT = {"DenseTensor", "CsrTensor", "CooTensor", "FixedMaskTensor"}

SHAPES = [(4, 8), (8, 48), (3, 96)]


def rand(shape, seed=0, zeros=False):
    x = np.random.default_rng(seed).normal(size=shape).astype(np.float32)
    if zeros:
        x[np.abs(x) < 0.6] = 0.0
    return jnp.asarray(x)


def test_every_registered_layout_is_covered():
    # scope to the library's own layouts: other tests register throwaway
    # layouts (e.g. the paper's CscTensor extensibility example) into the
    # process-global registry at runtime
    builtin = {name for name, cls in all_layouts().items()
               if cls.__module__.startswith("repro.")}
    missing = builtin - set(CONSTRUCTORS)
    assert not missing, (
        f"layouts registered without differential coverage: {missing} — "
        f"add them to CONSTRUCTORS in {__file__}"
    )


# ---------------------------------------------------------------------------
# 1. round trip preserves kept values
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(CONSTRUCTORS))
@pytest.mark.parametrize("shape", SHAPES)
def test_roundtrip_preserves_kept_values(name, shape):
    x = rand(shape, seed=hash(name) % 1000, zeros=name in EXACT)
    t = CONSTRUCTORS[name](x)
    d = np.asarray(t.to_dense())
    assert d.shape == tuple(x.shape)
    assert t.shape == tuple(x.shape)
    kept = d != 0
    np.testing.assert_allclose(d[kept], np.asarray(x)[kept], rtol=1e-6,
                               err_msg=f"{name}: kept values corrupted")
    if name in EXACT:
        np.testing.assert_allclose(d, np.asarray(x), rtol=1e-6,
                                   err_msg=f"{name}: not lossless")


@given(rows=st.integers(1, 10), cols=st.integers(1, 64),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_roundtrip_property_all_layouts(rows, cols, seed):
    x = rand((rows, cols), seed=seed, zeros=True)
    for name, make in CONSTRUCTORS.items():
        d = np.asarray(make(x).to_dense())
        kept = d != 0
        np.testing.assert_allclose(d[kept], np.asarray(x)[kept], rtol=1e-6,
                                   err_msg=name)


# ---------------------------------------------------------------------------
# 2. masks / structural constraints are honored
# ---------------------------------------------------------------------------


def test_fixed_mask_honored():
    x = rand((8, 16), seed=1)
    mask = jnp.asarray(np.random.default_rng(0).random((8, 16)) < 0.5)
    d = np.asarray(FixedMaskTensor(x, mask).to_dense())
    assert (d[~np.asarray(mask)] == 0).all()
    np.testing.assert_allclose(d[np.asarray(mask)],
                               np.asarray(x)[np.asarray(mask)], rtol=1e-6)


@pytest.mark.parametrize("name,blocksize", [("NMTensor", (2, 4)),
                                            ("GroupedNMTensor", (2, 4))])
def test_block_sparsity_honored(name, blocksize):
    n, m = blocksize
    x = rand((8, 96), seed=2)
    d = np.asarray(CONSTRUCTORS[name](x).to_dense())
    nnz = (d.reshape(8, -1, m) != 0).sum(-1)
    assert nnz.max() <= n, f"{name}: {nnz.max()} > {n} nonzeros in a block"


def test_capacity_padding_is_inert():
    """CSR/COO capacity padding must not leak values into the dense view."""
    x = np.zeros((6, 10), np.float32)
    x[1, 3], x[4, 7] = 2.5, -1.25
    for cls in (CsrTensor, CooTensor):
        t = cls.from_dense(jnp.asarray(x), nnz_cap=16)  # cap >> nnz
        d = np.asarray(t.to_dense())
        np.testing.assert_array_equal(d, x, err_msg=cls.__name__)
        assert t.nnz_cap == 16


# ---------------------------------------------------------------------------
# 3. gradients through the layout match the dense reference
# ---------------------------------------------------------------------------


def grad_through_layout(make, x, w):
    """d/dx sum(make(x).to_dense() * w) — the gradient a training loop sees
    when a weight lives in this layout."""
    return jax.grad(lambda xx: jnp.sum(make(xx).to_dense() * w))(x)


@pytest.mark.parametrize("name", sorted(CONSTRUCTORS))
@pytest.mark.parametrize("shape", [(4, 8), (8, 96)])
def test_grad_matches_dense_reference(name, shape):
    # no induced zeros: keeps the kept-set identification unambiguous
    # (an exactly-zero kept value has probability 0 under a continuous draw)
    x = rand(shape, seed=3)
    w = rand(shape, seed=4)
    make = CONSTRUCTORS[name]
    got = np.asarray(grad_through_layout(make, x, w))
    keep = np.asarray(make(x).to_dense()) != 0
    want = np.asarray(w) * keep
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                               err_msg=f"{name}: gradient mismatch")
    # dropped positions contribute exactly zero gradient
    assert (got[~keep] == 0).all(), f"{name}: gradient leaks into dropped"


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_grad_property_all_layouts(seed):
    x = rand((6, 48), seed=seed)
    w = rand((6, 48), seed=seed + 1)
    for name, make in CONSTRUCTORS.items():
        got = np.asarray(grad_through_layout(make, x, w))
        keep = np.asarray(make(x).to_dense()) != 0
        np.testing.assert_allclose(got, np.asarray(w) * keep, rtol=1e-5,
                                   atol=1e-6, err_msg=name)


def test_grad_cotangent_is_layout_structured():
    """grad w.r.t. the layout itself yields a layout-structured cotangent
    whose value leaf has the stored-value shape (autograd.py contract)."""
    x = rand((8, 96), seed=5)
    for name, make in CONSTRUCTORS.items():
        t = make(x)
        g = jax.grad(lambda tt: jnp.sum(tt.to_dense() ** 2),
                     allow_int=True)(t)
        leaf = getattr(g, "val", getattr(g, "data", None))
        ref = getattr(t, "val", getattr(t, "data", None))
        assert leaf is not None and leaf.shape == ref.shape, name
        assert np.isfinite(np.asarray(leaf)).all(), name

"""n:m:g conversion quality (paper §5.2, Fig 7) and fixed-pattern regather."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import nmg
from repro.core.sparsifiers import SameFormatSparsifier

KEY = jax.random.PRNGKey(7)


def test_energy_ordering_fig7():
    """Paper Fig 7: unstructured >= n:m >= n:m:g (g large) >= n:m:g (g small);
    blocked is worst among the structured family."""
    x = jax.random.normal(KEY, (32, 192))
    e_un = float(nmg.energy(x * nmg.unstructured_mask(x, 0.5), x))
    e_nm = float(nmg.energy(x * nmg.nm_mask(x, 2, 4), x))
    es = {
        g: float(nmg.energy(
            nmg.dense_to_grouped_nm(x, 2, 4, g).to_dense(), x))
        for g in (1, 2, 4, 8)
    }
    e_bl = float(nmg.energy(x * nmg.blocked_mask(x, 4, 0.5), x))
    assert e_un >= e_nm - 1e-6
    assert e_nm >= es[8] - 1e-6
    # monotone in g (larger chunks = more freedom)
    assert es[8] >= es[4] >= es[2] >= es[1] - 1e-6
    assert es[8] >= e_bl  # structured n:m:g beats blocked at same sparsity


def test_density_is_half_for_2_4():
    x = jax.random.normal(KEY, (16, 96))
    d = nmg.dense_to_grouped_nm(x, 2, 4, 2).to_dense()
    assert abs(float(jnp.mean(d != 0)) - 0.5) < 1e-6


def test_greedy_vs_exact_small():
    """Greedy is near the brute-force optimum on small chunks."""
    x = jax.random.normal(KEY, (4, 24))  # C(2,1)=2, g=2 -> CG=4 blocks/chunk
    tg = nmg.dense_to_grouped_nm(x, 1, 2, 2, method="greedy")
    te = nmg.dense_to_grouped_nm(x, 1, 2, 2, method="exact")
    eg = float(nmg.energy(tg.to_dense(), x))
    ee = float(nmg.energy(te.to_dense(), x))
    assert ee >= eg - 1e-6
    assert eg >= 0.93 * ee  # greedy within 7% of optimal


def test_swap_refines_greedy():
    x = jax.random.normal(KEY, (8, 96))
    eg = float(nmg.energy(
        nmg.dense_to_grouped_nm(x, 2, 4, 2, method="greedy").to_dense(), x))
    es = float(nmg.energy(
        nmg.dense_to_grouped_nm(x, 2, 4, 2, method="swap").to_dense(), x))
    assert es >= eg - 1e-6  # paper's GPU swap algorithm never loses


def test_gr_sharing_costs_energy():
    """TPU row-sharing (gr>1) is more restrictive: energy <= gr=1
    (the adaptation cost quantified in DESIGN.md §2.1)."""
    x = jax.random.normal(KEY, (16, 96))
    e1 = float(nmg.energy(nmg.dense_to_grouped_nm(x, 2, 4, 2, gr=1).to_dense(), x))
    e4 = float(nmg.energy(nmg.dense_to_grouped_nm(x, 2, 4, 2, gr=4).to_dense(), x))
    assert e4 <= e1 + 1e-6


def test_same_format_regather_fixed_pattern():
    """SameFormatSparsifier(fixed) keeps blk_idx and re-reads values —
    the cheap per-step path after optimizer updates (paper §4, Fig 9)."""
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, 2, 4, 2)
    x2 = x + 0.01 * jax.random.normal(jax.random.PRNGKey(1), x.shape)
    t2 = SameFormatSparsifier(fixed_pattern=True).resparsify(t, x2)
    assert np.array_equal(np.asarray(t2.blk_idx), np.asarray(t.blk_idx))
    mask = np.asarray(t.to_dense()) != 0
    d2 = np.asarray(t2.to_dense())
    np.testing.assert_allclose(d2[mask], np.asarray(x2)[mask], rtol=1e-5)
    # and nothing outside the old pattern
    assert (d2[~mask] == 0).all()


def test_same_format_recompute_pattern():
    x = jax.random.normal(KEY, (8, 96))
    t = nmg.dense_to_grouped_nm(x, 2, 4, 2)
    # radically different values -> pattern should adapt
    x2 = jax.random.normal(jax.random.PRNGKey(9), x.shape)
    t2 = SameFormatSparsifier(fixed_pattern=False).resparsify(t, x2)
    e_fixed = float(nmg.energy(
        SameFormatSparsifier(True).resparsify(t, x2).to_dense(), x2))
    e_new = float(nmg.energy(t2.to_dense(), x2))
    assert e_new >= e_fixed - 1e-6  # recomputed pattern preserves more


def test_jit_conversion():
    """dense->n:m:g is jit-compatible — the paper's 'performance critical'
    conversion can fuse into the training step."""
    x = jax.random.normal(KEY, (8, 96))
    f = jax.jit(lambda y: nmg.dense_to_grouped_nm(y, 2, 4, 2).to_dense())
    np.testing.assert_allclose(
        f(x), nmg.dense_to_grouped_nm(x, 2, 4, 2).to_dense(), rtol=1e-6
    )

"""Fault-injection tests: schedule determinism under a fixed seed, the
engine's retry/backoff handling of transient faults, outage propagation
past the retry cap, and the seeded fault-storm property — every request
reaches a terminal outcome, survivors' tokens are bitwise-identical to a
fault-free run at the same weight tier, and no KV pages leak."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import init_lm
from repro.serve import (
    FaultConfig,
    FaultInjector,
    InjectedFaultError,
    Request,
    SamplingParams,
    ServeEngine,
    burst_arrivals,
    sparsify_for_serving,
)

KEY = jax.random.PRNGKey(0)

#: every fault except transient errors; sleep is injected as a no-op in
#: these tests, so the schedules fire without slowing the suite
STORM = FaultConfig(seed=2, horizon=256, spike_prob=0.2,
                    spike_s=(0.001, 0.002),
                    slow_windows=((2, 6, 3.0), (10, 14, 2.0)),
                    error_prob=0.3, max_consecutive_errors=2,
                    admission_delay_s=0.001)

NOSLEEP = dict(sleep=lambda s: None)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_smoke("bert-base-sten"), dtype="float32")
    params = init_lm(KEY, cfg)
    yield cfg, params
    from repro.serve import cache as _cache, engine as _engine
    for mod in (_cache, _engine):
        for fn in vars(mod).values():
            clear = getattr(fn, "cache_clear", None)
            if clear is not None:
                clear()
    jax.clear_caches()


def make_reqs(cfg, n, *, plen=8, gen=6, deadline_s=None, arrivals=None):
    key = jax.random.PRNGKey(1)
    reqs = []
    for i in range(n):
        prompt = np.asarray(jax.random.randint(
            jax.random.fold_in(key, i), (plen,), 0, cfg.vocab, jnp.int32))
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=gen,
            sampling=SamplingParams(greedy=True, seed=i),
            arrival_time=0.0 if arrivals is None else float(arrivals[i]),
            priority=i % 3, deadline_s=deadline_s,
        ))
    return reqs


# ---------------------------------------------------------------------------
# schedule determinism
# ---------------------------------------------------------------------------


def test_schedules_identical_under_same_seed():
    a, b = FaultInjector(STORM, **NOSLEEP), FaultInjector(STORM, **NOSLEEP)
    for step in range(2 * STORM.horizon):   # incl. modulo reuse past it
        assert a.spike_at(step) == b.spike_at(step)
        assert a.errors_at(step) == b.errors_at(step)
        assert a.slow_factor(step) == b.slow_factor(step)
    assert any(a.spike_at(s) > 0 for s in range(STORM.horizon))
    assert any(a.errors_at(s) > 0 for s in range(STORM.horizon))
    assert a.slow_factor(3) == 3.0 and a.slow_factor(12) == 2.0
    assert a.slow_factor(7) == 1.0


def test_schedules_differ_across_seeds():
    a = FaultInjector(STORM, **NOSLEEP)
    b = FaultInjector(dataclasses.replace(STORM, seed=6), **NOSLEEP)
    assert any(a.errors_at(s) != b.errors_at(s)
               or a.spike_at(s) != b.spike_at(s)
               for s in range(STORM.horizon))


def test_error_burst_bounded_by_config():
    inj = FaultInjector(STORM, **NOSLEEP)
    for step in range(STORM.horizon):
        n = inj.errors_at(step)
        assert 0 <= n <= STORM.max_consecutive_errors
        raises = 0
        for _ in range(n + 2):              # engine-style retry loop
            try:
                inj.pre_decode(step)
                break
            except InjectedFaultError:
                raises += 1
        assert raises == n                  # burst clears, then admits


def test_burst_arrivals_deterministic_sorted():
    kw = dict(n_background=8, rate_hz=50.0, bursts=((0.1, 4), (0.5, 3)))
    a = burst_arrivals(seed=3, **kw)
    assert a == burst_arrivals(seed=3, **kw)
    assert a != burst_arrivals(seed=4, **kw)
    assert a == sorted(a) and len(a) == 8 + 4 + 3
    assert a.count(0.1) == 4 and a.count(0.5) == 3


# ---------------------------------------------------------------------------
# engine retry handling
# ---------------------------------------------------------------------------


def test_transient_errors_retried_token_stream_unchanged(setup):
    cfg, params = setup
    reqs = make_reqs(cfg, 4)
    base = ServeEngine(params, cfg, max_slots=2, max_seq_len=16,
                       decode_chunk=4)
    want = {o.uid: o.tokens for o in base.run(reqs)}

    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=16,
                      decode_chunk=4,
                      faults=FaultInjector(STORM, **NOSLEEP))
    outs = eng.run(reqs)
    assert eng.stats["fault_retries"] > 0
    assert {o.uid: o.tokens for o in outs} == want


def test_error_burst_past_retry_cap_propagates(setup):
    cfg, params = setup
    outage = FaultConfig(seed=0, horizon=8, error_prob=1.0,
                         max_consecutive_errors=5, max_retries=2)
    eng = ServeEngine(params, cfg, max_slots=2, max_seq_len=16,
                      decode_chunk=4,
                      faults=FaultInjector(outage, **NOSLEEP))
    for r in make_reqs(cfg, 1):
        eng.submit(r)
    with pytest.raises(InjectedFaultError):
        while eng.step():
            pass


# ---------------------------------------------------------------------------
# the fault-storm property
# ---------------------------------------------------------------------------


def test_fault_storm_every_request_terminal_survivors_bitwise(setup):
    """Seeded storm over the paged engine at a fixed sparse tier: every
    request reaches a terminal outcome, every request served despite the
    storm decodes bitwise-identically to the fault-free run, and the page
    allocator ends the run with zero pages in use."""
    cfg, params = setup
    sparse = sparsify_for_serving(params, 1, 4, 8, gr=64)
    arrivals = burst_arrivals(n_background=4, rate_hz=100.0,
                              bursts=((0.0, 6),), seed=2)
    # a couple of tight deadlines so the timeout path fires inside the
    # storm; the rest are generous
    reqs = make_reqs(cfg, len(arrivals), deadline_s=None,
                     arrivals=arrivals)
    reqs[3] = dataclasses.replace(reqs[3], deadline_s=1e-6)
    reqs[7] = dataclasses.replace(reqs[7], deadline_s=1e-6)
    ekw = dict(max_slots=2, max_seq_len=16, decode_chunk=4, paged=True,
               page_size=4, num_pages=16)

    base = ServeEngine(sparse, cfg, **ekw)
    base_outs = base.run(reqs)
    served_base = {o.uid: o.tokens for o in base_outs
                   if o.finish_reason in ("length", "stop")}
    assert base.kv.alloc.pages_in_use() == 0

    eng = ServeEngine(sparse, cfg, faults=FaultInjector(STORM, **NOSLEEP),
                      **ekw)
    outs = eng.run(reqs)

    terminal = ("length", "stop", "rejected", "timeout", "shed")
    assert len(outs) == len(reqs)
    assert all(o.finish_reason in terminal for o in outs)
    assert eng.stats["timeout"] == 2
    served = {o.uid: o.tokens for o in outs
              if o.finish_reason in ("length", "stop")}
    # survivors decode bitwise-identically to the fault-free run at the
    # same tier: host-side fault hooks cannot reach a traced program
    for uid, toks in served.items():
        assert toks == served_base[uid], f"uid {uid} diverged under storm"
    assert eng.kv.alloc.pages_in_use() == 0
    # determinism of the storm itself: a same-seed rerun injects the
    # same faults and lands the same outcomes
    eng2 = ServeEngine(sparse, cfg, faults=FaultInjector(STORM, **NOSLEEP),
                       **ekw)
    outs2 = eng2.run(reqs)
    assert [(o.uid, o.finish_reason, o.tokens) for o in outs2] == \
        [(o.uid, o.finish_reason, o.tokens) for o in outs]
    # "slow_s" scales with the *measured* step time (wall clock), so it
    # varies run-to-run; every schedule-derived counter must match
    drop = ("slow_s",)
    assert {k: v for k, v in eng2.faults.injected.items()
            if k not in drop} == \
        {k: v for k, v in eng.faults.injected.items() if k not in drop}

"""Small I/O utilities shared by the serving/tuning/benchmark layers.

:func:`atomic_write_json` is the one way any repro artifact (``BENCH_*``
merges, ``ServeMetrics.dump_json``, the ``TuningTable`` cache) reaches
disk: serialize to a pid-unique temp file in the destination directory,
then ``os.replace`` into place.  A reader therefore never observes a torn
or truncated file, and a run killed mid-write leaves the previous
artifact intact instead of a corrupt one — the writer-side completion of
the truncated-table *read* hardening from ``repro.tune``
(``TuningTable.load`` tolerating corrupt files).
"""

from __future__ import annotations

import json
import os
from typing import Any

__all__ = ["atomic_write_json"]


def atomic_write_json(path: str, obj: Any, *, indent: int = 2,
                      sort_keys: bool = False) -> None:
    """Atomically serialize ``obj`` as JSON to ``path``.

    The temp file is pid-unique (concurrent writers cannot interleave
    bytes) and lives next to the destination so the final ``os.replace``
    is a same-filesystem atomic rename.  On any serialization or write
    failure the temp file is removed and ``path`` is left untouched."""
    tmp = f"{path}.{os.getpid()}.tmp"
    try:
        with open(tmp, "w") as f:
            json.dump(obj, f, indent=indent, sort_keys=sort_keys)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)

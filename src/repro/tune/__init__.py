"""``repro.tune`` — autotuning & cost-model subsystem.

Replaces the hard-coded kernel-routing constants with measured, persisted
decisions:

* :mod:`repro.tune.table` — the persistent :class:`TuningTable` (JSON,
  keyed by device kind + power-of-two shape bucket),
* :mod:`repro.tune.routing` — lookups with shipped defaults that
  reproduce the historical heuristics exactly when no table is active
  (consumed by ``kernels/ops.py`` and ``core/dispatch.py``),
* :mod:`repro.tune.bench` — the microbenchmark harness and tuners
  (shared with ``benchmarks/fig6_spmm.py``), including
  :func:`autotune_for_serving`, the engine warmup hook,
* ``python -m repro.tune`` — the offline CLI that sweeps the grid and
  writes the table.

Tables change only *which* registered kernel path runs — never its
output (``tests/test_tune.py`` pins tuned and heuristic routing to
bitwise-identical results).

``bench`` imports the kernel modules, so it is intentionally *not*
imported here: ``kernels/ops.py`` can import ``repro.tune.routing``
without a cycle.
"""

from repro.tune.routing import (
    DEFAULT_DECODE_M_MAX,
    DEFAULT_GEMV_PALLAS,
    DEFAULT_SPMM_BLOCK_ELEMS,
    active_table,
    clear_active_table,
    load_table,
    load_table_cli,
    set_active_table,
)
from repro.tune.table import TuningTable, bucket, device_kind, shape_key

__all__ = [
    "DEFAULT_DECODE_M_MAX",
    "DEFAULT_GEMV_PALLAS",
    "DEFAULT_SPMM_BLOCK_ELEMS",
    "TuningTable",
    "active_table",
    "bucket",
    "clear_active_table",
    "device_kind",
    "load_table",
    "load_table_cli",
    "set_active_table",
    "shape_key",
]

"""Microbenchmark harness: the measurements behind the tuning table.

One timing loop (:func:`time_us`) and one right-operand-width sweep
(:func:`sweep_m`) serve every consumer: the ``python -m repro.tune`` CLI,
the serving warmup hook (:func:`autotune_for_serving`) and
``benchmarks/fig6_spmm.py`` (which used to own this machinery; it now
imports it from here so the fig-6 plot and the tuner can never disagree
about what was measured).

Every tuner mutates a :class:`~repro.tune.table.TuningTable` in place and
returns what it measured; persistence and activation are the caller's
business (the CLI saves, the warmup hook activates).
"""

from __future__ import annotations

import time
from typing import Iterable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.tune import routing
from repro.tune.table import TuningTable, bucket, shape_key

__all__ = [
    "time_us",
    "sweep_m",
    "measured_crossover",
    "tune_decode_threshold",
    "tune_spmm_block",
    "tune_gemv_pallas",
    "tune_spmm_pallas",
    "tune_fused_qkv",
    "tune_conversion_costs",
    "autotune_for_serving",
]


def time_us(fn, *args, reps: int = 5, inner: int = 5) -> float:
    """Median-of-``reps`` wall time of ``inner`` back-to-back calls (us).
    The first (untimed) call absorbs compilation."""
    jax.block_until_ready(fn(*args))
    best = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best.append((time.perf_counter() - t0) / inner)
    best.sort()
    return best[len(best) // 2] * 1e6


def sweep_m(t, key, ms: Sequence[int], *, reps: int = 5,
            include_dense: bool = True, dtype=jnp.float32) -> list[dict]:
    """Time the gemv / spmm (/ dense) paths for right operands [K, M] over
    the width sweep ``ms``.  ``t`` is the GroupedNMTensor under test; the
    right operand is random in ``dtype``.  Returns one record
    ``{"path", "M", "us"}`` per (path, M).

    What is timed is what the router actually chooses between on the
    serving entry point (``nmg_linear``): the backend-routed ``nmg_gemv``
    path *with* its dtype-preserving transposed-output epilogue vs the
    backend-routed ``nmg_spmm`` path plus the cast-and-transpose it
    forces — both emitting [M, R] in ``dtype``.  Going through the public
    routed entry points (not the ``_xla`` variants) matters on TPU, where
    the router dispatches the Pallas kernels: the measurements must come
    from the implementations that will actually run.  (On CPU the bare
    f32 kernels would lower to near-identical XLA programs at small M, so
    the epilogue difference is the real routing consequence there.)"""
    from repro.kernels import ops as kops

    dt = jnp.dtype(dtype)
    K = kops._route_ctx(t, dt)["K"]  # the router's own K/R derivation
    sd = t.sparse_dim % 2
    paths = [
        ("gemv",
         jax.jit(lambda a, b: kops.nmg_gemv(a, b, out_dtype=dt,
                                            transpose_out=True)),
         lambda b: (t, b)),
        ("spmm",
         jax.jit(lambda a, b: kops.nmg_spmm(a, b).astype(dt).T),
         lambda b: (t, b)),
    ]
    if include_dense:
        wd = t.to_dense()
        if sd == 0:  # canonical view is the transpose
            wd = wd.T
        dense = jax.jit(lambda b, w: b.T @ w.T)  # same [M, R] orientation
        paths.append(("dense", dense, lambda b: (b, wd)))

    records = []
    for m in ms:
        b = jax.random.normal(jax.random.fold_in(key, m), (K, m), jnp.float32
                              ).astype(dt)
        for name, fn, mkargs in paths:
            records.append({
                "path": name, "M": int(m),
                "us": time_us(fn, *mkargs(b), reps=reps),
            })
    return records


def measured_crossover(records: Iterable[dict], *, tol: float = 0.05) -> int:
    """The measured gemv/spmm crossover: the widest M (scanning the sweep
    upward) at which the gemv path is still no slower than the spmm path —
    i.e. the empirical ``decode_m_max`` for the swept shape.  0 means the
    gemv path never won (route everything to spmm).

    ``tol`` keeps timing noise from flipping the route where the two paths
    are effectively tied (at tiny M they often lower to near-identical
    programs): gemv holds the route until spmm beats it by more than the
    tolerance fraction.  A *single* losing M does not end the scan — one
    noisy sample at the narrow end must not zero the threshold while gemv
    genuinely wins at the real decode widths — but two losses in a row
    (or a loss closing the sweep) are treated as the crossover."""
    gemv = {r["M"]: r["us"] for r in records if r["path"] == "gemv"}
    spmm = {r["M"]: r["us"] for r in records if r["path"] == "spmm"}
    crossover = 0
    losses = 0
    for m in sorted(gemv.keys() & spmm.keys()):
        if gemv[m] <= spmm[m] * (1.0 + tol):
            crossover = m
            losses = 0
        else:
            losses += 1
            if losses >= 2:
                break
    return crossover


# ---------------------------------------------------------------------------
# tuners: measure -> table entry
# ---------------------------------------------------------------------------


def _probe_tensor(key, K: int, R: int, fmt: tuple, gr: int,
                  dtype=jnp.float32):
    """Random probe weight in the dtype under test: stored-value dtype
    changes the gathered-weight traffic and einsum compute dtype, so a
    bf16 bucket must be measured on bf16-stored values."""
    from repro.core import nmg

    n, m, g = fmt
    w = jax.random.normal(key, (R, K), jnp.float32).astype(dtype)
    return nmg.dense_to_grouped_nm(w, n=n, m=m, g=g, gr=gr, sparse_dim=1)


def tune_decode_threshold(table: TuningTable, *, K: int, R: int, fmt: tuple,
                          gr: int, dtype=jnp.float32,
                          ms: Sequence[int] = (1, 2, 4, 8, 16, 32, 64, 128),
                          reps: int = 5, t=None,
                          key: Optional[jax.Array] = None) -> int:
    """Measure the gemv/spmm crossover for one (shape bucket, format) and
    record it as that bucket's ``decode_m_max``.  ``t`` optionally
    supplies an existing (unbatched) tensor to sweep in place of the
    random probe the shape parameters otherwise build.

    The same sweep also yields absolute numbers, so each swept width's
    best-path latency is recorded as the bucket's
    ``matmul_latency/.../M{bucket}`` entry (best over the M values that
    share a bucket) — the admission-time cost predictions the serving SLO
    controller reads back through
    :func:`repro.tune.routing.matmul_latency_us`."""
    key = jax.random.PRNGKey(0) if key is None else key
    if t is None:
        t = _probe_tensor(key, K, R, fmt, gr, dtype=dtype)
    records = sweep_m(t, key, ms, reps=reps, include_dense=False,
                      dtype=dtype)
    crossover = measured_crossover(records)
    table.put(shape_key("decode_m_max", K=K, R=R, fmt=fmt, gr=gr,
                        dtype=dtype), crossover)
    best_by_m: dict = {}
    for r in records:
        m = int(r["M"])
        best_by_m[m] = min(best_by_m.get(m, float("inf")), r["us"])
    lat_key = shape_key("matmul_latency", K=K, R=R, fmt=fmt, gr=gr,
                        dtype=dtype)
    best_by_bucket: dict = {}
    for m, us in best_by_m.items():
        b = bucket(m)
        best_by_bucket[b] = min(best_by_bucket.get(b, float("inf")), us)
    for b, us in best_by_bucket.items():
        table.put(f"{lat_key}/M{b}", us)
    return crossover


def tune_spmm_block(table: TuningTable, *, K: int = 4096, R: int = 4096,
                    N: int = 256, fmt: tuple = (1, 4, 8), gr: int = 64,
                    candidates: Sequence[int] = (1 << 18, 1 << 20, 1 << 22,
                                                 1 << 24),
                    reps: int = 5) -> int:
    """Sweep the XLA spmm gathered-block cap and record the fastest as the
    device-wide ``spmm_block_elems``.

    The probe must be large enough that the candidates *compile
    differently*: a cap only binds when ``per_group = (K/m) * n * N``
    gathered elements times ``Gr = R/gr`` fiber groups exceeds it.  The
    defaults give per_group = 2^18 and Gr = 64, so the candidate ladder
    maps to group-block sizes 1/4/16/64 — four genuinely distinct
    programs.  (A too-small probe would make every candidate lower to the
    same single-block program and the winner would be timing noise.)"""
    from repro.kernels import ops as kops

    key = jax.random.PRNGKey(1)
    t = _probe_tensor(key, K, R, fmt, gr)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    best, best_us = None, float("inf")
    for cand in candidates:
        fn = jax.jit(lambda a, bb, c=int(cand):
                     kops.nmg_spmm_xla(a, bb, block_elems=c))
        us = time_us(fn, t, b, reps=reps)
        if us < best_us:
            best, best_us = int(cand), us
    table.put("spmm_block_elems", best)
    return best


def tune_gemv_pallas(table: TuningTable, *, K: int = 1024, R: int = 1024,
                     M: int = 8, fmt: tuple = (1, 4, 8), gr: int = 64,
                     dtype=jnp.float32,
                     tms: Sequence[int] = (128,),
                     depths: Sequence[int] = (64, 128, 256),
                     reps: int = 3, interpret: Optional[bool] = None) -> dict:
    """Sweep the Pallas gemv output-tile width / packed-contraction depth
    and record the fastest config for the shape bucket.  On CPU this runs
    the kernel in interpret mode — meaningful only as a smoke test, so the
    CLI gates it behind ``--pallas`` off-TPU."""
    from repro.kernels import ops as kops
    from repro.kernels.nmg_gemv import nmg_gemv_pallas

    if interpret is None:
        interpret = not kops.on_tpu()
    key = jax.random.PRNGKey(2)
    t = _probe_tensor(key, K, R, fmt, gr)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, M), jnp.float32
                          ).astype(dtype)
    best, best_us = None, float("inf")
    for tm in tms:
        for depth in depths:
            fn = jax.jit(lambda a, bb, tm=tm, d=depth: nmg_gemv_pallas(
                a, bb, tm=tm, target_depth=d, interpret=interpret))
            us = time_us(fn, t, b, reps=reps, inner=1 if interpret else 5)
            if us < best_us:
                best = {"tm": int(tm), "target_depth": int(depth)}
                best_us = us
    table.put(shape_key("gemv_pallas", K=K, R=R, fmt=fmt, gr=gr,
                        dtype=dtype), best)
    return best


def tune_spmm_pallas(table: TuningTable, *, K: int = 1024, R: int = 1024,
                     N: int = 256, fmt: tuple = (1, 4, 8), gr: int = 64,
                     dtype=jnp.float32,
                     tns: Sequence[int] = (128,),
                     depths: Sequence[int] = (128,),
                     reps: int = 3, interpret: Optional[bool] = None) -> dict:
    """Sweep the Pallas spmm schedule (streamed double-buffer vs pipelined
    grid) and tile config, recording the fastest as the shape bucket's
    ``spmm_pallas`` entry.  Interpret-mode timings off-TPU are smoke only
    (the CLI gates this behind ``--pallas`` there)."""
    from repro.kernels import ops as kops
    from repro.kernels.nmg_spmm import nmg_spmm_pallas

    if interpret is None:
        interpret = not kops.on_tpu()
    key = jax.random.PRNGKey(5)
    t = _probe_tensor(key, K, R, fmt, gr)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32
                          ).astype(dtype)
    best, best_us = None, float("inf")
    for stream in (True, False):
        for tn in tns:
            for depth in depths:
                fn = jax.jit(lambda a, bb, tn=tn, d=depth, s=stream:
                             nmg_spmm_pallas(a, bb, tn=tn, target_depth=d,
                                             stream=s, interpret=interpret))
                us = time_us(fn, t, b, reps=reps,
                             inner=1 if interpret else 5)
                if us < best_us:
                    best = {"tn": int(tn), "target_depth": int(depth),
                            "stream": bool(stream)}
                    best_us = us
    table.put(shape_key("spmm_pallas", K=K, R=R, fmt=fmt, gr=gr,
                        dtype=dtype), best)
    return best


def tune_fused_qkv(table: TuningTable, *, K: int = 256,
                   Rs: Sequence[int] = (256, 256, 256),
                   fmt: tuple = (1, 4, 8), gr: int = 64, M: int = 4,
                   dtype=jnp.float32, reps: int = 3,
                   use_pallas: Optional[bool] = None) -> bool:
    """Measure the fused-QKV megakernel against the per-projection gemv
    path at a decode width and record the winner as the bucket's
    ``fused_qkv`` bool (the summed output rows key the bucket, matching
    the router's fused-group context).  Fusion should win wherever the
    per-launch gather overhead dominates; a bucket where it does not gets
    an explicit veto instead of a silent slowdown."""
    from repro.kernels import ops as kops

    if use_pallas is None:
        use_pallas = kops.on_tpu()
    key = jax.random.PRNGKey(6)
    ws = tuple(_probe_tensor(jax.random.fold_in(key, i), K, R, fmt, gr,
                             dtype=dtype)
               for i, R in enumerate(Rs))
    b = jax.random.normal(jax.random.fold_in(key, 9), (K, M), jnp.float32
                          ).astype(dtype)
    # weights are closed over, as in the engine's jitted decode step —
    # only the activation is a per-call argument on either path
    fused_fn = jax.jit(lambda bb: kops.nmg_qkv(ws, bb, out_dtype=dtype,
                                               use_pallas=use_pallas))
    # per-launch sequential baseline (one dispatch per projection) — the
    # structure the megakernel collapses, same framing as fig6's series
    launches = tuple(
        jax.jit(lambda bb, w=w: kops.nmg_gemv(w, bb, out_dtype=dtype,
                                              use_pallas=use_pallas))
        for w in ws)

    def seq_fn(bb):
        return tuple(f(bb) for f in launches)
    inner = 1 if (use_pallas and not kops.on_tpu()) else 20
    # interleaved best-of rounds: the decision hinges on tens-of-us launch
    # overhead, and a contended runner inflates the two paths asymmetrically
    fused_us = min(time_us(fused_fn, b, reps=reps, inner=inner)
                   for _ in range(3))
    seq_us = min(time_us(seq_fn, b, reps=reps, inner=inner)
                 for _ in range(3))
    win = bool(fused_us <= seq_us)
    table.put(shape_key("fused_qkv", K=K, R=sum(int(r) for r in Rs), fmt=fmt,
                        gr=gr, dtype=dtype), win)
    return win


def tune_conversion_costs(table: TuningTable, *, side: int = 256,
                          reps: int = 3) -> dict:
    """Measure lossless layout-conversion costs among the interchange
    layouts (Dense/Csr/Coo/FixedMask) and record them; the dispatcher's
    conversion tie-breaker consults these via
    :func:`repro.tune.routing.conversion_cost`."""
    import importlib

    conv = importlib.import_module("repro.core.convert")
    from repro.core.layouts import (CooTensor, CsrTensor, DenseTensor,
                                    FixedMaskTensor)

    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (side, side), jnp.float32)
    x = x * (jax.random.uniform(jax.random.fold_in(key, 1),
                                (side, side)) < 0.25)
    insts = {DenseTensor: conv.as_layout(x)}
    for cls in (CsrTensor, CooTensor, FixedMaskTensor):
        insts[cls] = conv.convert(insts[DenseTensor], cls)
    measured = {}
    for src_cls, inst in insts.items():
        for dst_cls in conv.lossless_targets(src_cls):
            if dst_cls is src_cls or dst_cls not in insts:
                continue
            us = time_us(lambda i=inst, d=dst_cls: conv.convert(i, d),
                         reps=reps, inner=3)
            k = f"convert_cost/{src_cls.__name__}->{dst_cls.__name__}"
            table.put(k, us)
            measured[k] = us
    return measured


# ---------------------------------------------------------------------------
# serving warmup hook: tune the engine's actual shapes
# ---------------------------------------------------------------------------


def autotune_for_serving(params, *, max_slots: int, prompt_lens: Sequence[int],
                         dtype=None, reps: int = 3,
                         table: Optional[TuningTable] = None,
                         activate: bool = True) -> TuningTable:
    """Tune the decode/prefill routing for the *actual* sparse-weight
    shapes an engine will serve.

    Walks ``params`` for distinct :class:`GroupedNMTensor` shape/format
    signatures and measures each one's gemv/spmm crossover at the widths
    the engine produces — ``max_slots`` single-token rows per decode step,
    one ``prompt_len``-row block per admission — plus powers of two
    bracketing them.  Each signature is measured on a same-shaped random
    probe rather than the weight itself: gather cost is independent of the
    stored values, and model weights may be layer-stacked (a leading scan
    axis on ``val``) while the routed matmuls always see one layer's
    logical ``dense_shape``, which is exactly what the probe rebuilds.
    Entries land in ``table`` (default: the active table, or a fresh one),
    which is activated so the engine's subsequent first-trace compiles
    against the tuned thresholds.
    """
    from repro.core.layouts import GroupedNMTensor
    from repro.kernels import ops as kops

    if table is None:
        table = routing.active_table() or TuningTable.for_device()
    ms = sorted({1, 2, 4, 8, 16, 32, int(max_slots),
                 *(int(p) for p in prompt_lens)})
    seen = set()
    key = jax.random.PRNGKey(4)
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, GroupedNMTensor)):
        if not isinstance(leaf, GroupedNMTensor):
            continue
        dt = jnp.dtype(dtype) if dtype is not None else leaf.val.dtype
        # the router's own context derivation: table entries must land in
        # exactly the buckets nmg_matmul/nmg_linear will look up
        ctx = kops._route_ctx(leaf, dt)
        sig = shape_key("decode_m_max", **ctx)
        if sig in seen:
            continue
        seen.add(sig)
        tune_decode_threshold(table, ms=ms, reps=reps, key=key, **ctx)
    if activate:
        routing.set_active_table(table)
    return table

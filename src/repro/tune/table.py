"""Persistent tuning table: measured kernel-routing decisions, keyed by
device kind and shape bucket.

The table is a flat ``{key: value}`` JSON cache.  Keys are strings built
by :func:`shape_key` from a decision kind plus the power-of-two shape
bucket and format the decision applies to, e.g.::

    decode_m_max/K1024/R1024/1:4:8/gr64/float32   -> 24
    spmm_block_elems                              -> 4194304
    gemv_pallas/K1024/R1024/1:4:8/gr64/float32    -> {"tm": 128,
                                                      "target_depth": 256}
    convert_cost/CsrTensor->DenseTensor           -> 13.7   (us)

Values are *decisions* (thresholds, block sizes, tile configs, measured
conversion costs), never kernels themselves: a table can only change
*which* registered path runs, so a stale or wrong table degrades
performance, not correctness (the differential suite pins every route to
bitwise-identical outputs).

A table file carries one device section per device kind, so a single
cache file can serve a heterogeneous fleet; :meth:`TuningTable.load`
selects the section for the running device and falls back to shipped
defaults (see :mod:`repro.tune.routing`) for every key the section does
not cover.
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Optional

import jax

from repro.ioutil import atomic_write_json

__all__ = [
    "SCHEMA_VERSION",
    "TuningTable",
    "bucket",
    "device_kind",
    "shape_key",
]

SCHEMA_VERSION = 1


def device_kind() -> str:
    """Normalized device identity the table sections are keyed by, e.g.
    ``cpu:cpu`` or ``tpu:tpu_v5e``."""
    dev = jax.devices()[0]
    kind = dev.device_kind.lower().replace(" ", "_")
    return f"{jax.default_backend()}:{kind}"


def bucket(x: int) -> int:
    """Shape bucket: the next power of two >= x (minimum 1).  Measured
    decisions generalize across the bucket, so the table stays small and a
    lookup for an unmeasured-but-nearby shape still hits."""
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def shape_key(kind: str, *, K: int, R: int, fmt: tuple, gr: int,
              dtype) -> str:
    """Build the table key for decision ``kind`` at a (bucketed) shape.

    ``K`` is the contraction extent, ``R`` the sparse operand's output
    extent, ``fmt`` the (n, m, g) sparsity format, ``gr`` the row-sharing
    width and ``dtype`` the activation dtype.
    """
    import jax.numpy as jnp

    n, m, g = fmt
    return (f"{kind}/K{bucket(K)}/R{bucket(R)}/{n}:{m}:{g}/gr{gr}/"
            f"{jnp.dtype(dtype).name}")


@dataclasses.dataclass
class TuningTable:
    """In-memory view of one device section of the JSON cache."""

    device: str
    entries: dict[str, Any] = dataclasses.field(default_factory=dict)
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)

    # -- lookups ----------------------------------------------------------
    def get(self, key: str, default: Any = None) -> Any:
        return self.entries.get(key, default)

    def put(self, key: str, value: Any) -> None:
        self.entries[key] = value

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    # -- persistence ------------------------------------------------------
    @classmethod
    def for_device(cls, device: Optional[str] = None) -> "TuningTable":
        return cls(device=device or device_kind())

    @classmethod
    def load(cls, path: str, *, device: Optional[str] = None
             ) -> "TuningTable":
        """Load the section for ``device`` (default: the running device).
        A file without a matching section yields an *empty* table — every
        lookup then falls back to the shipped defaults."""
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != SCHEMA_VERSION:
            raise ValueError(
                f"tuning table {path!r} has schema {doc.get('schema')!r}; "
                f"this build reads schema {SCHEMA_VERSION} "
                f"(regenerate with `python -m repro.tune`)"
            )
        device = device or device_kind()
        section = doc.get("devices", {}).get(device, {})
        return cls(device=device,
                   entries=dict(section.get("entries", {})),
                   meta=dict(section.get("meta", {})))

    def save(self, path: str) -> None:
        """Write this device's section into ``path``, preserving sections
        other devices recorded (read-modify-write).

        The temp file is pid-unique and atomically renamed, so readers
        never see a torn file and concurrent savers cannot interleave
        writes; the read-modify-write itself is last-writer-wins (no
        cross-process lock) — concurrent tuners racing on one cache file
        can drop each other's *section update*, so fleet-shared caches
        should be written by one tuner per device kind at a time."""
        doc = {"schema": SCHEMA_VERSION, "devices": {}}
        if os.path.exists(path):
            try:
                with open(path) as f:
                    old = json.load(f)
                if old.get("schema") == SCHEMA_VERSION:
                    doc["devices"].update(old.get("devices", {}))
            except (OSError, ValueError):
                pass  # unreadable/corrupt cache: rewrite from scratch
        doc["devices"][self.device] = {
            "meta": self.meta,
            "entries": self.entries,
        }
        atomic_write_json(path, doc, sort_keys=True)

    def merge(self, other: "TuningTable") -> None:
        """Adopt ``other``'s entries (other wins on conflicts)."""
        self.entries.update(other.entries)
        self.meta.update(other.meta)

"""Routing layer: table lookups with shipped defaults.

This module owns the *shipped defaults* that used to live as hard-coded
constants in ``kernels/ops.py`` (``DECODE_M_MAX = 16``,
``_SPMM_BLOCK_ELEMS = 1 << 22``) and the Pallas GEMV tile shape, and
answers every routing question the kernels ask:

* :func:`decode_m_max` — the gemv/spmm crossover width ``nmg_matmul`` /
  ``nmg_linear`` route on,
* :func:`spmm_block_elems` — the gathered-operand cap of one XLA spmm
  block,
* :func:`gemv_pallas_config` — the Pallas gemv output-tile / contraction
  depth,
* :func:`spmm_pallas_config` — the Pallas spmm column tile / contraction
  depth and whether the streamed (double-buffered weight DMA) schedule is
  used,
* :func:`fused_qkv` / :func:`fused_ffn` — whether the decode megakernels
  (``kernels/nmg_fused.py``) fuse eligible projection groups into one
  launch or fall back to per-projection gemv,
* :func:`conversion_cost` — measured lossless-conversion costs the
  dispatcher's tie-breaker consults (``core/dispatch.py``).

Each lookup returns ``(value, source)`` where ``source`` is ``"table"``
for a hit in the active :class:`~repro.tune.table.TuningTable` and
``"default"`` otherwise, so callers can surface the provenance in their
counters.  With no active table every answer is exactly the old
hard-coded behavior — loading a table is strictly opt-in.

Lookups happen at **trace time** (the kernels read them while JAX traces
a jitted caller), so a table must be active *before* the consuming
program compiles; swapping tables does not retrace already-compiled
programs.  The serving warmup hook (``serve/engine.py:warmup_engine``)
exists precisely to tune-then-compile in the right order.
"""

from __future__ import annotations

import collections
import os
import sys
import warnings
from typing import Optional

from repro.tune.table import TuningTable, bucket, shape_key

__all__ = [
    "DEFAULT_DECODE_M_MAX",
    "DEFAULT_SPMM_BLOCK_ELEMS",
    "DEFAULT_GEMV_PALLAS",
    "DEFAULT_SPMM_PALLAS",
    "DEFAULT_FUSED_QKV",
    "DEFAULT_FUSED_FFN",
    "ENV_TABLE",
    "active_table",
    "set_active_table",
    "clear_active_table",
    "load_table",
    "load_table_cli",
    "table_load_events",
    "decode_m_max",
    "spmm_block_elems",
    "gemv_pallas_config",
    "spmm_pallas_config",
    "fused_qkv",
    "fused_ffn",
    "conversion_cost",
    "matmul_latency_us",
]

#: widest right operand still considered decode-shaped when no table is
#: active (slot batches are single-token, so M == number of serving slots)
DEFAULT_DECODE_M_MAX = 16

#: default cap on the gathered-operand size (elements) of one XLA spmm
#: block — bounds peak memory like the old per-group scan did
DEFAULT_SPMM_BLOCK_ELEMS = 1 << 22

#: default Pallas gemv tile config (lane-width output tile, ~128-deep
#: packed contractions)
DEFAULT_GEMV_PALLAS = {"tm": 128, "target_depth": 128}

#: default Pallas spmm config: lane-width column tile, ~128-deep packed
#: contractions, and the double-buffered weight-streaming schedule
DEFAULT_SPMM_PALLAS = {"tn": 128, "target_depth": 128, "stream": True}

#: decode megakernels fuse by default — eligibility (matching formats,
#: decode-shaped M) is the kernels' business; the table can veto per bucket
DEFAULT_FUSED_QKV = True
DEFAULT_FUSED_FFN = True

#: environment variable naming a table file to auto-load (opt-in; read by
#: :func:`load_table_cli`, which the CLI entry points call)
ENV_TABLE = "REPRO_TUNE_TABLE"

_ACTIVE: Optional[TuningTable] = None


def active_table() -> Optional[TuningTable]:
    return _ACTIVE


def set_active_table(table: Optional[TuningTable]) -> None:
    """Install ``table`` as the process-wide routing source (None restores
    the shipped defaults).  Also wires the dispatcher's conversion-cost
    tie-breaker to the table's measured costs (and unwires it on None)."""
    global _ACTIVE
    _ACTIVE = table
    import importlib

    # module object import: the core package re-exports a *function* named
    # ``dispatch``, shadowing the submodule on attribute-style imports
    disp = importlib.import_module("repro.core.dispatch")
    disp.set_conversion_cost_model(
        conversion_cost if table is not None else None
    )


def clear_active_table() -> None:
    set_active_table(None)


# table-load provenance: ("table", "loaded" | "load_failed") -> count.
# Deliberately *not* reset with the routing counters — a corrupt table that
# was ever swallowed in this process stays visible to the checker and to
# post-mortem debugging even after the run fell back to defaults.
_LOAD_EVENTS: collections.Counter = collections.Counter()


def table_load_events() -> dict:
    """{("table", "loaded" | "load_failed"): count} for this process."""
    return dict(_LOAD_EVENTS)


def load_table(path: str) -> Optional[TuningTable]:
    """Load ``path``'s section for the running device and make it active.

    A corrupt, truncated, or schema-mismatched file is *not* fatal: it
    warns (``RuntimeWarning``), records a ``("table", "load_failed")``
    provenance event, leaves whatever table was previously active
    untouched, and returns None — the run proceeds on shipped defaults
    rather than dying because an optional optimization artifact rotted."""
    try:
        table = TuningTable.load(path)
    except (OSError, ValueError) as e:
        _LOAD_EVENTS[("table", "load_failed")] += 1
        warnings.warn(
            f"tuning table {path!r} failed to load ({e}) — routing falls "
            f"back to shipped defaults",
            RuntimeWarning,
            stacklevel=2,
        )
        return None
    _LOAD_EVENTS[("table", "loaded")] += 1
    set_active_table(table)
    return table


def load_table_cli(path: Optional[str], *, verbose: bool = True
                   ) -> Optional[TuningTable]:
    """The CLI entry points' one-stop loader: an explicit ``path`` wins,
    otherwise ``$REPRO_TUNE_TABLE`` is honored; either way the loaded
    table is announced — and a dangling env path is warned about —
    because tuning silently not taking effect is the failure mode this
    message exists to surface.  Returns None when neither source names a
    (readable) table."""
    if path:
        # the user explicitly asked for this table: a load failure is an
        # error, not a fall-back (silently running untuned would defeat
        # the point of passing --tuning-table)
        table = load_table(path)
        if table is None:
            raise ValueError(
                f"tuning table {path!r} failed to load (see warning above)"
            )
        src = path
    else:
        env = os.environ.get(ENV_TABLE)
        if not env:
            return None
        # the env spelling must not crash unrelated commands, but going
        # quiet would leave the user believing the run was tuned — so warn
        # on a missing, stale-schema, or corrupt env table and fall back
        # to defaults
        if not os.path.exists(env):
            print(f"tuning: ${ENV_TABLE}={env} does not exist — "
                  f"using shipped defaults", file=sys.stderr)
            return None
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            table = load_table(env)
        if table is None:
            msg = str(caught[-1].message) if caught else "load failed"
            print(f"tuning: ${ENV_TABLE}={env} is unreadable ({msg}) — "
                  f"using shipped defaults", file=sys.stderr)
            return None
        src = f"${ENV_TABLE}={env}"
    if verbose:
        print(f"tuning: loaded {len(table)} entries for {table.device} "
              f"from {src}")
    return table


# ---------------------------------------------------------------------------
# lookups
# ---------------------------------------------------------------------------


def _lookup(key: str, default):
    if _ACTIVE is not None:
        hit = _ACTIVE.get(key)
        if hit is not None:
            return hit, "table"
    return default, "default"


def decode_m_max(*, K: int, R: int, fmt: tuple, gr: int, dtype
                 ) -> tuple[int, str]:
    """Widest right operand routed to the GEMV path for this shape bucket.
    Exact-bucket hit, else the device-wide ``decode_m_max`` override, else
    the shipped default."""
    val, src = _lookup(
        shape_key("decode_m_max", K=K, R=R, fmt=fmt, gr=gr, dtype=dtype),
        None,
    )
    if val is None:
        val, src = _lookup("decode_m_max", DEFAULT_DECODE_M_MAX)
    return int(val), src


def spmm_block_elems() -> tuple[int, str]:
    """Gathered-operand element cap per XLA spmm block (device-wide: the
    cap protects peak memory, which does not depend on the shape bucket
    or dtype)."""
    val, src = _lookup("spmm_block_elems", DEFAULT_SPMM_BLOCK_ELEMS)
    return int(val), src


def gemv_pallas_config(*, K: int, R: int, fmt: tuple, gr: int, dtype
                       ) -> tuple[dict, str]:
    """Pallas gemv tile config {tm, target_depth} for this shape bucket."""
    val, src = _lookup(
        shape_key("gemv_pallas", K=K, R=R, fmt=fmt, gr=gr, dtype=dtype),
        None,
    )
    if val is None:
        val, src = _lookup("gemv_pallas", DEFAULT_GEMV_PALLAS)
    cfg = dict(DEFAULT_GEMV_PALLAS)
    cfg.update(val)
    return cfg, src


def spmm_pallas_config(*, K: int, R: int, fmt: tuple, gr: int, dtype
                       ) -> tuple[dict, str]:
    """Pallas spmm config {tn, target_depth, stream} for this shape bucket.
    Exact-bucket hit, else the device-wide ``spmm_pallas`` override, else
    the shipped default (streamed schedule)."""
    val, src = _lookup(
        shape_key("spmm_pallas", K=K, R=R, fmt=fmt, gr=gr, dtype=dtype),
        None,
    )
    if val is None:
        val, src = _lookup("spmm_pallas", DEFAULT_SPMM_PALLAS)
    cfg = dict(DEFAULT_SPMM_PALLAS)
    cfg.update(val)
    return cfg, src


def fused_qkv(*, K: int, R: int, fmt: tuple, gr: int, dtype
              ) -> tuple[bool, str]:
    """Whether eligible attention projections fuse into the single-launch
    QKV megakernel for this shape bucket (``R`` is the *summed* output
    rows of the fused group).  Bucket hit, else device-wide, else True."""
    val, src = _lookup(
        shape_key("fused_qkv", K=K, R=R, fmt=fmt, gr=gr, dtype=dtype),
        None,
    )
    if val is None:
        val, src = _lookup("fused_qkv", DEFAULT_FUSED_QKV)
    return bool(val), src


def fused_ffn(*, K: int, R: int, fmt: tuple, gr: int, dtype
              ) -> tuple[bool, str]:
    """Whether an eligible packed gated-MLP weight routes to the fused
    projection+gate megakernel for this shape bucket."""
    val, src = _lookup(
        shape_key("fused_ffn", K=K, R=R, fmt=fmt, gr=gr, dtype=dtype),
        None,
    )
    if val is None:
        val, src = _lookup("fused_ffn", DEFAULT_FUSED_FFN)
    return bool(val), src


def matmul_latency_us(*, K: int, R: int, fmt: tuple, gr: int, dtype,
                      M: int) -> tuple[Optional[float], str]:
    """Measured best-path latency (us) of one routed sparse matmul at
    right-operand width ``M`` for this shape bucket, or None when the
    active table has no measurement (there is no meaningful shipped
    default for an absolute latency — callers fall back to online
    observation).  Recorded by ``tune_decode_threshold`` from the same
    gemv/spmm sweep that sets the bucket's crossover; the serving SLO
    controller's admission-time cost prediction
    (``serve/slo.py:LatencyModel``) is the consumer."""
    key = (shape_key("matmul_latency", K=K, R=R, fmt=fmt, gr=gr,
                     dtype=dtype) + f"/M{bucket(M)}")
    val, src = _lookup(key, None)
    return (None if val is None else float(val)), src


def conversion_cost(src_cls: type, dst_cls: type) -> Optional[float]:
    """Measured cost (us) of a lossless ``src -> dst`` conversion, or None
    when the active table has no measurement.  ``core/dispatch.py`` uses
    this to break ties among conversion candidates that need the same
    *number* of conversions; with no table (or no measurement) the
    dispatcher keeps its registration-order tie-break, so default behavior
    is unchanged."""
    if _ACTIVE is None or src_cls is dst_cls:
        return None
    return _ACTIVE.get(f"convert_cost/{src_cls.__name__}->{dst_cls.__name__}")

"""Offline autotuner CLI.

    PYTHONPATH=src python -m repro.tune [--quick] [--out tune_table.json]

Runs the microbenchmark grid for the running device and writes (merges)
its section of the JSON tuning table:

* gemv/spmm crossover (``decode_m_max``) per (shape bucket, n:m:g, gr,
  dtype),
* the XLA spmm gathered-block cap (``spmm_block_elems``),
* lossless layout-conversion costs (``convert_cost/...``) for the
  dispatcher tie-breaker,
* the fused-QKV megakernel vs per-projection decision (``fused_qkv``)
  at the fig11 serving shapes,
* on TPU (or with ``--pallas`` anywhere): the Pallas gemv tile config
  sweep (``gemv_pallas/...``) and the Pallas spmm schedule sweep
  (``spmm_pallas/...`` — streamed double-buffer vs pipelined grid).

``--quick`` shrinks the grid to a CI-sized smoke (a handful of shapes,
few repetitions); the resulting table is still a *valid* table — just a
coarser one.  Load a table at runtime with ``--tuning-table`` on the
launch CLIs, ``--table`` on ``benchmarks/fig11_serve.py``, or the
``REPRO_TUNE_TABLE`` environment variable.
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp

from repro.tune import bench
from repro.tune.table import SCHEMA_VERSION, TuningTable, bucket, shape_key

DEFAULT_OUT = "tune_table.json"

# (K, R) probe shapes: serving-ish FFN projections small and large; the
# (256, 4096)/(4096, 256) pair matches the fig11 serving smoke's wi/wo
# buckets so a quick table already drives that run's routing
SHAPES_QUICK = ((256, 4096), (4096, 256))
SHAPES_FULL = ((256, 256), (1024, 1024), (256, 4096), (4096, 256),
               (1024, 4096), (4096, 1024))

# (n, m, g, gr): the serving default plus 2:4 row-shared and the paper's
# per-fiber CPU format
FMTS_QUICK = ((1, 4, 8, 64),)
FMTS_FULL = ((1, 4, 8, 64), (2, 4, 16, 64), (1, 4, 16, 1))

MS_QUICK = (1, 4, 8, 16, 32, 64)
MS_FULL = (1, 2, 4, 8, 16, 24, 32, 48, 64, 128)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid (fewer shapes/formats/reps)")
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="tuning-table JSON path (sections for other "
                         "devices in an existing file are preserved)")
    ap.add_argument("--pallas", action="store_true",
                    help="also sweep the Pallas gemv tile config off-TPU "
                         "(interpret mode; slow, smoke value only)")
    ap.add_argument("--skip-convert", action="store_true",
                    help="skip the layout-conversion cost sweep")
    args = ap.parse_args(argv)

    from repro.kernels import ops as kops

    shapes = SHAPES_QUICK if args.quick else SHAPES_FULL
    fmts = FMTS_QUICK if args.quick else FMTS_FULL
    ms = MS_QUICK if args.quick else MS_FULL
    dtypes = (jnp.float32,) if args.quick else (jnp.float32, jnp.bfloat16)
    reps = 3 if args.quick else 7

    table = TuningTable.for_device()
    t0 = time.time()
    print(f"repro.tune: device {table.device}, "
          f"{'quick' if args.quick else 'full'} grid")

    print("decision,key,value")
    for (K, R) in shapes:
        for (n, m, g, gr) in fmts:
            for dt in dtypes:
                crossover = bench.tune_decode_threshold(
                    table, K=K, R=R, fmt=(n, m, g), gr=gr, dtype=dt,
                    ms=ms, reps=reps,
                )
                key = shape_key("decode_m_max", K=K, R=R, fmt=(n, m, g),
                                gr=gr, dtype=dt)
                print(f"decode_m_max,{key},{crossover}")

    blk = bench.tune_spmm_block(
        table, reps=reps,
        candidates=(1 << 20, 1 << 22) if args.quick
        else (1 << 18, 1 << 20, 1 << 22, 1 << 24),
    )
    print(f"spmm_block_elems,spmm_block_elems,{blk}")

    if not args.skip_convert:
        for k, us in bench.tune_conversion_costs(table, reps=reps).items():
            print(f"convert_cost,{k},{us:.1f}")

    # fused-QKV decision at the fig11 serving shapes: the fused route is
    # the shipped default, so this either confirms it or writes a veto
    win = bench.tune_fused_qkv(table, reps=reps)
    print(f"fused_qkv,fig11-shapes,{win}")

    if kops.on_tpu() or args.pallas:
        cfg = bench.tune_gemv_pallas(table, reps=max(1, reps // 2))
        print(f"gemv_pallas,best,{json.dumps(cfg)}")
        scfg = bench.tune_spmm_pallas(table, reps=max(1, reps // 2))
        print(f"spmm_pallas,best,{json.dumps(scfg)}")
    else:
        print("gemv_pallas,skipped,(off-TPU; pass --pallas to sweep in "
              "interpret mode)")
        print("spmm_pallas,skipped,(off-TPU; pass --pallas to sweep in "
              "interpret mode)")

    table.meta.update({
        "generated_by": "python -m repro.tune"
                        + (" --quick" if args.quick else ""),
        "schema": SCHEMA_VERSION,
        "elapsed_s": round(time.time() - t0, 2),
        "shapes": [[bucket(K), bucket(R)] for K, R in shapes],
    })
    table.save(args.out)
    print(f"wrote {len(table)} entries for {table.device} to {args.out} "
          f"in {table.meta['elapsed_s']}s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

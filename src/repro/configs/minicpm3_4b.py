"""minicpm3-4b [dense]: 62L d_model=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention) [hf:openbmb/MiniCPM3-4B; hf].

Decode uses the absorbed-latent path over the compressed c_kv cache — the
MLA serving memory win."""

from repro.models.common import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    vocab=73448,
    d_model=2560,
    n_layers=62,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    attn_type="mla",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    act="silu",
    gated_mlp=True,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
)

FAMILY = "dense"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

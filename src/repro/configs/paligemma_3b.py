"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216
— SigLIP + gemma [arXiv:2407.07726; hf].

The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings [B, 256, d_model]; the backbone applies a
prefix-LM mask (bidirectional over the image prefix)."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    vocab=257216,
    d_model=2048,
    n_layers=18,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    attn_type="gqa",
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
    vision_prefix=256,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vision_prefix=8,
)

FAMILY = "vlm"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

from repro.configs.registry import (
    ARCHS,
    SHAPES,
    get_arch,
    get_smoke,
    input_specs,
    runnable_cells,
)

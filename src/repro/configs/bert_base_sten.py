"""BERT_BASE-scale config for the paper's own evaluation (Figs 8-11):
12L d_model=768 12H d_ff=3072 — the model STen sparsifies with n:m:g.

Adaptation note: the benchmark uses this as a causal LM backbone (the
sparsity pipeline under test is independent of attention directionality)."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="bert-base-sten",
    vocab=30522,
    d_model=768,
    n_layers=12,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    attn_type="gqa",
    act="gelu",
    gated_mlp=False,
)

SMOKE = CONFIG.scaled(vocab=512, d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=4, head_dim=16, d_ff=128)

FAMILY = "dense"
SKIP_LONG = "paper-eval model; not part of the 40-cell grid"

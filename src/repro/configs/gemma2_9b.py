"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap [arXiv:2408.00118].

long_500k RUNS for this arch (not pure full attention): local layers keep a
4096-window ring cache; global layers hold the full 500k cache (decode is
linear per token; memory shards over the mesh)."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    vocab=256000,
    d_model=3584,
    n_layers=42,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    attn_type="gqa",
    layer_pattern="alt_local_global",
    local_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=4, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, local_window=16,
)

FAMILY = "dense"
SKIP_LONG = None  # runs: local+global alternation is sub-quadratic locally

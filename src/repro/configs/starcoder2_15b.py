"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152 — GQA, RoPE [arXiv:2402.19173; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    vocab=49152,
    d_model=6144,
    n_layers=40,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    attn_type="gqa",
    act="gelu",
    gated_mlp=False,
    rope_theta=100_000.0,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
)

FAMILY = "dense"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060].

long_500k RUNS: O(1) recurrent decode state, chunked-scan prefill."""

from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    vocab=50280,
    d_model=1024,
    n_layers=48,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    attn_type="none",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2,
    ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, conv_width=4, chunk=16),
)

FAMILY = "ssm"
SKIP_LONG = None  # runs: constant-size recurrent state

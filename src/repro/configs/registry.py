"""Architecture registry: the 10 assigned archs x 4 input shapes (40 cells),
plus the paper's own BERT_BASE-scale evaluation config.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model input
of a (arch, shape) cell — weak-type-correct, shardable, no device allocation
— which is what the multi-pod dry-run lowers against.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import (
    arctic_480b,
    bert_base_sten,
    gemma2_9b,
    hymba_1_5b,
    mamba2_370m,
    minicpm3_4b,
    moonshot_16b_a3b,
    paligemma_3b,
    qwen1_5_4b,
    starcoder2_15b,
    whisper_large_v3,
)
from repro.models.common import ModelConfig

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_smoke", "input_specs",
           "runnable_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | decode (long)


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_MODULES = {
    "qwen1.5-4b": qwen1_5_4b,
    "starcoder2-15b": starcoder2_15b,
    "gemma2-9b": gemma2_9b,
    "minicpm3-4b": minicpm3_4b,
    "paligemma-3b": paligemma_3b,
    "moonshot-v1-16b-a3b": moonshot_16b_a3b,
    "arctic-480b": arctic_480b,
    "mamba2-370m": mamba2_370m,
    "whisper-large-v3": whisper_large_v3,
    "hymba-1.5b": hymba_1_5b,
    "bert-base-sten": bert_base_sten,
}

ARCHS = {name: m.CONFIG for name, m in _MODULES.items()}


def get_arch(name: str) -> ModelConfig:
    return _MODULES[name].CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _MODULES[name].SMOKE


def skip_reason(name: str, shape: str) -> Optional[str]:
    mod = _MODULES[name]
    if shape == "long_500k" and mod.SKIP_LONG:
        return mod.SKIP_LONG
    return None


def runnable_cells(include_paper_model: bool = False):
    """The (arch, shape) grid with skip annotations."""
    cells = []
    for name in _MODULES:
        if name == "bert-base-sten" and not include_paper_model:
            continue
        for shape in SHAPES:
            cells.append((name, shape, skip_reason(name, shape)))
    return cells


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec, *,
                batch_override: Optional[int] = None) -> dict:
    """ShapeDtypeStruct inputs for the cell's step function.

    train:   {'tokens', 'labels'} [B, S] int32 (+ modality stubs)
    prefill: {'tokens'} [B, S] (+ modality stubs)
    decode:  {'token' [B, 1], 'pos' scalar} — the KV cache is built by
             jax.eval_shape over init_cache (see launch/dryrun.py).
    """
    B = batch_override or shape.global_batch
    S = shape.seq_len
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = _sds((B, S), jnp.int32)
        specs["labels"] = _sds((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode
        specs["token"] = _sds((B, 1), jnp.int32)

    # modality stubs (assignment: frontend provides precomputed embeddings)
    if cfg.vision_prefix and shape.kind in ("train", "prefill"):
        specs["prefix_embeds"] = _sds((B, cfg.vision_prefix, cfg.d_model),
                                      cfg.jdtype)
    if cfg.n_enc_layers > 0 and shape.kind in ("train", "prefill"):
        # whisper: encoder frames; bounded by the 30 s receptive field
        enc_len = min(S, whisper_large_v3.ENC_LEN) if \
            cfg.name.startswith("whisper") else S
        specs["enc_embeds"] = _sds((B, enc_len, cfg.d_model), cfg.jdtype)
    return specs

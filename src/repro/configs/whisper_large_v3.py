"""whisper-large-v3 [audio]: 32L(+32L enc) d_model=1280 20H (MHA kv=20)
d_ff=5120 vocab=51866 — enc-dec, conv frontend STUB [arXiv:2212.04356].

Per the assignment the modality frontend is a stub: ``input_specs`` provides
precomputed frame embeddings [B, enc_len, d_model].  RoPE replaces the
original sinusoidal/learned positions (DESIGN.md §2.2)."""

from repro.models.common import ModelConfig

ENC_LEN = 1500  # 30 s of audio at 50 Hz after the conv frontend

CONFIG = ModelConfig(
    name="whisper-large-v3",
    vocab=51866,
    d_model=1280,
    n_layers=32,
    n_enc_layers=32,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    attn_type="gqa",
    act="gelu",
    gated_mlp=False,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_enc_layers=2, n_heads=4,
    n_kv_heads=4, head_dim=16, d_ff=128,
)

FAMILY = "audio"
SKIP_LONG = "pure full attention decoder (quadratic 524288 / full cache)"

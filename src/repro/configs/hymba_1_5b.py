"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504,
ssm_state=16 — parallel attn+mamba heads [arXiv:2411.13676; hf].

All attention heads use a sliding window (the few global layers of the
original are folded into the window for scan homogeneity — DESIGN.md §2.2);
the SSM path carries global context, so long_500k RUNS."""

from repro.models.common import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    vocab=32001,
    d_model=1600,
    n_layers=32,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    attn_type="hybrid",
    layer_pattern="local",
    local_window=2048,
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    act="silu",
    gated_mlp=True,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, local_window=16,
    ssm=SSMConfig(state_dim=8, head_dim=8, expand=2, conv_width=4, chunk=16),
)

FAMILY = "hybrid"
SKIP_LONG = None  # runs: sliding-window attn + constant SSM state

"""qwen1.5-4b [dense]: 40L d_model=2560 20H (MHA kv=20) d_ff=6912
vocab=151936 — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]."""

from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    vocab=151936,
    d_model=2560,
    n_layers=40,
    n_heads=20,
    n_kv_heads=20,
    head_dim=128,
    d_ff=6912,
    attn_type="gqa",
    qkv_bias=True,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128,
)

FAMILY = "dense"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (MHA kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 — kimi/moonlight
[hf:moonshotai/Moonlight-16B-A3B; hf]."""

from repro.models.common import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    vocab=163840,
    d_model=2048,
    n_layers=48,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    attn_type="gqa",
    moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                  capacity_factor=1.25),
    act="silu",
    gated_mlp=True,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64, moe=MoEConfig(num_experts=4, top_k=2, d_expert=64),
)

FAMILY = "moe"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual [hf:Snowflake/snowflake-arctic-base].

~470B expert parameters: the stress test for EP sharding + ZeRO-3 optimizer
state sharding in the dry-run."""

from repro.models.common import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    vocab=32000,
    d_model=7168,
    n_layers=35,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    attn_type="gqa",
    moe=MoEConfig(num_experts=128, top_k=2, d_expert=4864,
                  capacity_factor=1.25, dense_residual=True,
                  dense_residual_ff=4864),
    act="silu",
    gated_mlp=True,
)

SMOKE = CONFIG.scaled(
    vocab=512, d_model=64, n_layers=2, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64,
    moe=MoEConfig(num_experts=4, top_k=2, d_expert=64, dense_residual=True,
                  dense_residual_ff=64),
)

FAMILY = "moe"
SKIP_LONG = "pure full attention (quadratic 524288 prefill / full cache)"

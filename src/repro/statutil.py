"""Shared nan-safe statistics/formatting helpers.

One home for the percentile and metric-rendering helpers used by the
serving metrics (``repro.serve.metrics``), the benchmark harness
(``benchmarks/common.py``), and the observability exporters — previously
copied per-module with subtly different edge-case behavior.

Conventions: an empty sample is ``nan``, never an exception; ``nan``
renders as ``--`` (a run with no data is a legitimate outcome, e.g. an
all-shed overload run, and the report must stay printable).
"""

from __future__ import annotations

import math

import numpy as np

__all__ = ["pct", "fmt"]


def pct(xs, q: float) -> float:
    """Percentile ``q`` of ``xs`` as a float; ``nan`` for an empty sample
    (never raises on ``[]``, generators, or 0-size arrays)."""
    a = np.asarray(list(xs) if not hasattr(xs, "__len__") else xs,
                   np.float64)
    return float(np.percentile(a, q)) if a.size else float("nan")


def fmt(x: float, scale: float = 1.0, digits: int = 1) -> str:
    """Render a metric for a text report; ``nan`` prints as ``--``."""
    return "--" if math.isnan(x) else f"{x * scale:.{digits}f}"

from repro.data.pipeline import DataConfig, SyntheticLMPipeline

"""Public jit'd entry points for the sparsity kernels.

Each op picks the Pallas kernel on TPU and interpret-mode (or a pure-XLA
production path) on CPU, pads/crops shapes, and exposes a layout-level API
that core/ops.py registers with the dispatcher.

The n:m:g matmul family is **shape-routed** (the Scorch argument: sparse
kernel choice depends on format *and* operand shape):

  right operand        path                       regime
  -----------------    ------------------------   -------------------------
  M <= decode_m_max    ``nmg_gemv``  (decode)     serving decode GEMV: tiny
                                                  activation batch, weight-
                                                  stationary, dtype epilogue
  M >  decode_m_max    ``nmg_spmm``  (prefill)    wide right operand, column
                                                  tiled, f32 accumulator out

The routing decisions — the gemv/spmm crossover ``decode_m_max``, the
spmm gathered-block cap, and the Pallas gemv tile config — come from
``repro.tune.routing``: a lookup into the active
:class:`~repro.tune.table.TuningTable` (device kind + shape bucket) with
shipped defaults (``DECODE_M_MAX``, ``_SPMM_BLOCK_ELEMS`` below) that
reproduce the historical hard-coded heuristics exactly when no table is
loaded.  A table changes only *which* path runs, never its output.
Lookups happen at trace time, so load tables before compiling consumers
(the serving warmup hook does this in the right order).

Both paths consume the :class:`~repro.core.layouts.SpmmPlan` gather plan
the conversion precomputed (``GroupedNMTensor.gather_plan``) instead of
re-deriving index math per call.  ``kernel_counters`` records which path
each *trace* took — including the router's choice and its provenance,
e.g. ``("nmg_matmul", "gemv[table]")`` — the no-dense-fallback evidence
the serving perf smoke asserts on (dispatch is trace-time, so counters
count compilations, not calls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layouts import GroupedNMTensor
from repro.obs.registry import REGISTRY as _REGISTRY
from repro.kernels import ref as kref
from repro.tune import routing
from repro.kernels.fused_sparse_matmul import matmul_threshold_pallas
from repro.kernels.nm_mask import nm_mask_pallas
from repro.kernels.nmg_fused import (
    act_fn,
    fusable_ffn,
    fusable_qkv,
    fused_segments,
    nmg_ffn_pallas,
    nmg_qkv_pallas,
)
from repro.kernels.nmg_gemv import nmg_gemv_pallas
from repro.kernels.nmg_spmm import nmg_spmm_pallas

__all__ = [
    "on_tpu",
    "DECODE_M_MAX",
    "nmg_matmul",
    "nmg_spmm",
    "nmg_spmm_xla",
    "nmg_gemv",
    "nmg_gemv_xla",
    "nmg_linear",
    "nmg_qkv",
    "nmg_qkv_xla",
    "nmg_ffn",
    "nmg_ffn_xla",
    "maybe_fused_qkv",
    "maybe_fused_ffn",
    "nm_mask",
    "matmul_threshold",
    "kernel_counters",
    "reset_kernel_counters",
    "predict_route",
]

#: shipped-default decode width (single source of truth:
#: ``repro.tune.routing``); the router consults the active tuning table
#: first and falls back to this, so the name stays importable for code
#: and docs that reference the heuristic
DECODE_M_MAX = routing.DEFAULT_DECODE_M_MAX

#: shipped-default cap on the gathered-operand size (elements) of one XLA
#: spmm block — bounds peak memory like the old per-group scan did,
#: without its group-at-a-time serialization; tuned per device via
#: ``spmm_block_elems`` table entries
_SPMM_BLOCK_ELEMS = routing.DEFAULT_SPMM_BLOCK_ELEMS

# (kernel, path) -> number of traces routed there.  A ``repro.obs``
# registry family: same Counter semantics at every call site, but the
# counts join the unified telemetry snapshot and each routing decision
# becomes a timestamped ``kernel_route`` event on the kernel track when
# the flight recorder is enabled.
_KERNEL_COUNTS = _REGISTRY.family(
    "kernel_routes",
    help="trace-time kernel routing: (kernel, path) -> traces",
    trace_as="kernel_route", track="kernel")


def kernel_counters() -> dict:
    """Trace-time routing evidence: {(kernel, path): count}."""
    return dict(_KERNEL_COUNTS)


def reset_kernel_counters() -> None:
    _KERNEL_COUNTS.clear()


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# prefill-shaped path: wide right operand
# ---------------------------------------------------------------------------


def nmg_spmm(a: GroupedNMTensor, b: jnp.ndarray, *, use_pallas: bool | None = None
             ) -> jnp.ndarray:
    """C = A_canonical[R, K] @ B[K, N] (f32).

    Pallas kernel on TPU (interpret-mode validation on CPU via tests);
    the batched gather-einsum XLA path otherwise.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    _KERNEL_COUNTS[("nmg_spmm", "pallas" if use_pallas else "xla")] += 1
    if use_pallas:
        cfg, src = routing.spmm_pallas_config(**_route_ctx(a, b.dtype))
        sched = "stream" if cfg["stream"] else "grid"
        _KERNEL_COUNTS[("nmg_spmm_pallas", f"{sched}[{src}]")] += 1
        with jax.named_scope(f"repro.nmg_spmm_pallas[{sched}]"):
            return nmg_spmm_pallas(a, b, interpret=not on_tpu(),
                                   tn=cfg["tn"],
                                   target_depth=cfg["target_depth"],
                                   stream=cfg["stream"])
    return nmg_spmm_xla(a, b)


def _gather_block(b_p, cols, val_g):
    """One activation-stationary block: gather the compressed B rows for a
    slab of fiber-groups and contract in a single einsum.

    cols  [G, nb*n]  compressed-column plan slab
    val_g [G, gr, nb*n]
    -> [G, gr, N] f32
    """
    bg = jnp.take(b_p, cols.reshape(-1), axis=0)
    bg = bg.reshape(*cols.shape, b_p.shape[1])           # [G, nb*n, N]
    return jnp.einsum(
        "grk,gkn->grn",
        val_g.astype(jnp.float32), bg.astype(jnp.float32),
    )


def nmg_spmm_xla(a: GroupedNMTensor, b: jnp.ndarray, *,
                 block_elems: int | None = None) -> jnp.ndarray:
    """Pure-XLA production path: one batched gather + blocked einsum over
    the precomputed column plan.  Replaces the old per-fiber-group
    ``lax.scan`` (Gr sequential micro-matmuls) with ceil(Gr / block)
    vectorized blocks, where the block size caps the gathered operand at
    ``block_elems`` elements (the old scan's memory-safety property,
    without its serialization).  ``block_elems`` defaults to the routing
    lookup (tuned per device; shipped default ``_SPMM_BLOCK_ELEMS``) and
    is resolved at trace time."""
    if block_elems is None:
        block_elems, _ = routing.spmm_block_elems()
    return _nmg_spmm_xla(a, b, block_elems=int(block_elems))


@functools.partial(jax.jit, static_argnames=("block_elems",))
def _nmg_spmm_xla(a: GroupedNMTensor, b: jnp.ndarray, *,
                  block_elems: int) -> jnp.ndarray:
    gr = a.gr
    val = a.val                                # [R_pad, nblocks, n]
    R_pad, nblocks, n = val.shape
    cols = a.gather_plan().cols                # [Gr, nblocks*n]
    Gr = cols.shape[0]
    K_pad = nblocks * a.m
    K, N = b.shape
    b_p = jnp.pad(b, ((0, K_pad - K), (0, 0)))
    val_g = val.reshape(Gr, gr, nblocks * n)

    per_group = nblocks * n * N                # gathered elements per group
    gb = max(1, min(Gr, block_elems // max(1, per_group)))
    nblk = -(-Gr // gb)
    if nblk == 1:
        out = _gather_block(b_p, cols, val_g)  # [Gr, gr, N]
    else:
        pad = nblk * gb - Gr
        cols_b = jnp.pad(cols, ((0, pad), (0, 0))).reshape(nblk, gb, -1)
        val_b = jnp.pad(val_g, ((0, pad), (0, 0), (0, 0))).reshape(
            nblk, gb, gr, -1
        )
        out = jax.lax.map(
            lambda xs: _gather_block(b_p, xs[0], xs[1]), (cols_b, val_b)
        )
        out = out.reshape(nblk * gb, gr, N)[:Gr]
    out = out.reshape(R_pad, N)
    sd = a.sparse_dim % 2
    R = a.dense_shape[1 - sd]
    return out[:R]


# ---------------------------------------------------------------------------
# decode-shaped path: narrow right operand (serving GEMV)
# ---------------------------------------------------------------------------


def nmg_gemv(a: GroupedNMTensor, b: jnp.ndarray, *, out_dtype=None,
             transpose_out: bool = False,
             use_pallas: bool | None = None) -> jnp.ndarray:
    """C = A_canonical[R, K] @ B[K, M] for decode-shaped (narrow) B.

    ``out_dtype`` is honored in the kernel epilogue (single cast after the
    f32 accumulation); default f32 mirrors the SpMM contract so the two
    paths are drop-in interchangeable.  ``transpose_out=True`` returns
    [M, R] — free on the XLA path (the einsum emits that order directly),
    a transpose of the narrow output on the Pallas path."""
    if use_pallas is None:
        use_pallas = on_tpu()
    _KERNEL_COUNTS[("nmg_gemv", "pallas" if use_pallas else "xla")] += 1
    if use_pallas:
        cfg, _ = routing.gemv_pallas_config(**_route_ctx(a, b.dtype))
        with jax.named_scope("repro.nmg_gemv_pallas"):
            out = nmg_gemv_pallas(a, b, out_dtype=out_dtype,
                                  interpret=not on_tpu(),
                                  tm=cfg["tm"],
                                  target_depth=cfg["target_depth"])
        return out.T if transpose_out else out
    return nmg_gemv_xla(a, b, out_dtype=out_dtype,
                        transpose_out=transpose_out)


@functools.partial(jax.jit, static_argnames=("out_dtype", "transpose_out"))
def nmg_gemv_xla(a: GroupedNMTensor, b: jnp.ndarray, *, out_dtype=None,
                 transpose_out: bool = False) -> jnp.ndarray:
    """Activation-stationary XLA decode path: B is small enough to gather
    in one shot, so the whole product is a single gather + einsum over the
    precomputed plan.  ``transpose_out=True`` emits [M, R] directly (the
    orientation ``nmg_linear`` wants), skipping the output transpose."""
    gr = a.gr
    val = a.val
    R_pad, nblocks, n = val.shape
    cols = a.gather_plan().cols                # [Gr, nblocks*n]
    Gr = cols.shape[0]
    K_pad = nblocks * a.m
    K, M = b.shape
    b_p = jnp.pad(b, ((0, K_pad - K), (0, 0)))

    xg = jnp.take(b_p, cols.reshape(-1), axis=0)
    xg = xg.reshape(Gr, nblocks * n, M)
    val_g = val.reshape(Gr, gr, nblocks * n)
    sd = a.sparse_dim % 2
    R = a.dense_shape[1 - sd]
    spec = "grk,gkm->mgr" if transpose_out else "grk,gkm->grm"
    out = jnp.einsum(spec, val_g.astype(jnp.float32), xg.astype(jnp.float32))
    if transpose_out:
        out = out.reshape(M, R_pad)[:, :R]
    else:
        out = out.reshape(R_pad, M)[:R]
    if out_dtype is not None:
        out = out.astype(out_dtype)
    return out


# ---------------------------------------------------------------------------
# decode megakernels: fused QKV and fused gated-FFN
# ---------------------------------------------------------------------------


def _fused_ctx(ws, dtype) -> dict:
    """Routing context of a fused projection group: shared contraction
    extent, *summed* output rows."""
    w0 = ws[0]
    sd = w0.sparse_dim % 2
    return dict(K=w0.dense_shape[sd],
                R=sum(w.dense_shape[1 - (w.sparse_dim % 2)] for w in ws),
                fmt=(w0.n, w0.m, w0.g), gr=w0.gr, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "transpose_out"))
def nmg_qkv_xla(ws, b: jnp.ndarray, *, out_dtype=None,
                transpose_out: bool = False) -> tuple:
    """XLA fused QKV: the per-projection gather-einsum over the
    row-concatenated plan — one take + one einsum for the whole group.
    Each group's contraction is independent and ordered exactly as in
    :func:`nmg_gemv_xla`, so the per-projection slices match the
    sequential path bitwise."""
    w0 = ws[0]
    gr = w0.gr
    val = jnp.concatenate([w.val for w in ws], axis=0)
    cols = jnp.concatenate([w.gather_plan().cols for w in ws], axis=0)
    R_pad, nblocks, n = val.shape
    Gr = cols.shape[0]
    K_pad = nblocks * w0.m
    K, M = b.shape
    b_p = jnp.pad(b, ((0, K_pad - K), (0, 0)))

    xg = jnp.take(b_p, cols.reshape(-1), axis=0)
    xg = xg.reshape(Gr, nblocks * n, M)
    val_g = val.reshape(Gr, gr, nblocks * n)
    spec = "grk,gkm->mgr" if transpose_out else "grk,gkm->grm"
    out = jnp.einsum(spec, val_g.astype(jnp.float32), xg.astype(jnp.float32))
    out = out.reshape(M, R_pad) if transpose_out else out.reshape(R_pad, M)
    if out_dtype is not None:
        out = out.astype(out_dtype)
    segs = fused_segments(ws)
    if transpose_out:
        return tuple(out[:, off:off + R] for off, R in segs)
    return tuple(out[off:off + R] for off, R in segs)


def nmg_qkv(ws, b: jnp.ndarray, *, out_dtype=None,
            transpose_out: bool = False,
            use_pallas: bool | None = None) -> tuple:
    """Fused projection group: every weight of ``ws`` against the same
    decode-shaped B[K, M] in **one** launch.  Returns one [R_i, M] array
    (or [M, R_i] with ``transpose_out``) per projection."""
    if use_pallas is None:
        use_pallas = on_tpu()
    _KERNEL_COUNTS[("nmg_qkv", "pallas" if use_pallas else "xla")] += 1
    if use_pallas:
        cfg, _ = routing.gemv_pallas_config(**_fused_ctx(ws, b.dtype))
        with jax.named_scope("repro.nmg_qkv_pallas"):
            outs = nmg_qkv_pallas(tuple(ws), b, out_dtype=out_dtype,
                                  interpret=not on_tpu(), tm=cfg["tm"],
                                  target_depth=cfg["target_depth"])
        return tuple(o.T for o in outs) if transpose_out else outs
    return nmg_qkv_xla(tuple(ws), b, out_dtype=out_dtype,
                       transpose_out=transpose_out)


@functools.partial(
    jax.jit, static_argnames=("act", "out_dtype", "transpose_out")
)
def nmg_ffn_xla(w: GroupedNMTensor, b: jnp.ndarray, *, act: str = "silu",
                out_dtype=None, transpose_out: bool = False) -> jnp.ndarray:
    """XLA fused gated FFN: literally the sequential ops (projection with
    the decode epilogue, split, act, multiply) under one jit — bitwise
    equal to the unfused model path by construction."""
    hh = nmg_gemv_xla(w, b, out_dtype=out_dtype, transpose_out=True)
    u, v = jnp.split(hh, 2, axis=-1)
    out = act_fn(act)(u) * v                   # [M, F]
    return out if transpose_out else out.T


def nmg_ffn(w: GroupedNMTensor, b: jnp.ndarray, *, act: str = "silu",
            out_dtype=None, transpose_out: bool = False,
            use_pallas: bool | None = None) -> jnp.ndarray:
    """Fused gated-MLP pair: packed [D, 2F] weight against decode-shaped
    B[D, M], gate applied in the kernel epilogue.  Returns [F, M] (or
    [M, F] with ``transpose_out``)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    _KERNEL_COUNTS[("nmg_ffn", "pallas" if use_pallas else "xla")] += 1
    if use_pallas:
        cfg, _ = routing.gemv_pallas_config(**_route_ctx(w, b.dtype))
        with jax.named_scope("repro.nmg_ffn_pallas"):
            out = nmg_ffn_pallas(w, b, act=act, out_dtype=out_dtype,
                                 interpret=not on_tpu(), tm=cfg["tm"],
                                 target_depth=cfg["target_depth"])
        return out.T if transpose_out else out
    return nmg_ffn_xla(w, b, act=act, out_dtype=out_dtype,
                       transpose_out=transpose_out)


def maybe_fused_qkv(x: jnp.ndarray, ws, *, use_pallas: bool | None = None):
    """Linear-level fused-QKV router: y_i = x @ W_i for every projection in
    one launch, or None when the group is ineligible (mixed formats, dense
    weights, prefill-shaped x) or the table vetoes fusion — callers fall
    back to per-projection ``nmg_linear``.  Outputs are in x.dtype and
    bitwise-equal to the sequential path either way."""
    ws = tuple(ws)
    if not fusable_qkv(ws):
        return None
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    M = x2.shape[0]
    ctx = _fused_ctx(ws, x.dtype)
    thr, _ = routing.decode_m_max(**ctx)
    if M > thr:
        return None                            # prefill regime: spmm wins
    fuse, src = routing.fused_qkv(**ctx)
    if not fuse:
        _KERNEL_COUNTS[("nmg_qkv", f"sequential[{src}]")] += 1
        return None
    _KERNEL_COUNTS[("nmg_qkv", f"fused[{src}]")] += 1
    ys = nmg_qkv(ws, x2.T, out_dtype=x.dtype, transpose_out=True,
                 use_pallas=use_pallas)
    return tuple(y.reshape(*lead, -1) for y in ys)


def maybe_fused_ffn(x: jnp.ndarray, w, *, act: str = "silu",
                    use_pallas: bool | None = None):
    """Linear-level fused-FFN router: ``act(u) * v`` for the packed gated
    weight in one launch, or None (ineligible shape/format or table veto)
    so the caller runs the sequential projection + split + gate."""
    if not isinstance(w, GroupedNMTensor):
        return None
    sd = w.sparse_dim % 2
    R = w.dense_shape[1 - sd]
    if R % 2 or not fusable_ffn(w, R // 2):
        return None
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    M = x2.shape[0]
    ctx = _route_ctx(w, x.dtype)
    thr, _ = routing.decode_m_max(**ctx)
    if M > thr:
        return None
    fuse, src = routing.fused_ffn(**ctx)
    if not fuse:
        _KERNEL_COUNTS[("nmg_ffn", f"sequential[{src}]")] += 1
        return None
    _KERNEL_COUNTS[("nmg_ffn", f"fused[{src}]")] += 1
    y = nmg_ffn(w, x2.T, act=act, out_dtype=x.dtype, transpose_out=True,
                use_pallas=use_pallas)
    return y.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# shape routing
# ---------------------------------------------------------------------------


def _route_ctx(a: GroupedNMTensor, dtype) -> dict:
    """The routing-lookup context of a sparse operand: contraction extent,
    output extent, format, row sharing, activation dtype."""
    sd = a.sparse_dim % 2
    return dict(K=a.dense_shape[sd], R=a.dense_shape[1 - sd],
                fmt=(a.n, a.m, a.g), gr=a.gr, dtype=dtype)


def nmg_matmul(a: GroupedNMTensor, b: jnp.ndarray, *,
               use_pallas: bool | None = None) -> jnp.ndarray:
    """Shape-routed sparse @ dense: decode-shaped right operands take the
    GEMV path, everything else the column-tiled SpMM.  f32 output either
    way (the shared kernel contract).  The crossover width comes from the
    routing table (shipped default ``DECODE_M_MAX``); the chosen path and
    its provenance land in ``kernel_counters`` as
    ``("nmg_matmul", "<path>[<table|default>]")``."""
    if b.ndim == 2:
        thr, src = routing.decode_m_max(**_route_ctx(a, b.dtype))
        if b.shape[1] <= thr:
            _KERNEL_COUNTS[("nmg_matmul", f"gemv[{src}]")] += 1
            return nmg_gemv(a, b, use_pallas=use_pallas)
        _KERNEL_COUNTS[("nmg_matmul", f"spmm[{src}]")] += 1
    return nmg_spmm(a, b, use_pallas=use_pallas)


def nmg_linear(x: jnp.ndarray, w: GroupedNMTensor, *,
               use_pallas: bool | None = None) -> jnp.ndarray:
    """y = x @ W for an n:m:g weight W stored with sparse_dim = input axis
    (K) and groups along the output axis (N) — the serving fast path
    (paper §5.3: 'our sparse-dense GEMM kernel during inference').

    x: [..., K]  ->  y: [..., N] in x.dtype.  Decode-shaped x (few rows)
    takes the GEMV kernel, whose epilogue emits x.dtype directly — no f32
    round-trip and (on the XLA path) no output transpose at all; the
    prefill path casts before transposing, so the copy happens at the
    narrow dtype.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    M = x2.shape[0]
    thr, src = routing.decode_m_max(**_route_ctx(w, x.dtype))
    if M <= thr:
        _KERNEL_COUNTS[("nmg_linear", f"gemv[{src}]")] += 1
        y = nmg_gemv(w, x2.T, out_dtype=x.dtype, transpose_out=True,
                     use_pallas=use_pallas)
        return y.reshape(*lead, -1)
    _KERNEL_COUNTS[("nmg_linear", f"spmm[{src}]")] += 1
    yt = nmg_spmm(w, x2.T, use_pallas=use_pallas)  # f32 [N, M]
    return yt.astype(x.dtype).T.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# static route prediction (the checker's differential surface)
# ---------------------------------------------------------------------------


def _predict_linear(w: GroupedNMTensor, M: int, dtype,
                    use_pallas: bool) -> list:
    """Counter keys :func:`nmg_linear` would record for this trace."""
    thr, src = routing.decode_m_max(**_route_ctx(w, dtype))
    if M <= thr:
        return [("nmg_linear", f"gemv[{src}]"),
                ("nmg_gemv", "pallas" if use_pallas else "xla")]
    keys = [("nmg_linear", f"spmm[{src}]"),
            ("nmg_spmm", "pallas" if use_pallas else "xla")]
    if use_pallas:
        cfg, csrc = routing.spmm_pallas_config(**_route_ctx(w, dtype))
        sched = "stream" if cfg["stream"] else "grid"
        keys.append(("nmg_spmm_pallas", f"{sched}[{csrc}]"))
    return keys


def predict_route(op: str, a=None, *, M: int, dtype, ws=None,
                  act: str = "silu", use_pallas: bool | None = None) -> list:
    """Predict, without tracing anything, the ``kernel_counters`` keys one
    trace of ``op`` would record — the same routing lookups the runtime
    branches run, in the same order.  ``repro.check --differential``
    cross-checks these predictions against the counters a real engine
    warmup records; a mismatch means this mirror (or the router) drifted.

    ``op`` is the layout-level op name: ``"nmg_linear"`` / ``"nmg_matmul"``
    (plain projection of an [*, K] activation with ``M`` total rows),
    ``"mm_gated"`` (the model's gated-MLP entry, which may fuse), or
    ``"mm_fused_qkv"`` (projection group ``ws``).  Lookups read the active
    tuning table exactly as the runtime would, so predictions are
    table-sensitive — predict under the same table you serve under."""
    if use_pallas is None:
        use_pallas = on_tpu()

    if op in ("nmg_linear", "nmg_matmul"):
        keys = _predict_linear(a, M, dtype, use_pallas)
        if op == "nmg_matmul":
            thr, src = routing.decode_m_max(**_route_ctx(a, dtype))
            path = "gemv" if M <= thr else "spmm"
            keys = [("nmg_matmul", f"{path}[{src}]")] + [
                k for k in keys if k[0] != "nmg_linear"
            ]
        return keys

    if op == "mm_gated":
        if not isinstance(a, GroupedNMTensor):
            return []                          # dense weight: reference path
        sd = a.sparse_dim % 2
        R = a.dense_shape[1 - sd]
        ctx = _route_ctx(a, dtype)
        thr, _ = routing.decode_m_max(**ctx)
        eligible = R % 2 == 0 and fusable_ffn(a, R // 2)
        if not eligible or M > thr:
            return _predict_linear(a, M, dtype, use_pallas)
        fuse, src = routing.fused_ffn(**ctx)
        if fuse:
            return [("nmg_ffn", f"fused[{src}]"),
                    ("nmg_ffn", "pallas" if use_pallas else "xla")]
        return [("nmg_ffn", f"sequential[{src}]")] + _predict_linear(
            a, M, dtype, use_pallas
        )

    if op == "mm_fused_qkv":
        ws = tuple(ws if ws is not None else a)
        if not fusable_qkv(ws):
            return [k for w in ws
                    for k in _predict_linear(w, M, dtype, use_pallas)]
        ctx = _fused_ctx(ws, dtype)
        thr, _ = routing.decode_m_max(**ctx)
        if M > thr:
            return [k for w in ws
                    for k in _predict_linear(w, M, dtype, use_pallas)]
        fuse, src = routing.fused_qkv(**ctx)
        if fuse:
            return [("nmg_qkv", f"fused[{src}]"),
                    ("nmg_qkv", "pallas" if use_pallas else "xla")]
        return [("nmg_qkv", f"sequential[{src}]")] + [
            k for w in ws for k in _predict_linear(w, M, dtype, use_pallas)
        ]

    raise ValueError(f"predict_route: unknown op {op!r}")


# ---------------------------------------------------------------------------
# other kernels
# ---------------------------------------------------------------------------


def nm_mask(x: jnp.ndarray, n: int, m: int, *, use_pallas: bool | None = None
            ) -> jnp.ndarray:
    """Boolean per-m-block top-n keep mask along the last axis."""
    if use_pallas is None:
        use_pallas = on_tpu()
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_pallas:
        with jax.named_scope("repro.nm_mask_pallas"):
            mask = nm_mask_pallas(x2, n, m, interpret=not on_tpu())
        return mask.astype(jnp.bool_).reshape(shape)
    return kref.nm_mask_ref(x2, n, m).reshape(shape)


def matmul_threshold(a, b, threshold: float, *, use_pallas: bool | None = None):
    """Matmul with fused streaming threshold sparsifier.
    Returns (masked values, bool mask)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas:
        with jax.named_scope("repro.matmul_threshold_pallas"):
            val, mask = matmul_threshold_pallas(
                a, b, threshold=threshold, interpret=not on_tpu()
            )
        return val, mask.astype(jnp.bool_)
    val, mask = kref.matmul_threshold_ref(a, b, threshold)
    return val, mask

"""Public jit'd entry points for the sparsity kernels.

Each op picks the Pallas kernel on TPU and interpret-mode (or a pure-XLA
production path) on CPU, pads/crops shapes, and exposes a layout-level API
that core/ops.py registers with the dispatcher.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layouts import GroupedNMTensor, nm_patterns
from repro.kernels import ref as kref
from repro.kernels.fused_sparse_matmul import matmul_threshold_pallas
from repro.kernels.nm_mask import nm_mask_pallas
from repro.kernels.nmg_spmm import nmg_spmm_pallas

__all__ = [
    "on_tpu",
    "nmg_spmm",
    "nmg_spmm_xla",
    "nmg_linear",
    "nm_mask",
    "matmul_threshold",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nmg_spmm(a: GroupedNMTensor, b: jnp.ndarray, *, use_pallas: bool | None = None
             ) -> jnp.ndarray:
    """C = A_canonical[R, K] @ B[K, N] (f32).

    Pallas kernel on TPU (interpret-mode validation on CPU via tests);
    the gather-based XLA path otherwise.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas:
        return nmg_spmm_pallas(a, b, interpret=not on_tpu())
    return nmg_spmm_xla(a, b)


@jax.jit
def nmg_spmm_xla(a: GroupedNMTensor, b: jnp.ndarray) -> jnp.ndarray:
    """Pure-XLA production path for CPU: scan over fiber-groups, gathering
    the compressed B rows per group and running one dense matmul per group.
    Memory-safe (peak extra = one gathered [K*n/m, N] block per group)."""
    n, m, g, gr = a.n, a.m, a.g, a.gr
    val, blk_idx = a.val, a.blk_idx           # [R_pad, nb, n], [Gr, nc, CG]
    R_pad, nblocks, _ = val.shape
    Gr = blk_idx.shape[0]
    K_pad = nblocks * m
    K, N = b.shape
    b_p = jnp.pad(b, ((0, K_pad - K), (0, 0)))

    pats = jnp.asarray(nm_patterns(n, m))     # [C, n]
    pos_pat = jnp.repeat(pats, g, axis=0)     # [CG, n]: pattern of position
    nchunks = blk_idx.shape[1]
    # compressed B-row index per (fiber-group, position, l): [Gr, nb*n]
    cols = blk_idx[..., None] * m + pos_pat[None, None]
    cols = cols.reshape(Gr, nblocks * n)
    val_g = val.reshape(Gr, gr, nblocks * n)

    def per_group(carry, xs):
        cols_g, vals_g = xs
        bg = jnp.take(b_p, cols_g, axis=0)    # [nb*n, N]
        return carry, jnp.dot(
            vals_g.astype(jnp.float32), bg.astype(jnp.float32)
        )

    _, out = jax.lax.scan(per_group, None, (cols, val_g))  # [Gr, gr, N]
    out = out.reshape(R_pad, N)
    sd = a.sparse_dim % 2
    R = a.dense_shape[1 - sd]
    return out[:R]


def nmg_linear(x: jnp.ndarray, w: GroupedNMTensor, *,
               use_pallas: bool | None = None) -> jnp.ndarray:
    """y = x @ W for an n:m:g weight W stored with sparse_dim = input axis
    (K) and groups along the output axis (N) — the serving fast path
    (paper §5.3: 'our sparse-dense GEMM kernel during inference').

    x: [..., K]  ->  y: [..., N].  Internally computes
    (W_canonical[N, K] @ x^T)^T with the spmm kernel.
    """
    lead = x.shape[:-1]
    K = x.shape[-1]
    xt = x.reshape(-1, K).T                      # [K, M]
    yt = nmg_spmm(w, xt, use_pallas=use_pallas)  # [N, M]
    y = yt.T.reshape(*lead, -1)
    return y.astype(x.dtype)


def nm_mask(x: jnp.ndarray, n: int, m: int, *, use_pallas: bool | None = None
            ) -> jnp.ndarray:
    """Boolean per-m-block top-n keep mask along the last axis."""
    if use_pallas is None:
        use_pallas = on_tpu()
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    if use_pallas:
        mask = nm_mask_pallas(x2, n, m, interpret=not on_tpu())
        return mask.astype(jnp.bool_).reshape(shape)
    return kref.nm_mask_ref(x2, n, m).reshape(shape)


def matmul_threshold(a, b, threshold: float, *, use_pallas: bool | None = None):
    """Matmul with fused streaming threshold sparsifier.
    Returns (masked values, bool mask)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas:
        val, mask = matmul_threshold_pallas(
            a, b, threshold=threshold, interpret=not on_tpu()
        )
        return val, mask.astype(jnp.bool_)
    val, mask = kref.matmul_threshold_ref(a, b, threshold)
    return val, mask

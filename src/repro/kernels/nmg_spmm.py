"""Pallas TPU kernel for n:m:g sparse-dense GEMM (paper §5.1, Fig 6 —
re-architected for the MXU; see DESIGN.md §2.1).

Computes ``C[R, N] = A @ B`` where A is the canonical [R, K(sparse)] view of
a :class:`GroupedNMTensor` and B is dense [K, N].

TPU adaptation of the paper's AVX microkernel:

* The CPU kernel broadcasts each sparse value into a vector register and
  indirectly loads B rows (Fig 6 steps 1-4), one A-row at a time.  The MXU
  instead wants dense matmuls, so the format carries a row-sharing width
  ``gr`` (the chunk permutation is shared by ``gr`` consecutive A rows) and
  the kernel **packs gathered B rows into a deep contraction**: for each
  chunk it gathers batches of ~128 compressed B rows and issues
  ``(gr × depth) @ (depth × TN)`` MXU matmuls against the contiguous
  compressed-value tile.  ``gr`` >= 8 (sublane) makes the gathers amortize;
  the paper's CPU format is the special case gr=1 (kernel still correct,
  MXU poorly utilized — use the XLA path there).
* Chunks fix the pattern order (paper: kernels "avoid branches based on the
  sparsity structure"): chunk position p carries pattern ``p // g``, a
  compile-time constant, so every gather is a *dynamic-base, static-offset*
  row slice.  The only runtime data is the m-block permutation ``blk_idx``,
  which lives in SMEM — the TPU analogue of the paper's index loads.
* The revolving-door pattern order (adjacent patterns differ in one offset)
  maximizes row reuse between consecutive gathers, mirroring the paper's
  "save and initialize only one vector register".

Two schedules share the gather/matmul body:

* ``stream=False`` — the original pipelined grid
  ``(R_pad/gr, N/TN, nchunks)`` with the chunk (K) dimension innermost so
  the output tile is revisited and accumulated in f32.
* ``stream=True`` (default) — **double-buffered weight streaming** for the
  prefill/large-M regime: grid ``(N/TN, R_pad/gr)`` with the full
  ``(K_pad, TN)`` B column slab resident in VMEM across row groups, while
  the compressed value tiles stay in HBM (``memory_space=ANY``) and are
  DMA'd chunk-by-chunk through a 2-slot VMEM buffer inside the kernel
  (async copy started for chunk k+1 while chunk k computes).  B — the
  *large* operand at prefill shapes — is loaded once per column tile
  instead of once per (row group × chunk) grid step, and weight fetch
  overlaps the MXU.  Chunk accumulation order is identical to the grid
  schedule, so the two produce bitwise-equal outputs (pinned by the
  differential suite).

VMEM working set per grid step (bf16, TN=256, gr=128, 2:4:16 => CG=96):
  val tile   gr × CG×n × 2B (× 2 slots when streaming) =  48 KiB
  B tile     CG×m × TN × 2B (full K slab when streaming)
  out tile   gr × TN × 4B            = 128 KiB
comfortably inside the ~16 MiB v5e VMEM budget for transformer K extents.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import GroupedNMTensor, nm_patterns

__all__ = ["nmg_spmm_pallas"]


def _kernel(idx_ref, val_ref, b_ref, o_ref, *, n, m, g, gr, CG, pats,
            batch_positions):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    vals = val_ref[...].reshape(gr, CG * n)  # contiguous compressed tile

    # iterate chunk positions in sub-batches sized to pack ~128-deep matmuls
    for start in range(0, CG, batch_positions):
        stop = min(start + batch_positions, CG)
        rows = []
        for p in range(start, stop):  # static unroll; pattern p//g static
            b_loc = idx_ref[0, 0, p] - ki * CG  # dynamic m-block base
            mrows = b_ref[pl.ds(b_loc * m, m), :]  # one dynamic row-slice
            rows.extend(mrows[l : l + 1, :] for l in pats[p // g])
        gathered = jnp.concatenate(rows, axis=0)  # ((stop-start)*n, TN)
        o_ref[...] += jnp.dot(
            vals[:, start * n : stop * n],
            gathered.astype(vals.dtype),
            preferred_element_type=jnp.float32,
        )


def _stream_kernel(idx_ref, val_hbm, b_ref, o_ref, scratch, sems, *, n, m, g,
                   gr, CG, pats, nchunks, batch_positions):
    """Weight-streaming schedule: value tiles DMA'd from HBM through a
    2-slot double buffer while the full B column slab stays resident."""
    gi = pl.program_id(1)

    def chunk_dma(slot, ki):
        return pltpu.make_async_copy(
            val_hbm.at[pl.ds(gi * gr, gr), pl.ds(ki * CG, CG), :],
            scratch.at[slot],
            sems.at[slot],
        )

    chunk_dma(0, 0).start()  # warm-up: chunk 0 in flight before the loop
    o_ref[...] = jnp.zeros_like(o_ref)

    def body(ki, _):
        slot = jax.lax.rem(ki, 2)

        @pl.when(ki + 1 < nchunks)
        def _prefetch():
            chunk_dma(jax.lax.rem(ki + 1, 2), ki + 1).start()

        chunk_dma(slot, ki).wait()
        vals = scratch[slot].reshape(gr, CG * n)

        # identical gather/accumulate order to the grid schedule => the two
        # streams of f32 adds match bitwise
        for start in range(0, CG, batch_positions):
            stop = min(start + batch_positions, CG)
            rows = []
            for p in range(start, stop):  # static unroll; pattern p//g static
                b_loc = idx_ref[0, ki, p]  # absolute m-block base: B holds K
                mrows = b_ref[pl.ds(b_loc * m, m), :]
                rows.extend(mrows[l : l + 1, :] for l in pats[p // g])
            gathered = jnp.concatenate(rows, axis=0)
            o_ref[...] += jnp.dot(
                vals[:, start * n : stop * n],
                gathered.astype(vals.dtype),
                preferred_element_type=jnp.float32,
            )
        return 0

    jax.lax.fori_loop(0, nchunks, body, 0)


@functools.partial(
    jax.jit, static_argnames=("tn", "interpret", "target_depth", "stream")
)
def nmg_spmm_pallas(a: GroupedNMTensor, b: jnp.ndarray, *, tn: int = 128,
                    interpret: bool = True, target_depth: int = 128,
                    stream: bool = True) -> jnp.ndarray:
    """C = A_canonical @ B via the Pallas kernel.  Returns f32 [R, N].

    ``stream`` picks the schedule: double-buffered weight streaming
    (default, the prefill path) or the original pipelined grid."""
    n, m, g, gr = a.n, a.m, a.g, a.gr
    C = math.comb(m, n)
    CG = C * g
    pats = [tuple(int(v) for v in row) for row in nm_patterns(n, m)]

    val, blk_idx = a.val, a.blk_idx
    R_pad, nblocks, _ = val.shape
    Gr, nchunks, _ = blk_idx.shape
    K_pad = nblocks * m

    # pad B to the compressed K extent and a TN multiple of columns
    K, N = b.shape
    b_p = jnp.pad(b, ((0, K_pad - K), (0, (-N) % tn)))
    N_pad = b_p.shape[1]

    batch_positions = max(1, target_depth // n)

    if stream:
        grid = (N_pad // tn, Gr)
        out = pl.pallas_call(
            functools.partial(
                _stream_kernel, n=n, m=m, g=g, gr=gr, CG=CG, pats=pats,
                nchunks=nchunks, batch_positions=batch_positions,
            ),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, nchunks, CG), lambda ni, gi: (gi, 0, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec(memory_space=pltpu.ANY),  # val stays in HBM
                # B slab constant in gi: resident across the row-group loop
                pl.BlockSpec((K_pad, tn), lambda ni, gi: (0, ni)),
            ],
            out_specs=pl.BlockSpec((gr, tn), lambda ni, gi: (gi, ni)),
            out_shape=jax.ShapeDtypeStruct((R_pad, N_pad), jnp.float32),
            scratch_shapes=[pltpu.VMEM((2, gr, CG, n), val.dtype),
                            pltpu.SemaphoreType.DMA((2,))],
            interpret=interpret,
        )(blk_idx, val, b_p)
    else:
        grid = (Gr, N_pad // tn, nchunks)
        out = pl.pallas_call(
            functools.partial(
                _kernel, n=n, m=m, g=g, gr=gr, CG=CG, pats=pats,
                batch_positions=batch_positions,
            ),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, CG), lambda gi, ni, ki: (gi, ki, 0),
                             memory_space=pltpu.SMEM),
                pl.BlockSpec((gr, CG, n), lambda gi, ni, ki: (gi, ki, 0)),
                pl.BlockSpec((CG * m, tn), lambda gi, ni, ki: (ki, ni)),
            ],
            out_specs=pl.BlockSpec((gr, tn), lambda gi, ni, ki: (gi, ni)),
            out_shape=jax.ShapeDtypeStruct((R_pad, N_pad), jnp.float32),
            interpret=interpret,
        )(blk_idx, val, b_p)

    # crop row padding (canonical row count) and column padding
    sd = a.sparse_dim % 2
    R = a.dense_shape[1 - sd]
    return out[:R, :N]

"""Pallas TPU kernel for the per-block fraction (n:m) blocking sparsifier.

Computes the keep-mask of per-m-block top-n selection along the last axis —
the first pass of the paper's two-pass blocking sparsifier (Table 1), and the
hot path of weight re-sparsification after optimizer updates (paper §5.2
notes conversion performance is critical during training).

Rank-based selection: element i of a block is kept iff
``#{j : |x_j| > |x_i|  or  (|x_j| == |x_i| and j < i)} < n`` — an O(m^2)
comparison network that is fully vectorized on the VPU (m <= 16), avoids
sorting, and reproduces jax.lax.top_k's lowest-index tie-breaking exactly
(so the Pallas kernel and the jnp oracle agree bit-for-bit).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["nm_mask_pallas"]


def _kernel(x_ref, o_ref, *, n, m):
    tr, tk = x_ref.shape
    nb = tk // m
    a = jnp.abs(x_ref[...]).reshape(tr, nb, m)
    ai = a[..., :, None]  # |x_i|
    aj = a[..., None, :]  # |x_j|
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (tr, nb, m, m), 2)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (tr, nb, m, m), 3)
    beats = (aj > ai) | ((aj == ai) & (iota_j < iota_i))
    rank = jnp.sum(beats.astype(jnp.int32), axis=3)  # [tr, nb, m]
    keep = (rank < n).astype(o_ref.dtype).reshape(tr, tk)
    o_ref[...] = keep


@functools.partial(jax.jit, static_argnames=("n", "m", "tr", "tk", "interpret"))
def nm_mask_pallas(x: jnp.ndarray, n: int, m: int, *, tr: int = 256,
                   tk: int = 512, interpret: bool = True) -> jnp.ndarray:
    """Keep-mask (float32 0/1) of per-m-block top-n along the last axis.

    x: [R, K]; K is zero-padded to a multiple of lcm(tk, m) internally.
    Zero-padding is safe: padded entries rank below any real |x| > 0 and the
    pad region is cropped from the output.
    """
    assert x.ndim == 2
    R, K = x.shape
    tk = max(m, (tk // m) * m)
    x_p = jnp.pad(x, (((0, (-R) % tr), (0, (-K) % tk))))
    Rp, Kp = x_p.shape

    out = pl.pallas_call(
        functools.partial(_kernel, n=n, m=m),
        grid=(Rp // tr, Kp // tk),
        in_specs=[pl.BlockSpec((tr, tk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tr, tk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Rp, Kp), jnp.float32),
        interpret=interpret,
    )(x_p)
    return out[:R, :K]

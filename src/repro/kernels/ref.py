"""Pure-jnp oracles for every Pallas kernel in this package.

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import nmg
from repro.core.layouts import GroupedNMTensor

__all__ = ["nmg_spmm_ref", "nmg_qkv_ref", "nmg_ffn_ref", "nm_mask_ref",
           "matmul_threshold_ref"]


def nmg_spmm_ref(a: GroupedNMTensor, b: jnp.ndarray) -> jnp.ndarray:
    """C = A_canonical @ B where A_canonical is the [R(group), K(sparse)]
    view of the n:m:g tensor (densify-then-matmul oracle)."""
    dense = a.to_dense()
    if a.sparse_dim % 2 == 0:  # canonical view is the transpose
        dense = dense.T
    return jnp.dot(dense.astype(jnp.float32), b.astype(jnp.float32))


def nmg_qkv_ref(ws, b: jnp.ndarray) -> tuple:
    """Fused-QKV oracle: nothing but one :func:`nmg_spmm_ref` per
    projection — the megakernel tests diff the single-launch kernels
    against this trivially-auditable composition."""
    return tuple(nmg_spmm_ref(w, b) for w in ws)


def nmg_ffn_ref(w: GroupedNMTensor, b: jnp.ndarray, *, act: str = "silu"
                ) -> jnp.ndarray:
    """Fused gated-FFN oracle: project the packed [D, 2F] weight with
    :func:`nmg_spmm_ref`, split into the u/gate halves along the output
    rows, apply the activation, multiply.  [F, M] f32."""
    hh = nmg_spmm_ref(w, b)                    # [2F, M]
    u, v = jnp.split(hh, 2, axis=0)
    if act == "silu":
        f = jax.nn.silu
    else:
        f = functools.partial(jax.nn.gelu, approximate=True)
    return f(u) * v


def nm_mask_ref(x: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Per-m-block top-n keep mask along the last axis (ties -> lowest
    index, matching jax.lax.top_k)."""
    return nmg.nm_mask(x, n, m).astype(jnp.bool_)


def matmul_threshold_ref(a, b, threshold: float):
    """Dense matmul followed by a scalar-threshold streaming sparsifier:
    returns (masked values, keep mask)."""
    y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    mask = jnp.abs(y) >= threshold
    return y * mask, mask

"""Pallas TPU kernel: dense matmul with a fused *streaming* sparsifier
epilogue (paper §3.3: "streaming sparsifiers could be fused into their
associated operator").

This is the kernel-level realization of STen's inline-sparsifier concept:
``C = A @ B`` is tiled on the MXU, and in the epilogue of the final K-step a
scalar-threshold streaming sparsifier is applied *in registers*, emitting the
masked values and the keep-mask in a single pass — the dense intermediate is
never materialized in HBM.  (The dispatcher uses this via the ``inline=``
fusion hook; see core/dispatch.py.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matmul_threshold_pallas"]


def _kernel(a_ref, b_ref, oval_ref, omask_ref, *, threshold, k_steps):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        oval_ref[...] = jnp.zeros_like(oval_ref)

    oval_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(ki == k_steps - 1)
    def _epilogue():
        y = oval_ref[...]
        mask = (jnp.abs(y) >= threshold).astype(jnp.float32)
        oval_ref[...] = y * mask
        omask_ref[...] = mask

    @pl.when(ki < k_steps - 1)
    def _keep_mask_defined():
        omask_ref[...] = jnp.zeros_like(omask_ref)


@functools.partial(
    jax.jit, static_argnames=("threshold", "tm", "tn", "tk", "interpret")
)
def matmul_threshold_pallas(a, b, *, threshold: float, tm: int = 128,
                            tn: int = 128, tk: int = 128,
                            interpret: bool = True):
    """(A @ B) with fused scalar-threshold sparsifier.

    Returns (masked f32 values [M, N], f32 0/1 keep mask [M, N]).
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    a_p = jnp.pad(a, (((0, (-M) % tm), (0, (-K) % tk))))
    b_p = jnp.pad(b, (((0, (-K) % tk), (0, (-N) % tn))))
    Mp, Kp = a_p.shape
    Np = b_p.shape[1]
    k_steps = Kp // tk

    val, mask = pl.pallas_call(
        functools.partial(_kernel, threshold=threshold, k_steps=k_steps),
        grid=(Mp // tm, Np // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tk, tn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
            pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
        ],
        interpret=interpret,
    )(a_p, b_p)
    return val[:M, :N], mask[:M, :N]

"""Pallas TPU kernel for decode-shaped n:m:g sparse-dense matmul.

Computes ``C[R, M] = A @ B`` where A is the canonical [R, K(sparse)] view of
a :class:`GroupedNMTensor` and B is a *narrow* dense right operand
[K, M <= ~16] — the shape a serving decode step produces (B = the batch of
per-slot activations, transposed).  The wide-N SpMM kernel
(:mod:`repro.kernels.nmg_spmm`) tiles B columns for prefill-shaped operands;
in the decode regime that tiling degenerates (one mostly-padding column
tile), so this kernel is specialized the other way around:

* **weight-stationary, output-tiled**: the grid is ``(R_pad/gr, nchunks)``
  — each step owns a ``gr``-row output stripe and walks the chunk (K)
  dimension innermost; the compressed value tile is the large resident
  operand and the whole (padded) B chunk-slab rides along in VMEM, which is
  affordable precisely because M is tiny.
* **f32 accumulator scratch + dtype-preserving epilogue**: partial products
  accumulate in an f32 VMEM scratch across chunk steps; the *last* chunk
  step casts once into the output ref, which carries the caller-requested
  dtype.  The serving path asks for the activation dtype, eliminating the
  f32 round-trip (and the separate ``astype`` copy) the SpMM contract
  forces on ``nmg_linear``.
* The gather strategy is the same dynamic-base/static-offset row slicing as
  the SpMM kernel: chunk position p carries pattern ``p // g`` at compile
  time, so only the m-block base index (SMEM) is runtime data.

M is padded to the TPU lane width (``tm``); interpret mode (CPU tests)
accepts any padding.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import GroupedNMTensor, nm_patterns

__all__ = ["nmg_gemv_pallas", "gemv_pallas_call"]


def _kernel(idx_ref, val_ref, b_ref, o_ref, acc_ref, *, n, m, g, gr, CG,
            pats, nchunks, batch_positions):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    vals = val_ref[...].reshape(gr, CG * n)  # contiguous compressed tile

    # pack gathered B rows into ~128-deep contractions (MXU-friendly even
    # though the N side is a single narrow tile)
    for start in range(0, CG, batch_positions):
        stop = min(start + batch_positions, CG)
        rows = []
        for p in range(start, stop):  # static unroll; pattern p//g static
            b_loc = idx_ref[0, 0, p] - ki * CG  # dynamic m-block base
            mrows = b_ref[pl.ds(b_loc * m, m), :]  # one dynamic row-slice
            rows.extend(mrows[l : l + 1, :] for l in pats[p // g])
        gathered = jnp.concatenate(rows, axis=0)  # ((stop-start)*n, TM)
        acc_ref[...] += jnp.dot(
            vals[:, start * n : stop * n],
            gathered.astype(vals.dtype),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == nchunks - 1)
    def _epilogue():
        # single cast into the caller-requested output dtype
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("n", "m", "g", "gr", "out_dtype", "tm", "interpret",
                     "target_depth"),
)
def gemv_pallas_call(val: jnp.ndarray, blk_idx: jnp.ndarray, b: jnp.ndarray,
                     *, n: int, m: int, g: int, gr: int, out_dtype=None,
                     tm: int = 128, interpret: bool = True,
                     target_depth: int = 128) -> jnp.ndarray:
    """The raw decode-kernel launch on the storage arrays: one
    ``pallas_call`` over (``val`` [R_pad, nblocks, n], ``blk_idx``
    [R_pad/gr, nchunks, C*g], ``b`` [K, M]) returning the *uncropped*
    [R_pad, M] product.

    Factored out of :func:`nmg_gemv_pallas` so the fused megakernels
    (:mod:`repro.kernels.nmg_fused`) can launch the identical kernel body
    over row-concatenated operands: every output row's contraction is
    independent and runs the same per-chunk accumulation order, so fused
    and per-projection launches agree bitwise by construction."""
    C = math.comb(m, n)
    CG = C * g
    pats = [tuple(int(v) for v in row) for row in nm_patterns(n, m)]
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else jnp.float32

    R_pad, nblocks, _ = val.shape
    Gr, nchunks, _ = blk_idx.shape
    K_pad = nblocks * m

    # pad B to the compressed K extent and the lane width in M
    K, M = b.shape
    m_pad = min(tm, max(8, M)) if interpret else tm
    b_p = jnp.pad(b, ((0, K_pad - K), (0, (-M) % m_pad)))
    M_pad = b_p.shape[1]

    batch_positions = max(1, target_depth // n)
    grid = (Gr, nchunks)

    out = pl.pallas_call(
        functools.partial(
            _kernel, n=n, m=m, g=g, gr=gr, CG=CG, pats=pats,
            nchunks=nchunks, batch_positions=batch_positions,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, CG), lambda gi, ki: (gi, ki, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((gr, CG, n), lambda gi, ki: (gi, ki, 0)),
            pl.BlockSpec((CG * m, M_pad), lambda gi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((gr, M_pad), lambda gi, ki: (gi, 0)),
        out_shape=jax.ShapeDtypeStruct((R_pad, M_pad), out_dtype),
        scratch_shapes=[pltpu.VMEM((gr, M_pad), jnp.float32)],
        interpret=interpret,
    )(blk_idx, val, b_p)
    return out[:, :M]


@functools.partial(
    jax.jit, static_argnames=("out_dtype", "tm", "interpret", "target_depth")
)
def nmg_gemv_pallas(a: GroupedNMTensor, b: jnp.ndarray, *,
                    out_dtype=None, tm: int = 128, interpret: bool = True,
                    target_depth: int = 128) -> jnp.ndarray:
    """C = A_canonical @ B via the decode kernel.  Returns [R, M] in
    ``out_dtype`` (default: f32, matching the SpMM contract)."""
    out = gemv_pallas_call(a.val, a.blk_idx, b, n=a.n, m=a.m, g=a.g,
                           gr=a.gr, out_dtype=out_dtype, tm=tm,
                           interpret=interpret, target_depth=target_depth)
    # crop row padding (canonical row count)
    sd = a.sparse_dim % 2
    R = a.dense_shape[1 - sd]
    return out[:R]

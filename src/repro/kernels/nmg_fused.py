"""Decode megakernels: fused QKV and fused gated-FFN Pallas launches.

PR 4's decode kernel (:mod:`repro.kernels.nmg_gemv`) wins the serving
regime but still launches once *per projection* and re-gathers each fiber
group's activations per launch.  The paper's argument (and the Hoefler et
al. survey's) is that grouped n:m only pays when the gather cost is
amortized across the whole operator — so the decode step wants one
weight-stationary launch per fused operator, not one per weight.

Two fusions, both exploiting n:m:g storage invariants:

* **QKV** (:func:`nmg_qkv_pallas`): ``wq``/``wk``/``wv`` share the
  contraction axis (d_model) and, when sparsified together, the
  (n, m, g, gr) format.  Their compressed storage concatenates along the
  canonical output-row axis — ``val`` on rows, ``blk_idx`` on fiber
  groups, legal because conversion pads every operand's rows to a ``gr``
  multiple — so **one** ``gemv_pallas_call`` launch computes all three
  projections, gathering each fiber group's activation rows once per
  token.  Per-row contractions are independent and run the identical
  per-chunk accumulation order as three separate launches, so fused and
  sequential outputs agree **bitwise** (pinned by tests/test_megakernel).
* **Gated FFN** (:func:`nmg_ffn_pallas`): the gated-MLP packs ``w1`` and
  ``gate`` into one ``[D, 2F]`` weight; the fusion is the in-kernel gate
  epilogue.  The grid walks F/gr output stripes with *two* f32
  accumulators per step — the ``u`` stripe (rows [f, f+gr)) and its
  ``v`` partner at row offset +F — and the last chunk step casts both to
  the activation dtype and emits ``act(u) * v`` directly, exactly the op
  order ``models/transformer._sublayer_ffn`` runs after a sequential
  projection (split -> act -> multiply).  silu is bitwise-stable (the
  logistic lowers to one primitive); approximate-gelu's tanh polynomial
  may differ by ulps depending on what XLA fuses it with.

Both kernels keep the gemv contract: f32 VMEM scratch accumulation, one
dtype cast in the epilogue, M padded to the lane width.
"""

from __future__ import annotations

import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.layouts import GroupedNMTensor, nm_patterns
from repro.kernels.nmg_gemv import gemv_pallas_call

__all__ = [
    "act_fn",
    "fusable_qkv",
    "fusable_ffn",
    "fused_segments",
    "nmg_qkv_pallas",
    "nmg_ffn_pallas",
]


def act_fn(name: str):
    """The model stack's activation by name (gelu is the tanh approximation
    ``models/transformer._act`` uses — the fused epilogue must match it
    bitwise)."""
    if name == "silu":
        return jax.nn.silu
    return functools.partial(jax.nn.gelu, approximate=True)


def _canon_R(w: GroupedNMTensor) -> int:
    return w.dense_shape[1 - (w.sparse_dim % 2)]


def fusable_qkv(ws: Sequence) -> bool:
    """Static (trace-time) eligibility of a projection list for the fused
    QKV launch: all grouped n:m:g, same (n, m, g, gr) format, same
    contraction extent, same stored dtype, sparse along the input axis."""
    if not ws or not all(isinstance(w, GroupedNMTensor) for w in ws):
        return False
    w0 = ws[0]
    for w in ws:
        if (w.n, w.m, w.g, w.gr) != (w0.n, w0.m, w0.g, w0.gr):
            return False
        if w.sparse_dim % 2 != 0:  # canonical view must be [R(out), K(in)]
            return False
        if w.dense_shape[0] != w0.dense_shape[0]:  # shared K
            return False
        if w.val.shape[1:] != w0.val.shape[1:] or w.val.dtype != w0.val.dtype:
            return False
        if w.blk_idx.shape[1:] != w0.blk_idx.shape[1:]:
            return False
        if w.val.shape[0] != w.blk_idx.shape[0] * w.gr:  # rows pad to gr
            return False
    return True


def fusable_ffn(w, F: int) -> bool:
    """Static eligibility of a packed ``[D, 2F]`` gated-MLP weight for the
    dual-accumulator kernel: grouped n:m:g, sparse along the input axis,
    exactly 2F unpadded rows, and the u/v halves splitting on a fiber-group
    boundary (F divisible by gr)."""
    if not isinstance(w, GroupedNMTensor) or w.sparse_dim % 2 != 0:
        return False
    if _canon_R(w) != 2 * F or F <= 0:
        return False
    # no row padding (group boundaries must be real rows) + aligned halves
    return w.val.shape[0] == 2 * F and F % w.gr == 0


def fused_segments(ws: Sequence) -> list:
    """Per-projection (row offset in the concatenated padded operand,
    canonical row count) — where each output lands after a fused launch."""
    segs, off = [], 0
    for w in ws:
        segs.append((off, _canon_R(w)))
        off += w.val.shape[0]
    return segs


def nmg_qkv_pallas(ws: Sequence, b: jnp.ndarray, *, out_dtype=None,
                   tm: int = 128, interpret: bool = True,
                   target_depth: int = 128) -> tuple:
    """All projections of ``ws`` against one decode-shaped ``b`` [K, M] in
    a single weight-stationary launch.  Returns one [R_i, M] array per
    projection, in ``out_dtype`` (default f32)."""
    assert fusable_qkv(ws), "operands not fusable; route per-projection"
    w0 = ws[0]
    val = jnp.concatenate([w.val for w in ws], axis=0)
    blk_idx = jnp.concatenate([w.blk_idx for w in ws], axis=0)
    out = gemv_pallas_call(val, blk_idx, b, n=w0.n, m=w0.m, g=w0.g,
                           gr=w0.gr, out_dtype=out_dtype, tm=tm,
                           interpret=interpret, target_depth=target_depth)
    return tuple(out[off:off + R] for off, R in fused_segments(ws))


def _ffn_kernel(idx_u_ref, idx_v_ref, val_u_ref, val_v_ref, b_ref, o_ref,
                acc_u_ref, acc_v_ref, *, n, m, g, gr, CG, pats, nchunks,
                batch_positions, act):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        acc_u_ref[...] = jnp.zeros_like(acc_u_ref)
        acc_v_ref[...] = jnp.zeros_like(acc_v_ref)

    # same inner loop as the gemv kernel, run for the stripe's u rows and
    # its gate partner at +F — one B chunk-slab feeds both contractions
    for idx_ref, val_ref, acc_ref in (
        (idx_u_ref, val_u_ref, acc_u_ref),
        (idx_v_ref, val_v_ref, acc_v_ref),
    ):
        vals = val_ref[...].reshape(gr, CG * n)
        for start in range(0, CG, batch_positions):
            stop = min(start + batch_positions, CG)
            rows = []
            for p in range(start, stop):  # static unroll; pattern p//g static
                b_loc = idx_ref[0, 0, p] - ki * CG
                mrows = b_ref[pl.ds(b_loc * m, m), :]
                rows.extend(mrows[l : l + 1, :] for l in pats[p // g])
            gathered = jnp.concatenate(rows, axis=0)
            acc_ref[...] += jnp.dot(
                vals[:, start * n : stop * n],
                gathered.astype(vals.dtype),
                preferred_element_type=jnp.float32,
            )

    @pl.when(ki == nchunks - 1)
    def _epilogue():
        # cast first, gate second — the exact op order the sequential path
        # runs (projection epilogue cast, then split/act/multiply), so the
        # fused output is bitwise-identical to it
        u = acc_u_ref[...].astype(o_ref.dtype)
        v = acc_v_ref[...].astype(o_ref.dtype)
        o_ref[...] = act_fn(act)(u) * v


@functools.partial(
    jax.jit,
    static_argnames=("act", "out_dtype", "tm", "interpret", "target_depth"),
)
def nmg_ffn_pallas(w: GroupedNMTensor, b: jnp.ndarray, *, act: str = "silu",
                   out_dtype=None, tm: int = 128, interpret: bool = True,
                   target_depth: int = 128) -> jnp.ndarray:
    """Gated-MLP pair in one launch: ``w`` is the packed [D, 2F] weight
    (sparse_dim=0), ``b`` [D, M] the decode activations.  Returns
    ``act(u) @ gate`` = [F, M] in ``out_dtype`` (default f32)."""
    n, m, g, gr = w.n, w.m, w.g, w.gr
    C = math.comb(m, n)
    CG = C * g
    pats = [tuple(int(v) for v in row) for row in nm_patterns(n, m)]
    out_dtype = jnp.dtype(out_dtype) if out_dtype is not None else jnp.float32

    val, blk_idx = w.val, w.blk_idx
    R_pad, nblocks, _ = val.shape
    Gr, nchunks, _ = blk_idx.shape
    F = _canon_R(w) // 2
    assert fusable_ffn(w, F), "weight not fusable; route per-projection"
    half = Gr // 2
    K_pad = nblocks * m

    K, M = b.shape
    m_pad = min(tm, max(8, M)) if interpret else tm
    b_p = jnp.pad(b, ((0, K_pad - K), (0, (-M) % m_pad)))
    M_pad = b_p.shape[1]

    batch_positions = max(1, target_depth // n)
    grid = (half, nchunks)

    out = pl.pallas_call(
        functools.partial(
            _ffn_kernel, n=n, m=m, g=g, gr=gr, CG=CG, pats=pats,
            nchunks=nchunks, batch_positions=batch_positions, act=act,
        ),
        grid=grid,
        in_specs=[
            # the stripe's index row and its gate partner at group +half:
            # the same array twice under shifted index maps
            pl.BlockSpec((1, 1, CG), lambda gi, ki: (gi, ki, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, CG), lambda gi, ki: (gi + half, ki, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((gr, CG, n), lambda gi, ki: (gi, ki, 0)),
            pl.BlockSpec((gr, CG, n), lambda gi, ki: (gi + half, ki, 0)),
            pl.BlockSpec((CG * m, M_pad), lambda gi, ki: (ki, 0)),
        ],
        out_specs=pl.BlockSpec((gr, M_pad), lambda gi, ki: (gi, 0)),
        out_shape=jax.ShapeDtypeStruct((F, M_pad), out_dtype),
        scratch_shapes=[pltpu.VMEM((gr, M_pad), jnp.float32),
                        pltpu.VMEM((gr, M_pad), jnp.float32)],
        interpret=interpret,
    )(blk_idx, blk_idx, val, val, b_p)
    return out[:, :M]

"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD: intra-chunk terms are computed as (decay-weighted) quadratic
attention-like einsums; inter-chunk state is carried by a lax.scan — the
standard O(S * Q) formulation (chunk size Q), which is what makes the
``long_500k`` decode/prefill cells feasible (constant-size recurrent state).

Decode is the O(1)-per-token recurrence over ``ssm_state`` [B, H, P, N] and a
rolling depthwise-conv window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, SSMConfig, dense_init, mm

__all__ = ["init_ssm", "apply_ssm", "decode_ssm", "init_ssm_state"]


def _rms_gated(x, z, w, eps=1e-6):
    x = x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def init_ssm(key, cfg: ModelConfig):
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.num_heads(D)
    N = s.state_dim
    conv_dim = di + 2 * N
    ks = jax.random.split(key, 4)
    return {
        # in_proj packs [z(di), x(di), B(N), C(N), dt(H)]
        "in_proj": dense_init(ks[0], (D, 2 * di + 2 * N + H), cfg.jdtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), cfg.jdtype,
                             scale=0.5),
        "conv_b": jnp.zeros((conv_dim,), cfg.jdtype),
        "a_log": jnp.zeros((H,), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), cfg.jdtype),
        "out_proj": dense_init(ks[3], (di, D), cfg.jdtype),
    }


def _split_proj(proj, di, N, H):
    z = proj[..., :di]
    xs = proj[..., di : 2 * di]
    B_ = proj[..., 2 * di : 2 * di + N]
    C_ = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N :]
    return z, xs, B_, C_, dt


def _causal_conv(x, w, b):
    """Depthwise causal conv: x [B, S, C], w [W, C]."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
    )
    return out + b


def apply_ssm(p, x, cfg: ModelConfig, return_state: bool = False):
    """x: [B, S, D] -> [B, S, D] (full-sequence / prefill path).
    With ``return_state`` also returns the decode state after position S-1
    ({'conv', 'ssm'}), so prefill can hand off to decode."""
    s: SSMConfig = cfg.ssm
    B, S, D = x.shape
    di, H, N, P = s.d_inner(D), s.num_heads(D), s.state_dim, s.head_dim
    Q = min(s.chunk, S)
    Sp = -(-S // Q) * Q

    proj = mm(x, p["in_proj"])
    z, xs, B_, C_, dt = _split_proj(proj, di, N, H)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)
    conv_tail = conv_in[:, -(s.conv_width - 1):, :]  # decode conv state
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xs, B_, C_ = (conv_out[..., :di], conv_out[..., di : di + N],
                  conv_out[..., di + N :])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B, S, H]
    A = -jnp.exp(p["a_log"])                                     # [H] < 0

    # pad to chunk multiple
    def padS(t):
        return jnp.pad(t, ((0, 0), (0, Sp - S)) + ((0, 0),) * (t.ndim - 2))

    cdt = jnp.dtype(s.acc_dtype)
    xs_c = padS(xs).reshape(B, -1, Q, H, P).astype(cdt)
    B_c = padS(B_).reshape(B, -1, Q, N).astype(cdt)
    C_c = padS(C_).reshape(B, -1, Q, N).astype(cdt)
    dt_c = padS(dt).reshape(B, -1, Q, H)
    nC = Sp // Q

    a = dt_c * A  # [B, nC, Q, H] log-decay per step
    a_cum = jnp.cumsum(a, axis=2)
    # intra-chunk: L[i, j] = exp(a_cum_i - a_cum_j) for i >= j
    seg = a_cum[:, :, :, None, :] - a_cum[:, :, None, :, :]  # [B,nC,Q,Q,H]
    iq = jnp.arange(Q)
    causal = (iq[:, None] >= iq[None, :])[None, None, :, :, None]
    L = jnp.where(causal, jnp.exp(seg), 0.0).astype(cdt)
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)  # [B, nC, Q, Q]
    y_intra = jnp.einsum(
        "bcij,bcijh,bcjh,bcjhp->bcihp", cb, L, dt_c.astype(cdt), xs_c
    ).astype(jnp.float32)

    # chunk final states: S_c = sum_j exp(a_last - a_cum_j) dt_j B_j x_j^T
    decay_to_end = jnp.exp(a_cum[:, :, -1:, :] - a_cum)  # [B, nC, Q, H]
    states = jnp.einsum(
        "bcjh,bcjh,bcjn,bcjhp->bchnp", decay_to_end.astype(cdt),
        dt_c.astype(cdt), B_c, xs_c
    ).astype(jnp.float32)  # [B, nC, H, N, P]
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])  # [B, nC, H]

    def chunk_scan(h, xs_):
        st, dec = xs_
        h_new = h * dec[:, :, None, None] + st
        return h_new, h

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_final, h_prevs = jax.lax.scan(
        chunk_scan, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )  # [nC, B, H, N, P] (state entering each chunk)
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)

    y_inter = jnp.einsum(
        "bcin,bcih,bchnp->bcihp", C_c.astype(jnp.float32),
        jnp.exp(a_cum), h_prevs
    )
    y = (y_intra + y_inter).reshape(B, Sp, H, P)[:, :S]
    y = y + xs_c.astype(jnp.float32).reshape(B, Sp, H, P)[:, :S] \
        * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = _rms_gated(y, z, p["norm_w"])
    out = mm(y, p["out_proj"])
    if return_state:
        # decode state layout is [B, H, P, N]
        state = {"conv": conv_tail, "ssm": jnp.moveaxis(h_final, -2, -1)}
        return out, state
    return out, None


def init_ssm_state(cfg: ModelConfig, batch: int):
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    di, H, N, P = s.d_inner(D), s.num_heads(D), s.state_dim, s.head_dim
    conv_dim = di + 2 * N
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), cfg.jdtype),
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def decode_ssm(p, x, cfg: ModelConfig, state):
    """Single-token recurrence.  x: [B, 1, D]."""
    s: SSMConfig = cfg.ssm
    B, _, D = x.shape
    di, H, N, P = s.d_inner(D), s.num_heads(D), s.state_dim, s.head_dim

    proj = mm(x, p["in_proj"])
    z, xs, B_, C_, dt = _split_proj(proj, di, N, H)
    conv_in = jnp.concatenate([xs, B_, C_], axis=-1)  # [B, 1, conv_dim]
    window = jnp.concatenate([state["conv"], conv_in], axis=1)  # [B, W, cd]
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    )[:, None, :]
    xs, B_, C_ = (conv_out[..., :di], conv_out[..., di : di + N],
                  conv_out[..., di + N :])

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * A)  # [B, H]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bf = B_[:, 0].astype(jnp.float32)  # [B, N]
    Cf = C_[:, 0].astype(jnp.float32)
    h = state["ssm"] * dec[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhpn", dt, Bf, xh
    )
    y = jnp.einsum("bn,bhpn->bhp", Cf, h) + xh * p["d_skip"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = _rms_gated(y, z, p["norm_w"])
    new_state = {"conv": window[:, 1:], "ssm": h}
    return mm(y, p["out_proj"]), new_state

"""Attention: RoPE, memory-bounded chunked softmax attention (causal /
sliding-window / prefix-LM / softcap), GQA and MLA (latent) variants with
KV-cache decode paths.

The chunked attention streams KV blocks with an online-softmax
(running max / normalizer) under a double lax.scan, so peak memory is
O(B * cq * H * ck) instead of O(B * H * S^2) — required for the 32k-prefill
dry-run cells and keeps the HLO small for 1-CPU compiles.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (MLAConfig, ModelConfig, dense_init, mm,
                                 mm_fused_qkv)

__all__ = [
    "rope",
    "chunked_attention",
    "decode_attention",
    "init_gqa",
    "apply_gqa",
    "decode_gqa",
    "init_mla",
    "apply_mla",
    "decode_mla",
    "pos_vec",
]

NEG_INF = -1e30


def pos_vec(pos, B: int) -> jnp.ndarray:
    """Normalize a decode position to a per-batch [B] int32 vector.

    Scalar ``pos`` (the classic single-sequence decode loop) broadcasts to
    all rows; a [B] vector is passed through — the continuous-batching
    engine drives every slot at its own position."""
    p = jnp.asarray(pos, jnp.int32)
    if p.ndim == 0:
        p = jnp.broadcast_to(p[None], (B,))
    return p


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, hd] (hd even); positions: [B, S] or [S]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, S, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked attention (prefill / training)
# ---------------------------------------------------------------------------


def _block_mask(qpos, kpos, *, causal, window, prefix_len):
    """qpos [cq], kpos [ck] -> bool [cq, ck] (True = visible)."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > (qpos[:, None] - window)
    if prefix_len:
        m |= kpos[None, :] < prefix_len
    return m


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None, prefix_len: int = 0,
                      softcap: Optional[float] = None, chunk_q: int = 512,
                      chunk_k: int = 512, q_offset: int = 0,
                      compute_dtype=jnp.float32) -> jnp.ndarray:
    """q: [B, Sq, H, hd]; k, v: [B, Sk, KV, hd] (H % KV == 0).
    Online-softmax over KV chunks; returns [B, Sq, H, hd] in q.dtype."""
    B, Sq, H, hd = q.shape
    _, Sk, KV, hdv = v.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)

    cq = min(chunk_q, Sq)
    ck = min(chunk_k, Sk)
    # pad to chunk multiples
    Sq_p, Sk_p = -(-Sq // cq) * cq, -(-Sk // ck) * ck
    qp = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Sk_p - Sk), (0, 0), (0, 0)))
    nq, nk = Sq_p // cq, Sk_p // ck

    cdt = jnp.dtype(compute_dtype)
    qb = qp.reshape(B, nq, cq, KV, G, hd).astype(cdt)
    kb = kp.reshape(B, nk, ck, KV, hd).astype(cdt)
    vb = vp.reshape(B, nk, ck, KV, hdv).astype(cdt)

    kb_s = jnp.moveaxis(kb, 1, 0)  # [nk, B, ck, KV, hd]
    vb_s = jnp.moveaxis(vb, 1, 0)

    # sliding-window block skipping: a query chunk starting at qi*cq only
    # sees kv blocks intersecting (qi*cq - window, qi*cq + cq); with causal
    # masking that is a CONSTANT number of blocks, so the inner scan length
    # drops from nk to nwin — the structural local-attention win (used by
    # hymba / gemma2-local layers; a §Perf hillclimb result).
    nwin = nk
    if window is not None and causal and not prefix_len:
        nwin = min(nk, (window + cq) // ck + 2)

    def q_step(_, qi_and_block):
        qi, qblk = qi_and_block  # qblk [B, cq, KV, G, hd]
        qpos = q_offset + qi * cq + jnp.arange(cq)

        if nwin < nk:
            kstart = jnp.clip((qi * cq - window) // ck, 0, nk - nwin)
        else:
            kstart = jnp.asarray(0)

        def kv_step(carry, j):
            m_run, l_run, acc = carry
            ki = kstart + j
            kblk = jax.lax.dynamic_index_in_dim(kb_s, ki, 0, False)
            vblk = jax.lax.dynamic_index_in_dim(vb_s, ki, 0, False)
            kpos = ki * ck + jnp.arange(ck)
            valid = kpos < Sk
            s = jnp.einsum("bqkgh,bckh->bqgkc", qblk, kblk,
                           preferred_element_type=jnp.float32) * scale
            if softcap is not None:
                s = softcap * jnp.tanh(s / softcap)
            mask = _block_mask(qpos, kpos, causal=causal, window=window,
                               prefix_len=prefix_len)
            mask = mask[None, :, None, None, :] & valid[None, None, None, None, :]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqgkc,bckh->bqgkh", p.astype(cdt), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, cq, G, KV), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, cq, G, KV), jnp.float32)
        a0 = jnp.zeros((B, cq, G, KV, hdv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nwin)
        )
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]  # [B, cq, G, KV, hdv]
        return None, out

    _, outs = jax.lax.scan(
        q_step, None, (jnp.arange(nq), jnp.moveaxis(qb, 1, 0))
    )  # [nq, B, cq, G, KV, hdv]
    # restore head order: the accumulator is [..., G, KV, hdv] but the
    # caller's head index is h = kv * G + g (kv-major, matching the input
    # reshape and the decode path) — swap before flattening.
    out = jnp.moveaxis(outs, 0, 1)  # [B, nq, cq, G, KV, hdv]
    out = jnp.swapaxes(out, 3, 4).reshape(B, Sq_p, KV * G, hdv)[:, :Sq]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, softcap=None,
                     window: Optional[int] = None) -> jnp.ndarray:
    """Single-token decode: q [B, 1, H, hd]; caches [B, S, KV, hd];
    cache_len [] or [B] current valid length(s) (the new token is already
    written).  A per-batch ``cache_len`` is the continuous-batching serving
    path: every slot attends over its own prefix while sharing one
    static-shape cache."""
    B, _, H, hd = q.shape
    S = k_cache.shape[1]
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qf = q.reshape(B, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bskh->bkgs", qf, k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)
    cl = jnp.asarray(cache_len)
    if cl.ndim == 1:
        cl = cl[:, None, None, None]  # [B, 1, 1, 1] broadcast over heads/seq
    valid = pos[None, None, None, :] < cl
    if window is not None:
        valid &= pos[None, None, None, :] > (cl - 1 - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA (with optional QKV bias, local window, softcap)
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig):
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (D, H * hd), cfg.jdtype),
        "wk": dense_init(ks[1], (D, KV * hd), cfg.jdtype),
        "wv": dense_init(ks[2], (D, KV * hd), cfg.jdtype),
        "wo": dense_init(ks[3], (H * hd, D), cfg.jdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), cfg.jdtype)
        p["bk"] = jnp.zeros((KV * hd,), cfg.jdtype)
        p["bv"] = jnp.zeros((KV * hd,), cfg.jdtype)
    return p


def _qkv(p, x, cfg: ModelConfig, positions):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    # one megakernel launch for all three projections when the weights are
    # grouped n:m:g and x is decode-shaped; bitwise-equal mm() fallback
    # otherwise
    q, k, v = mm_fused_qkv(x, p["wq"], p["wk"], p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_gqa(p, x, cfg: ModelConfig, *, is_local=False, prefix_len=0,
              positions=None, causal=True):
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _qkv(p, x, cfg, positions)
    window = cfg.local_window if is_local else None
    out = chunked_attention(
        q, k, v, causal=causal, window=window, prefix_len=prefix_len,
        softcap=cfg.attn_softcap, chunk_q=cfg.attn_chunk_q,
        chunk_k=cfg.attn_chunk_k, compute_dtype=cfg.attn_dtype,
    )
    return mm(out.reshape(B, S, -1), p["wo"]), (k, v)


def decode_gqa(p, x, cfg: ModelConfig, cache, pos, *, is_local=False):
    """x [B, 1, D]; cache {'k','v'} [B, S, KV, hd]; pos [] or [B] int32."""
    B = x.shape[0]
    pv = pos_vec(pos, B)
    q, k, v = _qkv(p, x, cfg, pv[:, None])
    rows = jnp.arange(B)
    kc = cache["k"].at[rows, pv].set(k[:, 0])
    vc = cache["v"].at[rows, pv].set(v[:, 0])
    window = cfg.local_window if is_local else None
    out = decode_attention(q, kc, vc, pv + 1, softcap=cfg.attn_softcap,
                           window=window)
    y = mm(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek family)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig):
    mla: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk_hd = mla.qk_nope_head_dim + mla.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], (D, mla.q_lora_rank), cfg.jdtype),
        "wuq": dense_init(ks[1], (mla.q_lora_rank, H * qk_hd), cfg.jdtype),
        "wdkv": dense_init(ks[2], (D, mla.kv_lora_rank), cfg.jdtype),
        "wuk": dense_init(
            ks[3], (mla.kv_lora_rank, H * mla.qk_nope_head_dim), cfg.jdtype
        ),
        "wuv": dense_init(
            ks[4], (mla.kv_lora_rank, H * mla.v_head_dim), cfg.jdtype
        ),
        "wkr": dense_init(ks[5], (D, mla.qk_rope_head_dim), cfg.jdtype),
        "wo": dense_init(ks[6], (H * mla.v_head_dim, D), cfg.jdtype),
        "q_norm": jnp.ones((mla.q_lora_rank,), cfg.jdtype),
        "kv_norm": jnp.ones((mla.kv_lora_rank,), cfg.jdtype),
    }


def _rms(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def apply_mla(p, x, cfg: ModelConfig, *, positions=None, causal=True):
    mla: MLAConfig = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    nd, rd, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    if positions is None:
        positions = jnp.arange(S)

    cq = _rms(mm(x, p["wdq"]), p["q_norm"])
    q = (mm(cq, p["wuq"])).reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv = _rms(mm(x, p["wdkv"]), p["kv_norm"])
    k_nope = (mm(ckv, p["wuk"])).reshape(B, S, H, nd)
    v = (mm(ckv, p["wuv"])).reshape(B, S, H, vd)
    k_rope = rope((mm(x, p["wkr"])).reshape(B, S, 1, rd), positions,
                  cfg.rope_theta)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, rd))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = chunked_attention(q_full, k, v, causal=causal,
                            chunk_q=cfg.attn_chunk_q,
                            chunk_k=cfg.attn_chunk_k,
                            compute_dtype=cfg.attn_dtype)
    return mm(out.reshape(B, S, -1), p["wo"]), ckv, k_rope


def decode_mla(p, x, cfg: ModelConfig, cache, pos, q_cache=None,
               dq_cache=None):
    """Absorbed-MLA decode over the *compressed* cache (the serving memory
    win that motivates MLA): cache = {'ckv' [B, S, r], 'kr' [B, S, rd]}.

    Scores in latent space: q_nope is absorbed through W_uk so attention
    reads c_kv directly; output re-expands through W_uv.
    """
    mla: MLAConfig = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    nd, rd, vd = mla.qk_nope_head_dim, mla.qk_rope_head_dim, mla.v_head_dim
    r = mla.kv_lora_rank
    pv = pos_vec(pos, B)
    positions = pv[:, None]

    cq = _rms(mm(x, p["wdq"]), p["q_norm"])
    q = (mm(cq, p["wuq"])).reshape(B, 1, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    ckv_t = _rms(mm(x, p["wdkv"]), p["kv_norm"])          # [B, 1, r]
    kr_t = rope((mm(x, p["wkr"])).reshape(B, 1, 1, rd), positions,
                cfg.rope_theta).reshape(B, 1, rd)
    if q_cache is not None:
        ckv_t, kr_t = q_cache(ckv_t, cfg), q_cache(kr_t, cfg)
    rows = jnp.arange(B)
    ckv = cache["ckv"].at[rows, pv].set(ckv_t[:, 0])
    kr = cache["kr"].at[rows, pv].set(kr_t[:, 0])
    ckv_r = dq_cache(ckv) if dq_cache is not None else ckv
    kr_r = dq_cache(kr) if dq_cache is not None else kr

    # absorb: q' = q_nope @ W_uk(head)  -> latent space   [B, H, r]
    wuk = p["wuk"].reshape(r, H, nd)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_r.astype(jnp.float32))
    s += jnp.einsum("bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32),
                    kr_r.astype(jnp.float32))
    s *= 1.0 / math.sqrt(nd + rd)
    S = ckv.shape[1]
    valid = jnp.arange(S)[None, None, :] < (pv + 1)[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", pattn, ckv_r.astype(jnp.float32))
    wuv = p["wuv"].reshape(r, H, vd)
    out = jnp.einsum("bhr,rhv->bhv", out_lat, wuv.astype(jnp.float32))
    y = mm(out.reshape(B, 1, H * vd).astype(x.dtype), p["wo"])
    return y, {"ckv": ckv, "kr": kr}

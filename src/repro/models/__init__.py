from repro.models.common import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_lm,
    logits_of,
    loss_fn,
    prefill,
    prefill_into_slot,
)

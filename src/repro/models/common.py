"""Model configuration dataclasses and parameter-initialization utilities.

One unified config covers all ten assigned architectures (dense GQA, MLA,
local/global alternation + softcap, QKV bias, MoE w/ optional dense residual,
SSM/SSD, hybrid attn+SSM, enc-dec, VLM-prefix).  Models are pure functions
over nested-dict param pytrees; sharding is decided *outside* the model by
path-based rules (dist/sharding.py), keeping model code mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "ModelConfig", "dense_init",
           "mm", "mm_fused_qkv", "mm_gated"]


def mm(x, w, *, inline=None):
    """Weight application admitting sparse layouts (the paper's technique
    integrates here: FixedMaskTensor during masked training, GroupedNMTensor
    for sparse serving — dispatched through the sten registry, so the same
    registered kernels back training forwards and serving).

    ``inline`` (optional) is a streaming sparsifier fused into the matmul
    when a fused implementation is registered (paper §3.3 — e.g.
    ``ScalarThresholdSparsifier`` hits ``matmul_threshold_pallas``); the
    produced intermediate is returned masked-dense so surrounding model code
    stays dense.
    """
    from repro.core.layouts import DenseTensor, SparsityLayout

    if not isinstance(w, SparsityLayout) and inline is None:
        return x @ w

    from repro.core import ops as sten_ops

    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if isinstance(w, SparsityLayout):
        # layout signature dispatch: FixedMask -> masked matmul impl,
        # GroupedNM -> the shape-routed nmg kernels (decode-shaped x hits
        # the GEMV path, prefill-shaped x the SpMM path) — the weight is
        # never densified here; only registered impls decide its
        # representation
        y = sten_ops.linear(x2, w, inline=inline)
    else:
        # dense weight + inline sparsifier: wrap operands so dispatch sees
        # DenseTensor signatures and can pick the fused kernel
        y = sten_ops.matmul(DenseTensor(x2), DenseTensor(w), inline=inline)
    if isinstance(y, SparsityLayout):
        y = y.to_dense()
    # match the dense path's promotion semantics (x @ w), so sparsifying a
    # weight never changes a layer's output dtype; the decode GEMV kernel
    # already emits x.dtype, in which case this cast is a no-op
    out_dtype = jnp.result_type(x.dtype, w.dtype)
    if y.dtype != out_dtype:
        y = y.astype(out_dtype)
    return y.reshape(*lead, -1)


def mm_fused_qkv(x, wq, wk, wv):
    """The attention projections, through the decode megakernel when
    eligible: one weight-stationary launch computes q/k/v, gathering each
    fiber group's activations once per token instead of once per
    projection.  Ineligible groups (dense weights, mixed formats,
    prefill-shaped x, table veto) fall back to three :func:`mm` calls;
    outputs are bitwise-equal either way, so this is purely a launch-count
    optimization."""
    from repro.kernels import ops as kops

    ys = kops.maybe_fused_qkv(x, (wq, wk, wv))
    ws = (wq, wk, wv)
    if ys is None:
        return tuple(mm(x, w) for w in ws)
    # the fused route emits x.dtype (like the per-projection decode
    # kernel); apply mm()'s promotion semantics on top so fusing never
    # changes a layer's output dtype
    outs = []
    for y, w in zip(ys, ws):
        out_dtype = jnp.result_type(x.dtype, w.dtype)
        outs.append(y.astype(out_dtype) if y.dtype != out_dtype else y)
    return tuple(outs)


def mm_gated(x, w, act: str, *, inline=None):
    """The gated-MLP pair (packed [D, 2F] weight) with the activation fused
    into the projection's kernel epilogue, or **None** when the megakernel
    route is ineligible — the caller then runs the sequential
    projection/split/activation path.  Only fires when no promotion cast
    would sit between projection and gate (promotion would change where the
    activation's rounding happens, breaking fused ≡ sequential bitwise)."""
    if inline is not None:
        return None
    if jnp.result_type(x.dtype, getattr(w, "dtype", x.dtype)) != x.dtype:
        return None
    from repro.kernels import ops as kops

    return kops.maybe_fused_ffn(x, w, act=act)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_expert: int = 1024          # expert FFN hidden size
    capacity_factor: float = 1.25
    dense_residual: bool = False  # Arctic-style dense MLP in parallel
    dense_residual_ff: int = 0
    router_jitter: float = 0.0
    combine: str = "gather"   # gather | scatter (EP combine strategy)
    impl: str = "pjit"        # pjit | shmap (explicit shard_map EP)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    acc_dtype: str = "float32"   # SSD intra-chunk einsum dtype (hillclimb)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    vocab: int = 32000
    d_model: int = 1024
    n_layers: int = 8
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 0             # 0 -> d_model // n_heads
    d_ff: int = 4096
    # attention family
    attn_type: str = "gqa"        # gqa | mla | none (pure SSM) | hybrid
    qkv_bias: bool = False        # Qwen-style
    logit_softcap: Optional[float] = None      # Gemma2 final-logit softcap
    attn_softcap: Optional[float] = None       # Gemma2 attention softcap
    local_window: Optional[int] = None         # sliding-window size
    layer_pattern: str = "global"  # global | local | alt_local_global
    post_norms: bool = False       # Gemma2 pre+post block norms
    act: str = "silu"              # silu | gelu
    gated_mlp: bool = True
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # sub-family configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec (whisper): n_enc_layers > 0 enables the encoder + cross-attn
    n_enc_layers: int = 0
    # VLM: number of (precomputed, stub-frontend) prefix embeddings
    vision_prefix: int = 0
    # execution knobs (perf hillclimb surface)
    attn_chunk_q: int = 512   # attention tile sizes: smaller tiles keep
    attn_chunk_k: int = 512   # score blocks VMEM-resident (flash-style)
    attn_dtype: str = "float32"  # streamed Q/K/V dtype (bf16 halves traffic;
    #                              softmax stats/accumulator stay f32)
    kv_cache_dtype: Optional[str] = None  # e.g. "int8" (quantized KV cache)
    # numerics
    dtype: str = "bfloat16"
    # paper integration: which weights the sparsity plan targets by default
    sparse_targets: tuple = ("mlp.wi", "mlp.wo", "attn.wo")
    # fused inline sparsifier (paper §3.3): when set, the MLP up-projection
    # runs through the fused matmul+threshold kernel and the produced
    # intermediate is thresholded in-stream (kernels/fused_sparse_matmul.py)
    mlp_inline_threshold: Optional[float] = None

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def validate(self):
        assert self.n_heads % max(1, self.n_kv_heads) == 0
        if self.attn_type == "mla":
            assert self.mla is not None
        if self.attn_type in ("none", "hybrid"):
            assert self.ssm is not None
        if self.layer_pattern == "alt_local_global":
            assert self.n_layers % 2 == 0 and self.local_window
        return self

    def scaled(self, **kw) -> "ModelConfig":
        """A reduced copy for CPU smoke tests."""
        return dataclasses.replace(self, **kw)


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (standard for LM stacks)."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)

"""Mixture-of-Experts FFN with capacity-buffer dispatch (expert parallel).

Dispatch is scatter-based (no O(T*E*cap) one-hot einsum): token ranks within
each expert come from an exclusive cumsum over the [T, E] assignment matrix,
tokens are scattered into a static [E, cap, D] buffer, experts run as one
batched einsum, and results gather back weighted by the router gate.  The
buffer carries an 'expert' logical axis, so under the production mesh the
scatter/gather lower to all-to-alls across the EP ('model') axis.

Supports top-k routing (Moonlight 64e top-6) and an Arctic-style dense
residual MLP in parallel with the experts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import logical_constraint
from repro.models.common import ModelConfig, MoEConfig, dense_init, mm

__all__ = ["init_moe", "apply_moe"]


def _act(name):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


def init_moe(key, cfg: ModelConfig):
    mc: MoEConfig = cfg.moe
    D, E, F = cfg.d_model, mc.num_experts, mc.d_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (D, E), jnp.float32),
        "wi": dense_init(ks[1], (E, D, 2 * F if cfg.gated_mlp else F),
                         cfg.jdtype),
        "wo": dense_init(ks[2], (E, F, D), cfg.jdtype),
    }
    if mc.dense_residual:
        Fr = mc.dense_residual_ff or F
        p["res_wi"] = dense_init(
            ks[3], (D, 2 * Fr if cfg.gated_mlp else Fr), cfg.jdtype
        )
        p["res_wo"] = dense_init(ks[4], (Fr, D), cfg.jdtype)
    return p


def apply_moe(p, x, cfg: ModelConfig):
    """x: [B, S, D] -> [B, S, D].  Returns (out, aux_loss)."""
    mc: MoEConfig = cfg.moe
    B, S, D = x.shape
    E, k = mc.num_experts, mc.top_k
    T = B * S
    x2 = x.reshape(T, D)

    logits = (x2.astype(jnp.float32) @ p["router"])  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)            # [T, k]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32), axis=1), axis=0
    ) / k
    aux = E * jnp.sum(me * ce)

    cap = max(1, int(T * k / E * mc.capacity_factor))
    cap = -(-cap // 8) * 8  # round to 8 for TPU-friendly shapes

    # rank of each (token, slot) within its expert via exclusive cumsum
    assign = jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.int32), axis=1)  # [T,E]
    ranks_base = jnp.cumsum(assign, axis=0) - assign                    # [T,E]
    flat_e = eidx.reshape(-1)                                            # [T*k]
    tok_of_slot = jnp.repeat(jnp.arange(T), k)
    # slot order within a token is distinct experts, so base rank suffices
    pos = ranks_base[tok_of_slot, flat_e]
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    # dispatch: scatter tokens into [E, cap, D] buffers
    buf = jnp.zeros((E, cap, D), x2.dtype)
    contrib = jnp.where(keep[:, None], x2[tok_of_slot], 0)
    buf = buf.at[flat_e, pos_c].add(contrib)
    buf = logical_constraint(buf, ("expert", None, None))

    # expert FFNs as one batched einsum (runs expert-parallel over 'model')
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if cfg.gated_mlp:
        u, v = jnp.split(h, 2, axis=-1)
        h = _act(cfg.act)(u) * v
    else:
        h = _act(cfg.act)(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_buf = logical_constraint(out_buf, ("expert", None, None))

    # combine: route expert outputs back to tokens
    if mc.combine == "replicated":
        # one explicit all-gather of the expert outputs, then a LOCAL
        # gather+segment-sum — bounds the expert->token routing at
        # |out_buf| per layer instead of GSPMD's per-gather replication
        # (§Perf cell B iteration 4)
        out_buf = logical_constraint(out_buf, (None, None, None))
    if mc.combine == "scatter":
        # scatter-add from the expert-sharded buffer into token-sharded
        # output (the reverse of dispatch) — gives GSPMD a symmetric
        # expert->token routing instead of a cross-shard gather, which it
        # lowers to replication (§Perf cell B iteration 3)
        pos_drop = jnp.where(keep, pos, cap)  # out-of-bounds -> dropped
        slot_token = jnp.zeros((E, cap), jnp.int32).at[
            flat_e, pos_drop].set(tok_of_slot.astype(jnp.int32),
                                  mode="drop")
        slot_gate = jnp.zeros((E, cap), jnp.float32).at[
            flat_e, pos_drop].set((gates.reshape(-1) * keep).astype(
                jnp.float32), mode="drop")
        contrib_back = out_buf.astype(jnp.float32) * slot_gate[..., None]
        y = jnp.zeros((T, D), jnp.float32).at[
            slot_token.reshape(-1)].add(contrib_back.reshape(E * cap, D))
        y = logical_constraint(y, ("batch", None))
    else:
        slot_out = out_buf[flat_e, pos_c]                   # [T*k, D]
        slot_out = jnp.where(keep[:, None], slot_out, 0)
        w = (gates.reshape(-1) * keep).astype(jnp.float32)[:, None]
        y = jax.ops.segment_sum(slot_out.astype(jnp.float32) * w,
                                tok_of_slot, num_segments=T)

    if mc.dense_residual:
        hr = mm(x2, p["res_wi"])
        if cfg.gated_mlp:
            u, v = jnp.split(hr, 2, axis=-1)
            hr = _act(cfg.act)(u) * v
        else:
            hr = _act(cfg.act)(hr)
        y = y + (mm(hr, p["res_wo"])).astype(jnp.float32)

    return y.reshape(B, S, D).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Explicit shard_map expert parallelism (§Perf cell B end-state)
# ---------------------------------------------------------------------------


def apply_moe_shmap(p, x, cfg: ModelConfig):
    """Expert parallelism with *no* token movement (beyond-paper, §Perf B):

    batch is replicated across the EP ('model') axis under the production
    sharding, so every model-rank already holds every local token.  Each
    rank therefore (1) routes locally (identical decisions on all ranks),
    (2) dispatches only the slots destined to ITS E/ep experts into a local
    capacity buffer, (3) runs its experts, (4) combines locally and
    (5) psums partial outputs over 'model'.  Collective cost per layer =
    one [T_local, D] psum + the ZeRO weight all-gathers — vs GSPMD's
    replication of the [E, cap, D] buffers (the arctic baseline wall).
    Falls back to the pjit path when no mesh context is active.
    """
    import functools

    from jax.sharding import PartitionSpec as P

    from repro.dist.compat import shard_map
    from repro.dist.sharding import active_rules

    ctx = active_rules()
    mc: MoEConfig = cfg.moe
    if ctx is None or "model" not in ctx[0].axis_names:
        return apply_moe(p, x, cfg)
    mesh, rules = ctx
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    ep = mesh.shape["model"]
    E, k = mc.num_experts, mc.top_k
    if E % ep != 0:
        return apply_moe(p, x, cfg)
    E_loc = E // ep
    B, S, D = x.shape

    expert_p = {kk: v for kk, v in p.items()
                if kk in ("router", "wi", "wo")}

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(dp or None), {  # x over batch; weights: E over model,
            "router": P(),
            "wi": P("model", None, None),
            "wo": P("model", None, None),
        }),
        out_specs=(P(dp or None), P()),
        check_vma=False,
    )
    def body(x_loc, p_loc):
        Bl = x_loc.shape[0]
        T = Bl * S
        x2 = x_loc.reshape(T, D)
        logits = x2.astype(jnp.float32) @ p_loc["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        gates, eidx = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jnp.sum(jax.nn.one_hot(eidx, E, dtype=jnp.float32),
                              axis=1), axis=0) / k
        aux = E * jnp.sum(me * ce)
        if dp:
            aux = jax.lax.pmean(aux, dp)

        # local experts of this model-rank: [lo, lo + E_loc)
        lo = jax.lax.axis_index("model") * E_loc
        flat_e = eidx.reshape(-1)
        tok_of_slot = jnp.repeat(jnp.arange(T), k)
        mine = (flat_e >= lo) & (flat_e < lo + E_loc)
        le = jnp.where(mine, flat_e - lo, 0)

        cap = max(8, int(T * k / E * mc.capacity_factor))
        cap = -(-cap // 8) * 8
        assign = jnp.where(mine, 1, 0)
        # rank within local expert via segment-wise cumsum over slots
        onehot = jax.nn.one_hot(le, E_loc, dtype=jnp.int32) * assign[:, None]
        pos = (jnp.cumsum(onehot, axis=0) - onehot)[
            jnp.arange(T * k), le]
        keep = mine & (pos < cap)
        pos_c = jnp.where(keep, pos, cap)  # cap slot == dropped (mode drop)

        buf = jnp.zeros((E_loc, cap + 1, D), x2.dtype)
        buf = buf.at[le, pos_c].add(
            jnp.where(keep[:, None], x2[tok_of_slot], 0))
        buf = buf[:, :cap]

        h = jnp.einsum("ecd,edf->ecf", buf, p_loc["wi"])
        if cfg.gated_mlp:
            u, v = jnp.split(h, 2, axis=-1)
            h = _act(cfg.act)(u) * v
        else:
            h = _act(cfg.act)(h)
        out_buf = jnp.einsum("ecf,efd->ecd", h, p_loc["wo"])

        slot_out = out_buf[le, jnp.where(keep, pos_c, 0)]
        slot_out = jnp.where(keep[:, None], slot_out, 0)
        w = (gates.reshape(-1) * keep).astype(jnp.float32)[:, None]
        y = jax.ops.segment_sum(slot_out.astype(jnp.float32) * w,
                                tok_of_slot, num_segments=T)
        y = jax.lax.psum(y, "model")
        return y.reshape(Bl, S, D), aux  # f32: residual adds in full precision

    y, aux = body(x, expert_p)
    if mc.dense_residual:
        # the dense residual MLP stays in pjit-land: GSPMD handles a plain
        # TP-sharded FFN well, and keeping it inside shard_map would
        # replicate its compute across all EP ranks
        hr = mm(x.reshape(-1, D), p["res_wi"])
        if cfg.gated_mlp:
            u, v = jnp.split(hr, 2, axis=-1)
            hr = _act(cfg.act)(u) * v
        else:
            hr = _act(cfg.act)(hr)
        y = y + (mm(hr, p["res_wo"])).reshape(B, S, D).astype(jnp.float32)
    return y.astype(x.dtype), aux

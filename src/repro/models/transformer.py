"""Unified LM stack covering all ten assigned architectures.

Pure functions over nested-dict params.  Layers are scan-stacked (leading L
dim) to keep HLO size and compile time bounded — required for 512-device AOT
compiles on one CPU.  Families:

  * GQA decoder (qwen / starcoder2 / paligemma text / moonshot / arctic attn)
  * Gemma2: alternating local/global attention (scan over layer *pairs*),
    attention + final-logit softcaps, post-norms
  * MLA (minicpm3) with absorbed-latent decode over the compressed cache
  * MoE FFN (moonshot top-6, arctic top-2 + dense residual)
  * SSD/mamba2 (attention-free) and hymba (parallel attn+SSM heads)
  * enc-dec (whisper backbone; conv frontend is a stub per the assignment —
    ``input_specs`` feeds precomputed frame embeddings; RoPE replaces the
    original sinusoidal/learned positions to keep the stack uniform, noted in
    DESIGN.md)
  * VLM prefix (paligemma: precomputed patch embeddings + prefix-LM mask)

Sparsity (the paper's technique) integrates at every projection through
``_mm``: any weight leaf may be a SparsityLayout (FixedMaskTensor during
sparse training, GroupedNMTensor for sparse serving) and dispatches through
sten; ``tag()`` sites let SparsityBuilder plans sparsify intermediates.

Serving: ``prefill`` runs the parallel forward while *collecting* the decode
cache (per-layer K/V, MLA latents, SSM end-states, cross-attn K/V) through
the layer scan; ``decode_step`` is the one-token path over that cache.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core import ops as sten_ops
from repro.core.builder import tag
from repro.core.layouts import SparsityLayout
from repro.dist.sharding import logical_constraint
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig, dense_init

__all__ = [
    "init_lm",
    "forward",
    "loss_fn",
    "logits_of",
    "init_cache",
    "prefill",
    "prefill_into_slot",
    "decode_step",
]


from repro.models.common import mm as _mm  # sparse-aware weight apply
from repro.models.common import mm_gated


def _rms(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def _act(name):
    return jax.nn.silu if name == "silu" else functools.partial(
        jax.nn.gelu, approximate=True
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_mlp(key, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    wi = dense_init(k1, (D, 2 * F if cfg.gated_mlp else F), cfg.jdtype)
    wo = dense_init(k2, (F, D), cfg.jdtype)
    return {"wi": wi, "wo": wo}


def _init_layer(key, cfg: ModelConfig, cross: bool = False):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), cfg.jdtype),
                         "ln2": jnp.zeros((cfg.d_model,), cfg.jdtype)}
    if cfg.attn_type in ("gqa", "hybrid"):
        p["attn"] = attn.init_gqa(ks[0], cfg)
    elif cfg.attn_type == "mla":
        p["attn"] = attn.init_mla(ks[0], cfg)
    if cfg.attn_type in ("none", "hybrid"):
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
    elif cfg.attn_type != "none":  # pure-SSM blocks have no separate MLP
        p["mlp"] = _init_mlp(ks[3], cfg)
    if cross:
        p["xattn"] = attn.init_gqa(ks[4], cfg)
        p["lnx"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
    if cfg.post_norms:
        p["post_ln1"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
        p["post_ln2"] = jnp.zeros((cfg.d_model,), cfg.jdtype)
    return p


def init_lm(key, cfg: ModelConfig):
    cfg.validate()
    k_emb, k_layers, k_enc, k_head = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embedding": dense_init(k_emb, (cfg.vocab, cfg.d_model), cfg.jdtype,
                                scale=1.0),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.jdtype),
    }
    pair = cfg.layer_pattern == "alt_local_global"
    n_bodies = cfg.n_layers // 2 if pair else cfg.n_layers
    cross = cfg.n_enc_layers > 0

    def one_body(k):
        if pair:
            k1, k2 = jax.random.split(k)
            return {"local": _init_layer(k1, cfg, cross),
                    "global": _init_layer(k2, cfg, cross)}
        return _init_layer(k, cfg, cross)

    params["layers"] = jax.vmap(one_body)(jax.random.split(k_layers, n_bodies))

    if cross:
        params["enc_layers"] = jax.vmap(
            lambda k: _init_layer(k, cfg, cross=False)
        )(jax.random.split(k_enc, cfg.n_enc_layers))
        params["enc_norm"] = jnp.zeros((cfg.d_model,), cfg.jdtype)

    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab),
                                       cfg.jdtype)
    return params


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _sublayer_attn(lp, x, cfg, *, is_local, prefix_len, causal,
                   enc_out=None, collect=False):
    h = _rms(x, lp["ln1"])
    aout = jnp.zeros_like(x)
    contrib: dict[str, Any] = {}
    if "attn" in lp:
        if cfg.attn_type == "mla":
            a, ckv, kr = attn.apply_mla(lp["attn"], h, cfg, causal=causal)
            if collect:
                contrib["ckv"] = ckv
                contrib["kr"] = kr.reshape(kr.shape[0], kr.shape[1], -1)
        else:
            a, (k, v) = attn.apply_gqa(lp["attn"], h, cfg, is_local=is_local,
                                       prefix_len=prefix_len, causal=causal)
            if collect:
                contrib["k"], contrib["v"] = k, v
        aout = aout + a
    if "ssm" in lp:
        s_out, s_state = ssm_mod.apply_ssm(lp["ssm"], h, cfg,
                                           return_state=collect)
        aout = aout + s_out
        if collect:
            contrib["ssm_state"] = s_state
        if "attn" in lp:
            aout = aout * 0.5  # hymba: mean of parallel heads
    aout = tag("attn.out", aout)
    if cfg.post_norms:
        aout = _rms(aout, lp["post_ln1"])
    x = x + aout

    if enc_out is not None and "xattn" in lp:
        hx = _rms(x, lp["lnx"])
        xa, (xk, xv) = _cross_attn(lp["xattn"], hx, enc_out, cfg)
        if collect:
            contrib["xk"], contrib["xv"] = xk, xv
        x = x + xa
    return x, contrib


def _cross_attn(p, x, enc_out, cfg):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (_mm(x, p["wq"])).reshape(B, S, H, hd)
    k = (_mm(enc_out, p["wk"])).reshape(B, -1, KV, hd)
    v = (_mm(enc_out, p["wv"])).reshape(B, -1, KV, hd)
    out = attn.chunked_attention(q, k, v, causal=False,
                                 chunk_q=cfg.attn_chunk_q,
                                 chunk_k=cfg.attn_chunk_k)
    return _mm(out.reshape(B, S, -1), p["wo"]), (k, v)


def _cross_attn_cached(p, x, xk, xv, cfg):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (_mm(x, p["wq"])).reshape(B, S, H, hd)
    out = attn.chunked_attention(q, xk, xv, causal=False,
                                 chunk_q=cfg.attn_chunk_q,
                                 chunk_k=cfg.attn_chunk_k)
    return _mm(out.reshape(B, S, -1), p["wo"])


def _sublayer_ffn(lp, x, cfg):
    h = _rms(x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        if cfg.moe.impl == "shmap":
            f, aux = moe_mod.apply_moe_shmap(lp["moe"], h, cfg)
        else:
            f, aux = moe_mod.apply_moe(lp["moe"], h, cfg)
    elif "mlp" in lp:
        inline = None
        if cfg.mlp_inline_threshold is not None:
            from repro.core.sparsifiers import ScalarThresholdSparsifier
            inline = ScalarThresholdSparsifier(cfg.mlp_inline_threshold)
        if cfg.gated_mlp:
            # fused gated megakernel: projection + split + act + gate in
            # one decode launch when eligible; None -> sequential path
            # (bitwise-equal — the kernel epilogue replays these exact ops)
            hh = mm_gated(h, lp["mlp"]["wi"], cfg.act, inline=inline)
            if hh is None:
                hh = _mm(h, lp["mlp"]["wi"], inline=inline)
                u, v = jnp.split(hh, 2, axis=-1)
                hh = _act(cfg.act)(u) * v
        else:
            hh = _mm(h, lp["mlp"]["wi"], inline=inline)
            hh = _act(cfg.act)(hh)
        hh = tag("mlp.act", hh)
        f = _mm(hh, lp["mlp"]["wo"])
    else:
        return x, aux
    f = tag("mlp.out", f)
    if cfg.post_norms:
        f = _rms(f, lp["post_ln2"])
    return x + f, aux


def _layer(lp, x, cfg, *, is_local, prefix_len, causal, enc_out=None,
           collect=False):
    x, contrib = _sublayer_attn(lp, x, cfg, is_local=is_local,
                                prefix_len=prefix_len, causal=causal,
                                enc_out=enc_out, collect=collect)
    x, aux = _sublayer_ffn(lp, x, cfg)
    return x, aux, contrib


def _body_fn(cfg, prefix_len, causal, enc_out=None, collect=False):
    pair = cfg.layer_pattern == "alt_local_global"
    all_local = cfg.layer_pattern == "local"

    def body(carry, lp):
        x, aux = carry
        if pair:
            x, a1, c1 = _layer(lp["local"], x, cfg, is_local=True,
                               prefix_len=prefix_len, causal=causal,
                               enc_out=enc_out, collect=collect)
            x, a2, c2 = _layer(lp["global"], x, cfg, is_local=False,
                               prefix_len=prefix_len, causal=causal,
                               enc_out=enc_out, collect=collect)
            return (x, aux + a1 + a2), {"local": c1, "global": c2}
        x, da, c = _layer(lp, x, cfg, is_local=all_local,
                          prefix_len=prefix_len, causal=causal,
                          enc_out=enc_out, collect=collect)
        return (x, aux + da), c

    return body


def _run_encoder(params, cfg, enc_embeds, dtype, remat="none"):
    e = logical_constraint(enc_embeds.astype(dtype), ("batch", "seq", None))
    enc_body = _body_fn(cfg, 0, causal=False)
    if remat != "none":
        enc_body = jax.checkpoint(enc_body)
    (e, _), _ = jax.lax.scan(enc_body, (e, jnp.zeros((), jnp.float32)),
                             params["enc_layers"])
    return _rms(e, params["enc_norm"])


def forward(params, cfg: ModelConfig, tokens=None, *, embeds=None,
            enc_embeds=None, prefix_embeds=None, remat: str = "full",
            collect_cache: bool = False):
    """Returns (hidden [B, S, D], moe_aux[, cache_contribs]).

    ``tokens`` [B, S] int32 or ``embeds`` [B, S, D]; ``prefix_embeds`` (VLM)
    are prepended; ``enc_embeds`` (enc-dec) run through the encoder for
    cross-attention.  With ``collect_cache`` the per-layer decode-cache
    contributions are returned stacked on a leading layer axis."""
    if embeds is None:
        embeds = jnp.take(params["embedding"], tokens, axis=0)
        embeds = embeds * jnp.asarray(
            jnp.sqrt(1.0 * cfg.d_model), embeds.dtype
        )
    prefix_len = 0
    if prefix_embeds is not None:
        embeds = jnp.concatenate([prefix_embeds.astype(embeds.dtype), embeds],
                                 axis=1)
        prefix_len = prefix_embeds.shape[1]
    x = logical_constraint(embeds, ("batch", "seq", None))

    enc_out = None
    if cfg.n_enc_layers > 0:
        assert enc_embeds is not None, "enc-dec model needs encoder inputs"
        enc_out = _run_encoder(params, cfg, enc_embeds, x.dtype, remat)

    body = _body_fn(cfg, prefix_len, causal=True, enc_out=enc_out,
                    collect=collect_cache)
    if remat != "none":
        body = jax.checkpoint(body)
    (x, aux), contribs = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params["layers"]
    )
    x = _rms(x, params["final_norm"])
    if collect_cache:
        return x, aux, contribs, enc_out
    return x, aux


def logits_of(params, cfg: ModelConfig, hidden):
    head = params.get("lm_head", None)
    if head is None:
        logits = hidden @ params["embedding"].T
    else:
        logits = _mm(hidden, head)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def loss_fn(params, cfg: ModelConfig, batch, *, remat: str = "full",
            aux_weight: float = 0.01):
    """batch: {'tokens' [B,S], 'labels' [B,S], optional 'enc_embeds',
    'prefix_embeds'}.  Labels < 0 are masked out."""
    hidden, aux = forward(
        params, cfg, batch["tokens"],
        enc_embeds=batch.get("enc_embeds"),
        prefix_embeds=batch.get("prefix_embeds"),
        remat=remat,
    )
    labels = batch["labels"]
    if batch.get("prefix_embeds") is not None:
        hidden = hidden[:, batch["prefix_embeds"].shape[1]:]
    logits = logits_of(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    ll = jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"ce": loss, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ---------------------------------------------------------------------------


#: static symmetric scale for int8 KV caches (RoPE'd keys/values are O(1);
#: production would track per-head scales — documented simplification)
KV_QUANT_SCALE = 1.0 / 24.0


def _cache_dt(cfg: ModelConfig):
    return jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype else cfg.jdtype


def _q_cache(x, cfg: ModelConfig):
    """Quantize a K/V tile for storage when the cache is int8."""
    if cfg.kv_cache_dtype == "int8":
        return jnp.clip(
            jnp.round(x.astype(jnp.float32) / KV_QUANT_SCALE), -127, 127
        ).astype(jnp.int8)
    return x.astype(_cache_dt(cfg))


def _dq_cache(x, cfg: ModelConfig):
    if x.dtype == jnp.int8:
        return x.astype(cfg.jdtype) * jnp.asarray(KV_QUANT_SCALE, cfg.jdtype)
    return x


def _layer_cache(cfg: ModelConfig, B: int, S: int, enc_len: int = 0):
    c: dict[str, Any] = {}
    cdt = _cache_dt(cfg)
    if cfg.attn_type in ("gqa", "hybrid"):
        kv, hd = cfg.n_kv_heads, cfg.hd
        c["k"] = jnp.zeros((B, S, kv, hd), cdt)
        c["v"] = jnp.zeros((B, S, kv, hd), cdt)
    elif cfg.attn_type == "mla":
        c["ckv"] = jnp.zeros((B, S, cfg.mla.kv_lora_rank), cdt)
        c["kr"] = jnp.zeros((B, S, cfg.mla.qk_rope_head_dim), cdt)
    if cfg.attn_type in ("none", "hybrid"):
        c["ssm_state"] = ssm_mod.init_ssm_state(cfg, B)
    if enc_len and cfg.n_enc_layers > 0:
        kv, hd = cfg.n_kv_heads, cfg.hd
        c["xk"] = jnp.zeros((B, enc_len, kv, hd), cfg.jdtype)
        c["xv"] = jnp.zeros((B, enc_len, kv, hd), cfg.jdtype)
    return c


def init_cache(cfg: ModelConfig, B: int, S: int, *, enc_len: int = 0,
               local_window_cache: bool = True):
    """Stacked per-layer decode cache.  For alt local/global models the
    local layers' KV cache is a ring buffer truncated to the sliding window
    (the gemma2 long-context memory saver)."""
    pair = cfg.layer_pattern == "alt_local_global"
    n_bodies = cfg.n_layers // 2 if pair else cfg.n_layers

    def stack(make):
        one = make()
        return jax.tree_util.tree_map(
            lambda l: jnp.zeros((n_bodies,) + l.shape, l.dtype), one
        )

    if pair:
        S_local = min(S, cfg.local_window) if (
            local_window_cache and cfg.local_window) else S
        return {
            "local": stack(lambda: _layer_cache(cfg, B, S_local, enc_len)),
            "global": stack(lambda: _layer_cache(cfg, B, S, enc_len)),
        }
    return stack(lambda: _layer_cache(cfg, B, S, enc_len))


def _decode_layer(lp, x, cfg, cache, pos, *, is_local):
    h = _rms(x, lp["ln1"])
    aout = jnp.zeros_like(x)
    new_cache = dict(cache)
    if "attn" in lp:
        if cfg.attn_type == "mla":
            a, upd = attn.decode_mla(
                lp["attn"], h, cfg,
                {"ckv": cache["ckv"], "kr": cache["kr"]}, pos,
                q_cache=_q_cache if cfg.kv_cache_dtype else None,
                dq_cache=(lambda z: _dq_cache(z, cfg))
                if cfg.kv_cache_dtype else None)
        else:
            a, upd = _decode_gqa_at(lp["attn"], h, cfg, cache, pos,
                                    is_local=is_local)
        new_cache.update(upd)
        aout = aout + a
    if "ssm" in lp:
        s_out, s_state = ssm_mod.decode_ssm(lp["ssm"], h, cfg,
                                            cache["ssm_state"])
        new_cache["ssm_state"] = s_state
        aout = aout + s_out
        if "attn" in lp:
            aout = aout * 0.5
    if cfg.post_norms:
        aout = _rms(aout, lp["post_ln1"])
    x = x + aout

    if "xattn" in lp and "xk" in cache:
        hx = _rms(x, lp["lnx"])
        x = x + _cross_attn_cached(lp["xattn"], hx, cache["xk"], cache["xv"],
                                   cfg)

    x, _ = _sublayer_ffn(lp, x, cfg)
    return x, new_cache


def _decode_gqa_at(p, x, cfg, cache, pos, *, is_local):
    """GQA decode; local layers with a window-sized cache use it as a ring
    buffer (write at pos % S_cache).  ``pos`` is a per-batch [B] vector —
    slots in a continuous batch each write/attend at their own position."""
    B = x.shape[0]
    pv = attn.pos_vec(pos, B)
    q, k, v = attn._qkv(p, x, cfg, pv[:, None])
    S_c = cache["k"].shape[1]
    ring = bool(is_local and cfg.local_window and S_c <= cfg.local_window)
    wpos = (pv % S_c) if ring else pv
    rows = jnp.arange(B)
    kc = cache["k"].at[rows, wpos].set(_q_cache(k[:, 0], cfg))
    vc = cache["v"].at[rows, wpos].set(_q_cache(v[:, 0], cfg))
    kd, vd = _dq_cache(kc, cfg), _dq_cache(vc, cfg)
    if ring:
        n_valid = jnp.minimum(pv + 1, S_c)
        out = attn.decode_attention(q, kd, vd, n_valid,
                                    softcap=cfg.attn_softcap)
    else:
        window = cfg.local_window if is_local else None
        out = attn.decode_attention(q, kd, vd, pv + 1,
                                    softcap=cfg.attn_softcap, window=window)
    y = _mm(out.reshape(B, 1, -1), p["wo"])
    return y, {"k": kc, "v": vc}


def decode_step(params, cfg: ModelConfig, token, cache, pos):
    """token [B, 1] int32; pos [] or [B] int32 (per-slot positions for the
    continuous-batching engine); returns (logits [B, V], new cache)."""
    x = jnp.take(params["embedding"], token, axis=0)
    x = x * jnp.asarray(jnp.sqrt(1.0 * cfg.d_model), x.dtype)
    x = logical_constraint(x, ("batch", None, None))
    pos = attn.pos_vec(pos, token.shape[0])
    pair = cfg.layer_pattern == "alt_local_global"
    all_local = cfg.layer_pattern == "local"

    def body(carry, xs):
        h = carry
        lp, c = xs
        if pair:
            h, cl = _decode_layer(lp["local"], h, cfg, c["local"], pos,
                                  is_local=True)
            h, cg = _decode_layer(lp["global"], h, cfg, c["global"], pos,
                                  is_local=False)
            return h, {"local": cl, "global": cg}
        h, c2 = _decode_layer(lp, h, cfg, c, pos, is_local=all_local)
        return h, c2

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = _rms(x, params["final_norm"])
    logits = logits_of(params, cfg, x)[:, 0]
    return logits, new_cache


def _to_cache_dtype(piece, dst_dtype):
    """Cast a collected contribution to the cache dtype, quantizing when the
    cache is int8."""
    if dst_dtype == jnp.int8 and piece.dtype != jnp.int8:
        piece = jnp.clip(
            jnp.round(piece.astype(jnp.float32) / KV_QUANT_SCALE), -127, 127)
    return piece.astype(dst_dtype)


@functools.lru_cache(maxsize=None)
def _seq_leaf_kinds(cfg: ModelConfig, enc_len: int):
    """Which cache leaves carry a sequence axis: probe ``init_cache`` at
    two lengths (shape-only, via eval_shape) and mark the leaves whose
    shape varies.  K/V, MLA latents vary; SSM conv/ssd states and cross
    K/V (sized by enc_len) do not.  Probe lengths are tiny so even ring
    (window-clamped) leaves are classified as sequence leaves."""
    probe = lambda s: jax.eval_shape(  # noqa: E731
        lambda: init_cache(cfg, 1, s, enc_len=enc_len)
    )
    return jax.tree_util.tree_map(
        lambda a, b: a.shape != b.shape, probe(2), probe(3)
    )


def _write_slot_leaf(dst, src, slot, offset, is_seq):
    """Write one request's collected cache leaf into batch row ``slot``.

    dst [L, B_slots, ...] is a serving cache leaf; src [L, 1, ...] the
    corresponding prefill contribution.  Sequence leaves (K/V, MLA
    latents) gain a seq axis in dst: the row for absolute position p is
    ``p % S_cache``, so ring (sliding-window) caches stay aligned with the
    decode path's ``pos % S_cache`` writes for *any* prompt length, and
    full-size caches (S_cache >= offset + S) get the identity placement.
    State leaves (SSM conv/ssd states, cross K/V) are overwritten
    wholesale; ``is_seq`` comes from :func:`_seq_leaf_kinds`, not shape
    coincidence, so a prompt that exactly fills the cache still honors
    ``offset``."""
    src = src[:, 0]  # [L, ...]
    if not is_seq:  # state leaf
        assert dst.shape[2:] == src.shape[1:], (dst.shape, src.shape)
        return dst.at[:, slot].set(_to_cache_dtype(src, dst.dtype))
    assert dst.ndim == src.ndim + 1 and dst.shape[3:] == src.shape[2:], (
        dst.shape, src.shape)
    S_c, S_src = dst.shape[2], src.shape[1]
    take = min(S_src, S_c)  # ring caches keep the tail
    piece = _to_cache_dtype(src[:, -take:], dst.dtype)
    rows = (jnp.asarray(offset) + (S_src - take)
            + jnp.arange(take, dtype=jnp.int32)) % S_c
    return dst.at[:, slot, rows].set(piece)


def prefill(params, cfg: ModelConfig, tokens, cache_len: int | None = None, *,
            enc_embeds=None, prefix_embeds=None, cache=None, slot=None,
            write_offset=0):
    """Parallel forward that also materializes the decode cache.

    Returns (last-position logits [B, V], cache).  Two modes:

    * ``cache_len`` given (classic): allocates a fresh ``cache_len``-sized
      cache and writes the collected per-layer K/V (and MLA latents / SSM
      end-states / cross K-V) at positions [0, S) for the whole batch.
    * ``cache`` + ``slot`` given (serving): ``tokens`` is a single request
      [1, S] and the contributions are written *into* the existing
      static-shape slot cache at batch row ``slot``, seq offset
      ``write_offset`` — the continuous-batching admission path.  ``slot``
      and ``write_offset`` may be traced, so one compiled prefill serves
      every slot.  NOTE: the contributions carry RoPE phases computed from
      position 0 and the forward pass does not read the existing cache, so
      a nonzero ``write_offset`` only *places* rows — prefix-continuation
      prefill (RoPE offset + attention over cached prefix rows) is not yet
      implemented; the engine always admits at offset 0.
    """
    B, S = tokens.shape
    hidden, _, contribs, enc_out = forward(
        params, cfg, tokens, enc_embeds=enc_embeds,
        prefix_embeds=prefix_embeds, remat="none", collect_cache=True,
    )
    logits = logits_of(params, cfg, hidden[:, -1:])[:, 0]

    if cache is not None:
        assert slot is not None, "slot-mode prefill needs a slot index"
        assert B == 1, "slot-mode prefill admits one request at a time"
        kinds = _seq_leaf_kinds(
            cfg, enc_embeds.shape[1] if enc_embeds is not None else 0
        )
        cache = jax.tree_util.tree_map(
            lambda d, s, isq: _write_slot_leaf(d, s, slot, write_offset,
                                               isq),
            cache, contribs, kinds,
        )
        return logits, cache

    assert cache_len is not None, "prefill needs cache_len or cache+slot"
    enc_len = enc_embeds.shape[1] if enc_embeds is not None else 0
    cache = init_cache(cfg, B, cache_len, enc_len=enc_len)

    def place(dst, src):
        # dst [L, B, S_cache, ...] vs src [L, B, S_seen, ...]: leaves differ
        # only on the seq axis (2).  Ring (window) caches keep the last
        # S_cache entries; ring write positions assume S % S_cache == 0
        # (holds for the assigned shapes: 32768/524288 vs window 4096).
        if dst.shape == src.shape:
            return _to_cache_dtype(src, dst.dtype)
        assert (dst.ndim == src.ndim and dst.shape[:2] == src.shape[:2]
                and dst.shape[3:] == src.shape[3:]), (dst.shape, src.shape)
        take = min(src.shape[2], dst.shape[2])
        piece = src[:, :, -take:]
        return jax.lax.dynamic_update_slice(
            dst, _to_cache_dtype(piece, dst.dtype), (0,) * dst.ndim
        )

    cache = jax.tree_util.tree_map(place, cache, contribs)
    return logits, cache


def prefill_into_slot(params, cfg: ModelConfig, tokens, cache, slot, *,
                      write_offset=0, enc_embeds=None, prefix_embeds=None):
    """Admit one request into a serving cache: prefill ``tokens`` [1, S] and
    write its cache contributions into batch row ``slot`` at
    ``write_offset``.  Returns (last-position logits [1, V], cache)."""
    return prefill(params, cfg, tokens, enc_embeds=enc_embeds,
                   prefix_embeds=prefix_embeds, cache=cache, slot=slot,
                   write_offset=write_offset)

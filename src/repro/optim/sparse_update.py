"""Sparse-aware parameter updates (paper §3.4 / §4, Fig 2 right).

In PyTorch-STen the in-place weight update is replaced by "calculate the
updated weights into a new tensor [and] sparsify using SameFormatSparsifier".
In JAX the optimizer is already functional, so this module is exactly that
missing piece: after the dense-math update, every sparse-layout parameter is
re-sparsified to its own format — cheap fixed-pattern masking most steps, a
full pattern recompute when the schedule says so (paper Fig 9: 'fixed' vs
'new' sparsification).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import OutFormat
from repro.core.layouts import (
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    SparsityLayout,
)
from repro.core.sparsifiers import (
    SameFormatSparsifier,
    ScalarFractionSparsifier,
)
from repro.core.autograd import sparsify_grads

__all__ = ["resparsify_params", "sparse_aware_update"]


def resparsify_params(params, *, recompute_pattern: bool = False,
                      target_sparsity=None):
    """Apply SameFormatSparsifier to every sparse-layout leaf.

    ``target_sparsity`` (optional, may be a traced scalar — the in-jit GMP
    ramp) overrides the recompute density for FixedMask leaves whose origin
    is a ``ScalarFractionSparsifier`` (or unrecorded): the pattern is
    recomputed by global magnitude at that sparsity instead of the origin's
    build-time fraction.  Every other origin (n:m / n:m:g, block-wise,
    random) keeps its native recompute — its pattern structure is a format
    property, not a schedule knob.
    """
    sp = SameFormatSparsifier(fixed_pattern=not recompute_pattern)

    def visit(leaf):
        if isinstance(leaf, FixedMaskTensor) and recompute_pattern:
            # recompute sees the RAW value buffer (STE regrowth: pruned
            # weights keep receiving updates and may re-enter the mask)
            if target_sparsity is not None and (
                    leaf.origin is None
                    or isinstance(leaf.origin, ScalarFractionSparsifier)):
                from repro.core import nmg
                mask = nmg.unstructured_mask(
                    leaf.val, target_sparsity
                ).astype(jnp.bool_)
                return FixedMaskTensor(leaf.val * mask, mask, leaf.origin)
            return sp.resparsify(leaf, leaf.val)
        if isinstance(leaf, GroupedNMTensor) and leaf.val.ndim == 4:
            # scan-stacked [L, ...] layout: regather per layer
            return jax.vmap(lambda t: sp.resparsify(t, t.to_dense()))(leaf)
        if isinstance(leaf, NMTensor) and leaf.val.ndim == \
                len(leaf.dense_shape) + 2:
            return jax.vmap(lambda t: sp.resparsify(t, t.to_dense()))(leaf)
        if isinstance(leaf, (FixedMaskTensor, GroupedNMTensor, NMTensor)):
            return sp.resparsify(leaf, leaf.to_dense())
        return leaf

    return jax.tree_util.tree_map(
        visit, params, is_leaf=lambda x: isinstance(x, SparsityLayout)
    )


def sparse_aware_update(update_fn, grads, state, params, *,
                        grad_formats: Optional[dict] = None,
                        recompute_pattern=False, target_sparsity=None, **kw):
    """Optimizer update + STen semantics:

    1. sparsify gradients per the builder's grad formats (paper §3.4
       ``set_weight_grad``);
    2. dense-math optimizer update (moments over stored values);
    3. re-sparsify sparse params (SameFormatSparsifier) — fixed pattern by
       default, recomputed when the sparsification schedule triggers.

    ``recompute_pattern`` may be a Python bool or a traced bool; the traced
    case uses lax.cond over the two re-sparsification paths, which is how
    the jitted multi-step trainer (launch/train.py) runs GMP pattern
    recomputes fully on device.  ``target_sparsity`` (static or traced)
    sets the recompute density for unstructured FixedMask params — the
    GMP ramp's current level.
    """
    if grad_formats:
        grads = sparsify_grads(grads, grad_formats)
    new_params, new_state, metrics = update_fn(grads, state, params, **kw)
    if isinstance(recompute_pattern, bool):
        new_params = resparsify_params(
            new_params, recompute_pattern=recompute_pattern,
            target_sparsity=target_sparsity if recompute_pattern else None,
        )
    else:
        tgt = (jnp.asarray(target_sparsity, jnp.float32)
               if target_sparsity is not None else None)
        new_params = jax.lax.cond(
            recompute_pattern,
            lambda p: resparsify_params(p, recompute_pattern=True,
                                        target_sparsity=tgt),
            lambda p: resparsify_params(p, recompute_pattern=False),
            new_params,
        )
    return new_params, new_state, metrics

"""Optimizers (AdamW, SGD-momentum) over possibly-sparse param pytrees.

Sparse layouts are pytrees, so optimizer states simply mirror every *inexact*
array leaf (values, masks-as-float, etc.); integer/bool metadata leaves
(CSR indices, n:m:g blk_idx, boolean masks) carry no moments and pass through
unchanged.  Under pjit the moment trees inherit the params' shardings, which
is ZeRO-3: every FSDP-sharded weight has FSDP-sharded optimizer state.

``value_and_grad_sparse`` wraps jax.value_and_grad with ``allow_int=True``
(required: layout metadata is integer) and normalizes float0 cotangents to
None so downstream tree_maps stay simple.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import dtypes

__all__ = ["AdamWConfig", "adamw_init", "adamw_update",
           "value_and_grad_sparse", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # weight decay applies only to >=2-D tensors (not norms/biases/masks)
    decay_min_ndim: int = 2


def _is_moment_leaf(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.inexact)


def adamw_init(params):
    """Moment trees mirror inexact leaves; momentum in f32 (master moments)."""
    def init(x):
        if _is_moment_leaf(x):
            return jnp.zeros(x.shape, jnp.float32)
        return None

    mu = jax.tree_util.tree_map(init, params)
    nu = jax.tree_util.tree_map(init, params)
    return {"mu": mu, "nu": nu, "step": jnp.zeros((), jnp.int32)}


def clip_by_global_norm(grads, max_norm: float):
    leaves = [
        g for g in jax.tree_util.tree_leaves(grads)
        if g is not None and _is_moment_leaf(g)
    ]
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves)
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))

    def clip(g):
        if g is None or not _is_moment_leaf(g):
            return g
        return g * scale.astype(g.dtype)

    return jax.tree_util.tree_map(clip, grads, is_leaf=lambda x: x is None), \
        gnorm


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """Returns (updated params, new state, metrics).  Sparsity-layout
    re-sparsification (SameFormatSparsifier) is applied by the caller via
    optim.sparse_update — kept separate so schedules control it."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        if g is None or mu is None or not _is_moment_leaf(p):
            return p, mu, nu
        gf = g.astype(jnp.float32)
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * gf
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(gf)
        mhat = mu2 / b1c
        nhat = nu2 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= cfg.decay_min_ndim:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), mu2, nu2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(_match_structure(grads, params))
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"gnorm": gnorm}


def _match_structure(grads, params):
    """Normalize float0 / missing cotangents to None leaves."""
    def norm(g):
        if g is None:
            return None
        if hasattr(g, "dtype") and g.dtype == dtypes.float0:
            return None
        return g

    return jax.tree_util.tree_map(norm, grads, is_leaf=lambda x: x is None)


def value_and_grad_sparse(fn: Callable, **kw):
    """jax.value_and_grad that tolerates integer/bool layout metadata."""
    vg = jax.value_and_grad(fn, allow_int=True, **kw)

    def wrapped(params, *args, **kwargs):
        val, grads = vg(params, *args, **kwargs)
        return val, _match_structure(grads, params)

    return wrapped

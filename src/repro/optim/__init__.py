from repro.optim.optimizers import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    value_and_grad_sparse,
)
from repro.optim.sparse_update import resparsify_params, sparse_aware_update
from repro.optim.gmp import GMPSchedule, gmp_sparsity

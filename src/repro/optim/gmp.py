"""Magnitude-pruning schedules (paper §2, §6.2): one-shot, iterative
(gradual magnitude pruning, Zhu & Gupta), and layer-wise.

These drive the Table-2 productivity study: each sparsifier differs only in
its schedule, a handful of lines on top of the shared machinery.
"""

from __future__ import annotations

import dataclasses

__all__ = ["GMPSchedule", "gmp_sparsity"]


@dataclasses.dataclass(frozen=True)
class GMPSchedule:
    mode: str = "iterative"     # one_shot | iterative | layer_wise
    target_sparsity: float = 0.5
    begin_step: int = 0
    end_step: int = 1000
    recompute_every: int = 100  # pattern-recompute cadence during ramp
    num_layers: int = 12        # layer_wise: layers pruned one at a time

    def sparsity_at(self, step: int) -> float:
        return gmp_sparsity(self, step)

    def recompute_at(self, step: int) -> bool:
        if self.mode == "one_shot":
            return step == self.begin_step
        if step < self.begin_step or step > self.end_step:
            return False
        return (step - self.begin_step) % max(1, self.recompute_every) == 0

    def layers_pruned_at(self, step: int) -> int:
        """layer_wise: how many leading layers are sparse at ``step``."""
        if self.mode != "layer_wise":
            return self.num_layers
        span = max(1, (self.end_step - self.begin_step) // self.num_layers)
        return min(self.num_layers, max(0, (step - self.begin_step) // span + 1))


def gmp_sparsity(s: GMPSchedule, step: int) -> float:
    """Cubic ramp (Zhu & Gupta 2017) for iterative; step function for
    one-shot; per-layer target for layer-wise."""
    if s.mode == "one_shot":
        return s.target_sparsity if step >= s.begin_step else 0.0
    if step <= s.begin_step:
        return 0.0
    if step >= s.end_step:
        return s.target_sparsity
    frac = (step - s.begin_step) / max(1, s.end_step - s.begin_step)
    return s.target_sparsity * (1.0 - (1.0 - frac) ** 3)

"""Magnitude-pruning schedules (paper §2, §6.2): one-shot, iterative
(gradual magnitude pruning, Zhu & Gupta), and layer-wise.

These drive the Table-2 productivity study: each sparsifier differs only in
its schedule, a handful of lines on top of the shared machinery.

Every query exists in two spellings: the host-side one over Python ints
(``sparsity_at`` / ``recompute_at``) and a traced one over jnp step counters
(``sparsity_at_traced`` / ``recompute_at_traced``) so the decisions can live
inside a jitted multi-step trainer (launch/train.py) as ``lax.cond``
predicates instead of host syncs.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

__all__ = ["GMPSchedule", "gmp_sparsity"]


@dataclasses.dataclass(frozen=True)
class GMPSchedule:
    mode: str = "iterative"     # one_shot | iterative | layer_wise
    target_sparsity: float = 0.5
    begin_step: int = 0
    end_step: int = 1000
    recompute_every: int = 100  # pattern-recompute cadence during ramp
    num_layers: int = 12        # layer_wise: layers pruned one at a time

    def sparsity_at(self, step: int) -> float:
        return gmp_sparsity(self, step)

    def recompute_at(self, step: int) -> bool:
        if self.mode == "one_shot":
            return step == self.begin_step
        if step < self.begin_step or step > self.end_step:
            return False
        # the ramp ends exactly at end_step: always fire a final recompute
        # there so the pattern reaches target_sparsity even when the span is
        # not a multiple of the cadence
        if step == self.end_step:
            return True
        return (step - self.begin_step) % max(1, self.recompute_every) == 0

    # -- traced spellings (jnp step counters, usable inside jit) ----------

    def sparsity_at_traced(self, step) -> jnp.ndarray:
        """``sparsity_at`` over a traced step counter (f32 scalar out).

        The cubic ramp is evaluated with the same f32 operation sequence as
        the host spelling (``gmp_sparsity``), so the two produce bitwise-
        equal levels — and therefore identical top-k counts in
        ``unstructured_mask`` — at every step.
        """
        step = jnp.asarray(step, jnp.float32)
        tgt = jnp.float32(self.target_sparsity)
        if self.mode == "one_shot":
            return jnp.where(step >= self.begin_step, tgt, 0.0)
        span = jnp.float32(max(1, self.end_step - self.begin_step))
        frac = jnp.clip((step - jnp.float32(self.begin_step)) / span,
                        0.0, 1.0)
        om = jnp.float32(1.0) - frac
        return tgt * (jnp.float32(1.0) - om * om * om)

    def recompute_at_traced(self, step) -> jnp.ndarray:
        """``recompute_at`` over a traced step counter (bool scalar out)."""
        step = jnp.asarray(step, jnp.int32)
        if self.mode == "one_shot":
            return step == self.begin_step
        in_ramp = (step >= self.begin_step) & (step <= self.end_step)
        on_cadence = (
            (step - self.begin_step) % max(1, self.recompute_every) == 0
        )
        return in_ramp & (on_cadence | (step == self.end_step))

    def layers_pruned_at(self, step: int) -> int:
        """layer_wise: how many leading layers are sparse at ``step``."""
        if self.mode != "layer_wise":
            return self.num_layers
        if step >= self.end_step:
            # the ramp is over: every layer is pruned, even when the span is
            # shorter than num_layers (integer-span schedules would
            # otherwise strand trailing layers dense forever)
            return self.num_layers
        span = max(1, (self.end_step - self.begin_step) // self.num_layers)
        return min(self.num_layers, max(0, (step - self.begin_step) // span + 1))


def gmp_sparsity(s: GMPSchedule, step: int) -> float:
    """Cubic ramp (Zhu & Gupta 2017) for iterative; step function for
    one-shot; per-layer target for layer-wise.

    The ramp is evaluated in float32 with the exact operation sequence of
    ``sparsity_at_traced`` so the host-driven reference loop and the in-jit
    fast path quantize to the same level (and hence recompute bitwise-equal
    masks) at every step — a float64 host ramp would round top-k counts
    differently on large tensors.
    """
    import numpy as _np

    if s.mode == "one_shot":
        return s.target_sparsity if step >= s.begin_step else 0.0
    if step <= s.begin_step:
        return 0.0
    if step >= s.end_step:
        return s.target_sparsity
    span = _np.float32(max(1, s.end_step - s.begin_step))
    frac = (_np.float32(step) - _np.float32(s.begin_step)) / span
    om = _np.float32(1.0) - frac
    tgt = _np.float32(s.target_sparsity)
    return float(tgt * (_np.float32(1.0) - om * om * om))

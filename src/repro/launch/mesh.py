"""Production mesh construction (assignment spec).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data", "model"); 2 pods = 512 chips
    multi-pod ("pod", "data", "model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests and
    the weak-scaling benchmark (which spawn subprocesses with
    ``--xla_force_host_platform_device_count``)."""
    return jax.make_mesh((data, model), ("data", "model"))

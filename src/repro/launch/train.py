"""Fault-tolerant training loop with integrated sparsity pipeline.

``python -m repro.launch.train --arch bert-base-sten --steps 200 --smoke``
trains the reduced config on CPU; on a real fleet the same loop runs under
the production mesh (--mesh pod).  Features:

  * sparse fine-tuning: GMP schedules (one-shot / iterative / layer-wise)
    drive per-step target sparsity; weights are FixedMaskTensors,
    re-sparsified by SameFormatSparsifier after each update, with pattern
    recomputes on the schedule's cadence (paper Figs 8-9, Table 2);
  * checkpoint/restart: async CheckpointManager, exact data-pipeline resume
    (index-addressed batches), --resume picks up LATEST;
  * straggler watchdog + elastic hooks (dist/elastic.py);
  * the jitted step donates params/opt-state (memory) and runs fully under
    pjit when a mesh is active.
"""

from __future__ import annotations

import argparse
import functools
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import FixedMaskTensor
from repro.core.sparsifiers import ScalarFractionSparsifier
from repro.data import DataConfig, SyntheticLMPipeline
from repro.dist.elastic import StragglerWatchdog
from repro.dist.sharding import ShardingRules
from repro.launch import steps as steps_mod
from repro.models import init_lm, loss_fn
from repro.optim import AdamWConfig, GMPSchedule, adamw_init
from repro.optim.sparse_update import resparsify_params


def build_sparse_params(params, sparsity: float, targets=("mlp", "attn.wo")):
    """Sparsify matching >=2-D weights to FixedMask via magnitude pruning
    (the paper's masked-training representation)."""
    sb = SparsityBuilder()
    for t in targets:
        sb.set_weight(f"*{t}*", ScalarFractionSparsifier(sparsity),
                      FixedMaskTensor)
    return sb.sparsify_params(params)


def retarget_sparsity(params, sparsity: float):
    """Recompute FixedMask patterns at a new global sparsity level
    (iterative GMP ramp)."""
    sp = ScalarFractionSparsifier(sparsity)

    def visit(leaf):
        if isinstance(leaf, FixedMaskTensor):
            dense = leaf.val  # STE: pruned weights kept in val for regrowth
            mask = sp.mask(dense)
            # keep the original origin: it is static pytree aux, and changing
            # it would desync the treedef from the optimizer moments (and
            # force a jit retrace) on every GMP retarget
            return FixedMaskTensor(dense * mask, mask, leaf.origin)
        return leaf

    return jax.tree_util.tree_map(
        visit, params, is_leaf=lambda x: isinstance(x, FixedMaskTensor)
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--gmp", choices=["one_shot", "iterative", "layer_wise"],
                    default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(key, cfg)

    gmp = None
    if args.gmp or args.sparsity > 0:
        gmp = GMPSchedule(
            mode=args.gmp or "one_shot",
            target_sparsity=args.sparsity or 0.5,
            begin_step=0 if (args.gmp or "one_shot") == "one_shot"
            else args.steps // 10,
            end_step=int(args.steps * 0.8),
            recompute_every=max(1, args.steps // 20),
            num_layers=cfg.n_layers,
        )
        params = build_sparse_params(params, gmp.sparsity_at(0))

    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params)

    data = SyntheticLMPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume:
        got = mgr.restore_latest({"params": params, "opt": opt_state})
        if got[0] is not None:
            start_step, tree, _ = got
            params, opt_state = tree["params"], tree["opt"]
            print(f"resumed from step {start_step}")

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, batch):
        from repro.optim import adamw_update, value_and_grad_sparse
        (loss, aux), grads = value_and_grad_sparse(
            lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
        )(params)
        new_p, new_s, m = adamw_update(grads, opt_state, params, opt_cfg)
        new_p = resparsify_params(new_p)  # SameFormat fixed-pattern pass
        return new_p, new_s, {"loss": loss, "gnorm": m["gnorm"]}

    watchdog = StragglerWatchdog(n_hosts=1)
    interrupted = []
    signal.signal(signal.SIGTERM, lambda *a: interrupted.append(1))

    t_start = time.time()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}

        # GMP schedule events (outside the jitted step: pattern recomputes
        # change which entries are nonzero, values stay jit-shaped)
        if gmp and gmp.recompute_at(step):
            params = retarget_sparsity(params, gmp.sparsity_at(step))

        params, opt_state, metrics = train_step(params, opt_state, batch)
        watchdog.observe(0, time.time() - t0)
        losses.append(float(metrics["loss"]))

        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} "
                  f"({time.time() - t0:.2f}s/step)", flush=True)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if interrupted:
            print("SIGTERM: checkpointing and exiting")
            if mgr:
                mgr.save(step + 1, {"params": params, "opt": opt_state},
                         blocking=True)
            return 1

    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 blocking=True)
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s; final loss {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

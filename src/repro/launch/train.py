"""Fault-tolerant training loop with integrated sparsity pipeline.

``python -m repro.launch.train --arch bert-base-sten --steps 200 --smoke``
trains the reduced config on CPU; on a real fleet the same loop runs under
the production mesh (--mesh pod).  Features:

  * sparse fine-tuning: GMP schedules (one-shot / iterative / layer-wise)
    drive per-step target sparsity; weights are FixedMaskTensors,
    re-sparsified by SameFormatSparsifier after each update, with pattern
    recomputes on the schedule's cadence (paper Figs 8-9, Table 2);
  * device-resident fast path (default): ``make_multi_step`` runs
    ``--log-every`` steps per jit call under ``lax.scan``; GMP pattern
    recomputes are an in-jit ``lax.cond`` driven by the traced step counter
    (the traced ``recompute_pattern`` path of optim/sparse_update.py), and
    metrics accumulate on device — the host syncs once per chunk, on the
    log cadence, instead of once per step;
  * ``--host-loop``: the per-step host-driven reference loop (pattern
    recomputes via host tree_map, one blocking sync per step) — kept as the
    equivalence oracle for the fast path (tests/test_train_fastpath.py);
  * checkpoint/restart: async CheckpointManager, exact data-pipeline resume
    (index-addressed batches), --resume picks up LATEST;
  * straggler watchdog + elastic hooks (dist/elastic.py);
  * the jitted step donates params/opt-state (memory) and runs fully under
    pjit when a mesh is active.
"""

from __future__ import annotations

import argparse
import functools
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import FixedMaskTensor
from repro.core.sparsifiers import ScalarFractionSparsifier
from repro.data import DataConfig, SyntheticLMPipeline
from repro.dist.elastic import StragglerWatchdog
from repro.models import init_lm, loss_fn
from repro.obs import trace as obs
from repro.obs.registry import REGISTRY
from repro.optim import (
    AdamWConfig,
    GMPSchedule,
    adamw_init,
    adamw_update,
    sparse_aware_update,
    value_and_grad_sparse,
)
from repro.optim.sparse_update import resparsify_params

__all__ = ["build_sparse_params", "retarget_sparsity", "make_train_step",
           "make_multi_step", "stack_batches", "main"]


def build_sparse_params(params, sparsity: float, targets=("mlp", "attn.wo")):
    """Sparsify matching >=2-D weights to FixedMask via magnitude pruning
    (the paper's masked-training representation)."""
    sb = SparsityBuilder()
    for t in targets:
        sb.set_weight(f"*{t}*", ScalarFractionSparsifier(sparsity),
                      FixedMaskTensor)
    return sb.sparsify_params(params)


def retarget_sparsity(params, sparsity: float):
    """Recompute sparsity patterns at a new global sparsity level (iterative
    GMP ramp) — the host-side spelling of the exact recompute the fast path
    runs in-jit: both route through ``resparsify_params`` so there is one
    recompute policy (unstructured FixedMask leaves follow the ramp, every
    other origin/layout uses its native recompute; the static ``origin``
    aux is preserved, keeping treedefs synced with optimizer moments)."""
    return resparsify_params(params, recompute_pattern=True,
                             target_sparsity=float(sparsity))


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def make_train_step(cfg, opt_cfg: AdamWConfig):
    """Single-step reference trainer (used by --host-loop): one jit call and
    one host sync per step; GMP retargets happen outside, on the host."""

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def train_step(params, opt_state, batch):
        (loss, aux), grads = value_and_grad_sparse(
            lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
        )(params)
        new_p, new_s, m = adamw_update(grads, opt_state, params, opt_cfg)
        new_p = resparsify_params(new_p)  # SameFormat fixed-pattern pass
        return new_p, new_s, {"loss": loss, "gnorm": m["gnorm"]}

    return train_step


def make_multi_step(cfg, opt_cfg: AdamWConfig, gmp: GMPSchedule | None,
                    n_inner: int):
    """Device-resident trainer: ``n_inner`` optimizer steps per jit call via
    ``lax.scan``.

    GMP semantics match the host reference exactly, shifted to the end of
    the step: the reference retargets *before* step ``s`` at
    ``sparsity_at(s)``; here the post-update re-sparsification of step
    ``s - 1`` recomputes the pattern when ``recompute_at(s)`` fires, at the
    same target — an in-jit ``lax.cond`` over the traced step counter (the
    traced ``recompute_pattern`` path of ``sparse_aware_update``), so no
    step ever blocks on the host.  Two boundary rules keep the final params
    bitwise-equal to the reference: the caller performs the single retarget
    at the very first step of a run (``recompute_at(start_step)``), which
    has no preceding in-jit step to piggyback on, and ``stop`` (the run's
    total step count) suppresses the retarget that would otherwise prepare
    the never-executed step ``stop``.

    Returns ``multi_step(params, opt_state, batches, step0, stop) ->
    (params, opt_state, metrics)`` where ``batches`` is a pytree of
    ``[n_inner, ...]`` arrays, ``step0`` the global index of the first step,
    and ``metrics`` holds per-step ``loss``/``gnorm`` arrays ([n_inner])
    that stay on device until the caller fetches them — the log-cadence
    flush.
    """

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def multi_step(params, opt_state, batches, step0, stop):
        stop = jnp.asarray(stop, jnp.int32)

        def inner(carry, xs):
            params, opt_state = carry
            batch, step = xs
            (loss, aux), grads = value_and_grad_sparse(
                lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
            )(params)
            if gmp is not None:
                nxt = step + 1
                recompute = gmp.recompute_at_traced(nxt) & (nxt < stop)
                target = gmp.sparsity_at_traced(nxt)
            else:
                recompute, target = False, None
            new_p, new_s, m = sparse_aware_update(
                lambda g_, s_, p_: adamw_update(g_, s_, p_, opt_cfg),
                grads, opt_state, params,
                recompute_pattern=recompute, target_sparsity=target,
            )
            return (new_p, new_s), {"loss": loss, "gnorm": m["gnorm"]}

        steps = jnp.asarray(step0, jnp.int32) + jnp.arange(
            n_inner, dtype=jnp.int32
        )
        (params, opt_state), metrics = jax.lax.scan(
            inner, (params, opt_state), (batches, steps)
        )
        return params, opt_state, metrics

    return multi_step


def stack_batches(data, lo: int, hi: int):
    """Host-stack the index-addressed batches for steps [lo, hi)."""
    per_step = [data.batch_at(s) for s in range(lo, hi)]
    return {
        k: jnp.asarray(np.stack([np.asarray(b[k]) for b in per_step]))
        for k in per_step[0]
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--sparsity", type=float, default=0.0)
    ap.add_argument("--gmp", choices=["one_shot", "iterative", "layer_wise"],
                    default=None)
    ap.add_argument("--host-loop", action="store_true",
                    help="per-step host-driven reference loop (GMP retarget "
                         "and metric sync on every step)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--tuning-table", default=None, metavar="PATH",
                    help="load a repro.tune table (written by "
                         "`python -m repro.tune`) before the step "
                         "compiles, so sparse kernel routing uses "
                         "measured decisions instead of shipped defaults")
    ap.add_argument("--check", action="store_true",
                    help="run the repro.check static verifier over the "
                         "train entry before the first step compiles; "
                         "abort on ERROR diagnostics")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the repro.obs flight recorder and write "
                         "a Chrome/Perfetto trace (train chunks, GMP "
                         "recomputes, per-layer sparsity, kernel routes) "
                         "to PATH on exit")
    args = ap.parse_args(argv)
    # the fast path chunks by --log-every; a non-positive value would spin
    # on zero-step chunks forever (and 0 was a ZeroDivisionError before)
    args.log_every = max(1, args.log_every)
    args.ckpt_every = max(1, args.ckpt_every)

    from repro.tune import load_table_cli

    load_table_cli(args.tuning_table)  # --tuning-table or $REPRO_TUNE_TABLE

    if args.check:
        # after the table load on purpose: routed-config diagnostics (R6)
        # must judge the same table the run is about to train under
        from repro.check import preflight

        rc = preflight(("train",), arch=args.arch)
        if rc:
            print("repro.check: train preflight failed — not training")
            return rc

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(key, cfg)

    gmp = None
    if args.gmp or args.sparsity > 0:
        gmp = GMPSchedule(
            mode=args.gmp or "one_shot",
            target_sparsity=args.sparsity or 0.5,
            begin_step=0 if (args.gmp or "one_shot") == "one_shot"
            else args.steps // 10,
            end_step=int(args.steps * 0.8),
            recompute_every=max(1, args.steps // 20),
            num_layers=cfg.n_layers,
        )
        params = build_sparse_params(params, gmp.sparsity_at(0))

    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = adamw_init(params)

    data = SyntheticLMPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    ))

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if mgr and args.resume:
        got = mgr.restore_latest({"params": params, "opt": opt_state})
        if got[0] is not None:
            start_step, tree, _ = got
            params, opt_state = tree["params"], tree["opt"]
            print(f"resumed from step {start_step}")

    watchdog = StragglerWatchdog(n_hosts=1)
    interrupted = []
    signal.signal(signal.SIGTERM, lambda *a: interrupted.append(1))

    if args.trace:
        obs.enable()
    run = _run_host_loop if args.host_loop else _run_fast
    rc = run(args, cfg, opt_cfg, gmp, params, opt_state, data, mgr,
             start_step, watchdog, interrupted)
    if args.trace:
        obs.dump(args.trace, registry_snapshot=REGISTRY.snapshot())
        print(f"wrote trace to {args.trace}")
    return rc


def _log_line(step, loss, gnorm, dt):
    print(f"step {step:5d} loss {loss:.4f} gnorm {gnorm:.3f} "
          f"({dt:.2f}s/step)", flush=True)


def _sparsity_telemetry(params, step: int) -> None:
    """Per-layer sparsity telemetry on the log cadence (flight recorder
    only — this syncs mask means to the host, so it must never run in an
    untraced hot loop).  Each FixedMask leaf becomes a registry gauge and
    one ``sparsity`` event on the train track; leaves stacked across
    layers (a leading scan axis) report per-layer means."""
    if not obs.enabled():
        return
    for path, leaf in jax.tree_util.tree_flatten_with_path(
            params, is_leaf=lambda x: isinstance(x, FixedMaskTensor))[0]:
        if not isinstance(leaf, FixedMaskTensor):
            continue
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        mask = np.asarray(leaf.mask)
        if mask.ndim >= 3:  # stacked layers: per-layer mean over axis 0
            per_layer = 1.0 - mask.reshape(mask.shape[0], -1).mean(axis=1)
            for i, s in enumerate(per_layer):
                REGISTRY.gauge(f"train_sparsity/{name}/layer{i}").set(
                    float(s))
            obs.event("sparsity", "train", step=step, weight=name,
                      mean=round(float(per_layer.mean()), 4),
                      per_layer=[round(float(s), 4) for s in per_layer])
        else:
            s = 1.0 - float(mask.mean())
            REGISTRY.gauge(f"train_sparsity/{name}").set(s)
            obs.event("sparsity", "train", step=step, weight=name,
                      sparsity=round(s, 4))


def _interrupt_save(mgr, step, params, opt_state) -> int:
    """SIGTERM epilogue shared by both loops: blocking checkpoint at the
    number of steps completed, exit code 1."""
    print("SIGTERM: checkpointing and exiting")
    if mgr:
        mgr.save(step, {"params": params, "opt": opt_state}, blocking=True)
    return 1


def _finish(args, mgr, params, opt_state, start_step, t_start, losses) -> int:
    """Normal epilogue shared by both loops: final blocking checkpoint +
    run summary."""
    if mgr:
        mgr.save(args.steps, {"params": params, "opt": opt_state},
                 blocking=True)
    final = f"; final loss {losses[-1]:.4f}" if losses else ""
    print(f"done: {args.steps - start_step} steps in "
          f"{time.time() - t_start:.1f}s{final}")
    return 0


def _run_fast(args, cfg, opt_cfg, gmp, params, opt_state, data, mgr,
              start_step, watchdog, interrupted):
    """Device-resident loop: chunks of up to --log-every steps per jit call;
    the host touches device values once per chunk."""
    # the first step of the run has no preceding in-jit step whose cond can
    # retarget for it — apply the schedule's step-``start_step`` recompute
    # once on the host (matches the reference loop's pre-step retarget)
    if gmp and gmp.recompute_at(start_step):
        obs.event("gmp_recompute", "train", step=start_step,
                  target=gmp.sparsity_at(start_step), in_jit=False)
        params = retarget_sparsity(params, gmp.sparsity_at(start_step))

    # chunk length -> compiled trainer.  Lengths come from a bounded set
    # (log_every, the remainder to a ckpt boundary, the final remainder),
    # so at most ~3 compiles per run; aligned cadences compile once.
    steppers: dict[int, callable] = {}

    t_start = time.time()
    losses: list[float] = []
    step = start_step
    while step < args.steps:
        next_ckpt = ((step // args.ckpt_every) + 1) * args.ckpt_every \
            if mgr else args.steps
        end = min(args.steps, next_ckpt, step + args.log_every)
        n = end - step
        if n not in steppers:
            steppers[n] = make_multi_step(cfg, opt_cfg, gmp, n)

        t0 = time.time()
        with obs.span("train_chunk", "train", step0=step, steps=n):
            batches = stack_batches(data, step, end)
            params, opt_state, metrics = steppers[n](
                params, opt_state, batches, jnp.int32(step),
                jnp.int32(args.steps)
            )
            # log-cadence flush: the only host<->device sync of the chunk
            chunk_loss = np.asarray(metrics["loss"])
            chunk_gnorm = np.asarray(metrics["gnorm"])
        if gmp is not None and obs.enabled():
            # the in-jit lax.cond recomputes this chunk ran, from the same
            # schedule the traced path consults (events, not measurements)
            for s in range(step + 1, end):
                if gmp.recompute_at(s) and s < args.steps:
                    obs.event("gmp_recompute", "train", step=s,
                              target=gmp.sparsity_at(s), in_jit=True)
        _sparsity_telemetry(params, end)
        dt = (time.time() - t0) / n
        watchdog.observe(0, dt)
        losses.extend(float(l) for l in chunk_loss)

        for s in range(step, end):
            if s % args.log_every == 0 or s == args.steps - 1:
                _log_line(s, chunk_loss[s - step], chunk_gnorm[s - step], dt)
        step = end
        if mgr and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt_state})
        if interrupted:
            return _interrupt_save(mgr, step, params, opt_state)

    return _finish(args, mgr, params, opt_state, start_step, t_start, losses)


def _run_host_loop(args, cfg, opt_cfg, gmp, params, opt_state, data, mgr,
                   start_step, watchdog, interrupted):
    """Per-step host-driven reference loop (the pre-fastpath behavior)."""
    train_step = make_train_step(cfg, opt_cfg)

    t_start = time.time()
    losses = []
    for step in range(start_step, args.steps):
        t0 = time.time()
        batch = data.batch_at(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}

        # GMP schedule events (outside the jitted step: pattern recomputes
        # change which entries are nonzero, values stay jit-shaped)
        if gmp and gmp.recompute_at(step):
            obs.event("gmp_recompute", "train", step=step,
                      target=gmp.sparsity_at(step), in_jit=False)
            params = retarget_sparsity(params, gmp.sparsity_at(step))

        with obs.span("train_step", "train", step=step):
            params, opt_state, metrics = train_step(params, opt_state, batch)
        watchdog.observe(0, time.time() - t0)
        losses.append(float(metrics["loss"]))

        if step % args.log_every == 0 or step == args.steps - 1:
            _sparsity_telemetry(params, step)
            _log_line(step, losses[-1], float(metrics["gnorm"]),
                      time.time() - t0)
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": params, "opt": opt_state})
        if interrupted:
            return _interrupt_save(mgr, step + 1, params, opt_state)

    return _finish(args, mgr, params, opt_state, start_step, t_start, losses)


if __name__ == "__main__":
    raise SystemExit(main())

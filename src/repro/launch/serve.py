"""Serving CLI: the paper's sparse-inference scenario as a service.

Two modes:

* one-shot (default): prefill + decode of one fixed batch, reporting
  per-token latency for dense vs n:m:g weights (paper Fig 11 at laptop
  scale) — kept as the reference the engine is tested token-for-token
  against.
* ``--engine``: the continuous-batching engine (``repro.serve``): a queue
  of requests is served through a static slot batch with per-slot KV
  caches, admission between decode steps, and p50/p99 per-token latency /
  TTFT / throughput reporting.  With ``--sparse`` the same request trace
  is served with dense and n:m:g FFN weights side by side.

``python -m repro.launch.serve --arch bert-base-sten --smoke --sparse
--engine`` runs a reduced model on CPU and serves 8 queued requests both
ways.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_smoke
from repro.models import decode_step, init_lm, prefill
from repro.obs import trace as obs
from repro.obs.registry import REGISTRY
from repro.serve import Request, SamplingParams, compare_dense_sparse
from repro.serve.engine import ServeEngine, sparsify_for_serving, \
    warmup_engine

__all__ = ["main", "run_oneshot", "sparsify_for_serving"]


def run_oneshot(params, cfg, prompts: jnp.ndarray, gen_len: int):
    """The original single-batch prefill + greedy decode loop.  Returns
    (generated tokens [B, gen_len], prefill seconds, decode seconds)."""
    B, S = prompts.shape
    jit_decode = jax.jit(
        lambda p, tok, cache, pos: decode_step(p, cfg, tok, cache, pos)
    )

    t0 = time.time()
    logits, cache = prefill(params, cfg, prompts, cache_len=S + gen_len)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        logits, cache = jit_decode(params, tok, cache, jnp.asarray(S + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    return jnp.concatenate(out, axis=1), t_prefill, t_decode


def _make_requests(key, cfg, args) -> list:
    """A queue of synthetic requests with slightly staggered arrivals and
    varied prompt lengths (so admission happens mid-stream)."""
    reqs = []
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        plen = max(4, args.prompt_len - (i % 4) * 2)
        prompt = np.asarray(
            jax.random.randint(k, (plen,), 0, cfg.vocab, jnp.int32)
        )
        reqs.append(Request(
            uid=i, prompt=prompt, max_new_tokens=args.gen_len,
            sampling=SamplingParams(greedy=True, seed=i),
            arrival_time=i * args.arrival_gap,
        ))
    return reqs


def _run_engine(args, cfg, params, key) -> int:
    reqs = _make_requests(key, cfg, args)
    max_seq = args.prompt_len + args.gen_len
    ekw = dict(max_slots=args.max_slots, max_seq_len=max_seq,
               decode_chunk=args.decode_chunk)
    if args.paged:
        if max_seq % args.page_size:
            ap_err = (f"--page-size {args.page_size} must divide "
                      f"max_seq_len {max_seq} (prompt-len + gen-len)")
            raise SystemExit(ap_err)
        ekw.update(paged=True, page_size=args.page_size,
                   num_pages=args.num_pages,
                   prefix_sharing=not args.no_prefix_sharing)
    warm = not args.no_warmup
    if args.slo_tpot_ms is not None or args.tiers:
        return _run_slo_engine(args, cfg, params, reqs, ekw, warm)
    if args.sparse:
        n, m, g = (int(v) for v in args.nm.split(":"))
        results = compare_dense_sparse(params, cfg, reqs, nm=(n, m, g),
                                       engine_kwargs=ekw, warmup=warm,
                                       tune=args.tune)
        for label, (outs, met) in results.items():
            print(met.report())
        d = results["dense"][1]
        s = results["sparse"][1]
        if d.tok_latency_p50 > 0:
            print(f"sparse/dense per-token p50 ratio: "
                  f"{s.tok_latency_p50 / d.tok_latency_p50:.2f}")
    else:
        if warm:
            warmup_engine(params, cfg, reqs, engine_kwargs=ekw,
                          tune=args.tune)
        eng = ServeEngine(params, cfg, **ekw)
        outs = eng.run(reqs)
        met = eng.metrics(label="dense")
        print(met.report())
        results = {"dense": (outs, met)}
    n_served = len(next(iter(results.values()))[0])
    kind = "paged" if args.paged else "slot"
    print(f"served {n_served} requests through "
          f"{args.max_slots}-slot continuous batching ({kind} KV cache)")
    if args.paged and not args.sparse:
        kv = eng.kv.stats
        print(f"paged KV: peak {kv['peak_pages_in_use']} pages in use, "
              f"{kv['shared_tokens']} prompt tokens prefix-shared, "
              f"{kv['cow_copies']} copy-on-write page copies, "
              f"{eng.stats['preemptions']} preemptions")
    return 0


def _run_slo_engine(args, cfg, params, reqs, ekw, warm) -> int:
    """``--engine`` with the SLO control loop: resident sparsity tiers,
    hysteresis degradation ladder, optional seeded fault injection."""
    from repro.serve import FaultConfig, FaultInjector, SLOConfig, \
        trace_events

    tiers = [t.strip() for t in (args.tiers or "dense,1:4:8-gr64").split(",")
             if t.strip()]
    slo = SLOConfig(
        tpot_ms=args.slo_tpot_ms if args.slo_tpot_ms is not None else 50.0,
        ttft_ms=args.slo_ttft_ms,
    )
    faults = None
    if args.faults:
        faults = FaultInjector(FaultConfig(
            seed=args.seed, spike_prob=0.02, error_prob=0.02,
            slow_windows=((20, 40, 3.0),),
        ))
    eng = ServeEngine(params, cfg, slo=slo, tiers=tiers, faults=faults,
                      **ekw)
    if warm:
        eng.warm_tiers(sorted({int(r.prompt.size) for r in reqs}))
    traced_after_warm = dict(trace_events())
    eng.run(reqs)
    met = eng.metrics(label="slo")
    print(met.report())
    print(f"tiers: {', '.join(tiers)} | tier switches "
          f"{eng.stats['tier_switches']} | shed {eng.stats['shed']} | "
          f"timeout {eng.stats['timeout']} | fault retries "
          f"{eng.stats['fault_retries']}")
    new_traces = {k: v - traced_after_warm.get(k, 0)
                  for k, v in trace_events().items()
                  if v != traced_after_warm.get(k, 0)}
    if new_traces:
        print(f"WARNING: serving recompiled after warmup: {new_traces}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--sparse", action="store_true")
    ap.add_argument("--nm", default="1:4:16",
                    help="n:m:g for --sparse")
    ap.add_argument("--seed", type=int, default=0)
    # engine mode
    ap.add_argument("--engine", action="store_true",
                    help="serve a request queue through the "
                         "continuous-batching engine")
    ap.add_argument("--requests", type=int, default=8,
                    help="queued requests in --engine mode")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="slot-batch size in --engine mode")
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="seconds between request arrivals (--engine)")
    ap.add_argument("--decode-chunk", type=int, default=8,
                    help="decode steps per jit call in --engine mode "
                         "(device-resident greedy inner loop; 1 = the "
                         "per-token host-paced reference)")
    ap.add_argument("--paged", action="store_true",
                    help="--engine mode: paged KV cache (page-table "
                         "indirection + copy-on-write prefix sharing) "
                         "instead of one full-length row per slot")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged); must divide "
                         "prompt-len + gen-len")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool size (--paged); default sizes the "
                         "pool to the slot cache's KV footprint")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="--paged: disable content-hash prefix sharing")
    ap.add_argument("--slo-tpot-ms", type=float, default=None,
                    help="--engine mode: enable the SLO control loop with "
                         "this per-token-latency objective (hysteresis "
                         "ladder: defer admissions -> sparser weight tier "
                         "-> shed; see docs/serving.md)")
    ap.add_argument("--slo-ttft-ms", type=float, default=None,
                    help="optional time-to-first-token objective for the "
                         "SLO attainment metric")
    ap.add_argument("--tiers", default=None,
                    help="comma-separated sparsity tiers, densest first "
                         "(e.g. 'dense,2:4,1:4:8-gr64'); implies the SLO "
                         "control loop (default SLO if --slo-tpot-ms is "
                         "not given)")
    ap.add_argument("--faults", action="store_true",
                    help="--engine mode with SLO loop: inject the "
                         "deterministic seeded fault schedule (latency "
                         "spikes, slow-decode windows, retried transient "
                         "errors) from serve/faults.py")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip the pre-compile pass; reported latencies "
                         "then include XLA compile stalls")
    ap.add_argument("--tuning-table", default=None, metavar="PATH",
                    help="load a repro.tune table (written by "
                         "`python -m repro.tune`) so kernel routing uses "
                         "measured decisions instead of shipped defaults")
    ap.add_argument("--tune", action="store_true",
                    help="--engine mode: autotune the served shapes "
                         "during warmup (repro.tune warmup hook)")
    ap.add_argument("--check", action="store_true",
                    help="run the repro.check static verifier over the "
                         "serve entry before doing anything; abort on "
                         "ERROR diagnostics")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the repro.obs flight recorder and write "
                         "a Chrome/Perfetto trace (request lifecycles, "
                         "controller decisions, fault injections, kernel "
                         "routes) to PATH on exit; open it at "
                         "https://ui.perfetto.dev")
    args = ap.parse_args(argv)
    if args.paged and not args.engine:
        ap.error("--paged requires --engine (the one-shot path has no "
                 "slot scheduler to page)")
    if (args.slo_tpot_ms is not None or args.tiers or args.faults) \
            and not args.engine:
        ap.error("--slo-tpot-ms/--slo-ttft-ms/--tiers/--faults require "
                 "--engine (the SLO control loop runs the continuous-"
                 "batching scheduler)")
    if args.faults and args.slo_tpot_ms is None and not args.tiers:
        ap.error("--faults needs the SLO control loop; pass --slo-tpot-ms "
                 "and/or --tiers")
    if args.tune and not args.engine:
        # the one-shot path has no warmup/tuning hook; accepting the flag
        # there would report an untuned run as tuned
        ap.error("--tune requires --engine")
    if args.tune and args.no_warmup:
        # tuning happens inside the warmup pass because routing lookups
        # resolve at trace time; skipping warmup would silently serve
        # default routing while reporting a "tuned" run
        ap.error("--tune requires the warmup pass; drop --no-warmup")

    from repro.tune import load_table_cli

    load_table_cli(args.tuning_table)  # --tuning-table or $REPRO_TUNE_TABLE

    if args.check:
        # after the table load on purpose: routed-config diagnostics (R6)
        # must judge the same table the run is about to serve under
        from repro.check import preflight

        rc = preflight(("serve",), arch=args.arch)
        if rc:
            print("repro.check: serve preflight failed — not serving")
            return rc

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(key, cfg)

    if args.trace:
        obs.enable()
    try:
        return _main_modes(args, cfg, params, key)
    finally:
        if args.trace:
            obs.dump(args.trace, registry_snapshot=REGISTRY.snapshot())
            print(f"wrote trace to {args.trace}")


def _main_modes(args, cfg, params, key) -> int:
    if args.engine:
        return _run_engine(args, cfg, params, key)

    if args.sparse:
        n, m, g = (int(v) for v in args.nm.split(":"))
        params = sparsify_for_serving(params, n, m, g)
        print(f"serving with {n}:{m}:{g} sparse FFN weights")

    B, S, G = args.batch, args.prompt_len, args.gen_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)
    gen, t_prefill, t_decode = run_oneshot(params, cfg, prompts, G)
    print(f"prefill {S} toks x {B} batch: {t_prefill * 1e3:.1f} ms")
    print(f"decode  {G - 1} steps: {t_decode / max(1, G - 1) * 1e3:.2f} "
          f"ms/token")
    print("sample:", np.asarray(gen[0, :12]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Batched serving loop: prefill + decode with (optionally) n:m:g sparse
weights — the paper's sparse-inference scenario as a service loop.

``python -m repro.launch.serve --arch bert-base-sten --smoke --sparse``
runs a reduced model on CPU, converts FFN weights to GroupedNMTensor, and
serves a batch of synthetic prompts, reporting per-token latency for dense
vs n:m:g weights (paper Fig 11 at laptop scale; the TPU-scale numbers come
from the dry-run roofline).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import GroupedNMTensor
from repro.core.sparsifiers import GroupedNMSparsifier
from repro.models import decode_step, init_lm, prefill


def sparsify_for_serving(params, n=1, m=4, g=16, gr=1):
    """Convert FFN weights to the n:m:g inference layout (paper §5.3:
    'our sparse-dense GEMM kernel during inference')."""
    sb = SparsityBuilder()
    sp = GroupedNMSparsifier(n, m, g, gr, sparse_dim=0)  # [K, N] weights
    sb.set_weight("*mlp.wi", sp, GroupedNMTensor)
    sb.set_weight("*mlp.wo", sp, GroupedNMTensor)
    return sb.sparsify_params(params)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="bert-base-sten")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--sparse", action="store_true")
    ap.add_argument("--nm", default="1:4:16",
                    help="n:m:g for --sparse")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_lm(key, cfg)
    if args.sparse:
        n, m, g = (int(v) for v in args.nm.split(":"))
        params = sparsify_for_serving(params, n, m, g)
        print(f"serving with {n}:{m}:{g} sparse FFN weights")

    B, S, G = args.batch, args.prompt_len, args.gen_len
    prompts = jax.random.randint(key, (B, S), 0, cfg.vocab, jnp.int32)

    jit_decode = jax.jit(
        lambda p, tok, cache, pos: decode_step(p, cfg, tok, cache, pos)
    )

    t0 = time.time()
    logits, cache = prefill(params, cfg, prompts, cache_len=S + G)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = jit_decode(params, tok, cache, jnp.asarray(S + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {S} toks x {B} batch: {t_prefill * 1e3:.1f} ms")
    print(f"decode  {G - 1} steps: {t_decode / max(1, G - 1) * 1e3:.2f} "
          f"ms/token")
    print("sample:", np.asarray(gen[0, :12]))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Roofline table generator: reads the dry-run JSONs and emits the
per-(arch x shape x mesh) three-term roofline analysis (assignment
§ROOFLINE ANALYSIS) as markdown for EXPERIMENTS.md.

With ``--bench BENCH_bench.json`` it also emits the **kernel roofline**
section: every benchmark record carrying a ``roofline_ideal_us`` (the
fig6 megakernel series) as measured-vs-ideal distance, so the decode
megakernel's gap to the HW roofline lands in the same report as the
end-to-end terms.

    python -m repro.launch.roofline [--dir experiments/dryrun] [--mesh 16x16]
                                    [--bench BENCH_bench.json]
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.launch.hlo_analysis import DEFAULT_HW_KIND, HW_BY_KIND, \
    hw_for_device


def fmt_s(x):
    if x is None:
        return "-"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1.0:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def advice(rec) -> str:
    dom = rec["roofline"]["dominant"]
    shape = rec["shape"]
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return "shrink cache bytes/token (int8 KV, window/ring caches)"
        return "cut HBM traffic: fuse/remat less, wider tiles, bf16 interms"
    if dom == "collective":
        return "cut sync bytes: value-only sparse all-reduce, overlap, " \
               "reduce-scatter instead of all-reduce"
    return "raise MXU utilization: bigger per-chip tiles, fewer pad waste"


def load(dir_: str, mesh: str | None, tag: str = "baseline"):
    recs = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        r = json.loads(p.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        if r.get("tag", "baseline") != tag:
            continue
        recs.append(r)
    return recs


def table(recs, *, full: bool = True) -> str:
    lines = [
        "| arch | shape | mesh | compute | memory | collective | dominant |"
        " bound | MODEL_FLOPs/HLO | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("skipped"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"SKIP | — | — | {r['skipped']} |"
            )
            continue
        if not r.get("ok"):
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"FAIL | — | — | {r.get('error','')[:60]} |"
            )
            continue
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        ratio = r.get("useful_flops_ratio", 0.0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {fmt_s(t['compute_s'])} | {fmt_s(t['memory_s'])} "
            f"| {fmt_s(t['collective_s'])} | **{t['dominant']}** "
            f"| {fmt_s(bound)} | {ratio:.2f} | {advice(r)} |"
        )
    return "\n".join(lines)


def kernel_table(bench_path: str) -> str:
    """Markdown kernel-roofline section from a ``BENCH_bench.json``:
    one row per record that carries a modelled ``roofline_ideal_us``
    (fig6's megakernel series).  Distance is measured/ideal — honest only
    when the benchmark ran on the chip ``HW`` describes; elsewhere the
    speedup column is the meaningful one."""
    doc = json.loads(pathlib.Path(bench_path).read_text())
    rows = [r for r in doc.get("results", [])
            if isinstance(r, dict) and "roofline_ideal_us" in r]
    if not rows:
        return f"(no kernel-roofline records in {bench_path})"
    lines = [
        "| kernel | us/call | sequential us | speedup | ideal us |"
        " distance |",
        "|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['name']} | {r['us_per_call']:.1f} "
            f"| {r.get('sequential_us', 0.0):.1f} "
            f"| {r.get('speedup_vs_sequential', 0.0):.2f}x "
            f"| {r['roofline_ideal_us']:.2f} "
            f"| {r['us_per_call'] / r['roofline_ideal_us']:.1f}x |"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--bench", default=None,
                    help="BENCH_bench.json to render the kernel-roofline "
                         "section from (fig6 megakernel records)")
    ap.add_argument("--device-kind", default=DEFAULT_HW_KIND,
                    help="HW constants to model against (keys of "
                         f"launch.hlo_analysis.HW_BY_KIND: "
                         f"{', '.join(sorted(HW_BY_KIND))})")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.tag)
    hw, matched = hw_for_device(args.device_kind)
    kind = args.device_kind if matched else DEFAULT_HW_KIND
    if not matched:
        print(f"warning: device kind {args.device_kind!r} has no "
              f"HW_BY_KIND entry — modelling against {DEFAULT_HW_KIND} "
              f"(the repro.check R7 diagnostic flags this too)")
    print(f"hardware ({kind}): {hw['peak_flops_bf16']/1e12:.0f} TF/s bf16, "
          f"{hw['hbm_bw']/1e9:.0f} GB/s HBM, {hw['ici_bw']/1e9:.0f} GB/s ICI"
          " per chip\n")
    print(table(recs))
    if args.bench:
        print("\n### kernel roofline (decode megakernels)\n")
        print(kernel_table(args.bench))


if __name__ == "__main__":
    main()

"""Step functions (train / prefill / decode) and their sharding assembly.

These are the programs the multi-pod dry-run lowers and the training loop
executes.  Sparsity (the paper's technique) enters through the params tree:
any weight may be a sparse layout, gradients may be sparsified per the
builder's grad formats, and the sparse-aware update re-sparsifies after the
dense optimizer math (SameFormatSparsifier).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.sharding import (
    ShardingRules,
    batch_spec,
    divisible as _divisible,
    param_specs,
    tree_shardings,
    use_rules,
)
from repro.models import decode_step, init_cache, init_lm, loss_fn, prefill
from repro.models.common import ModelConfig
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    sparse_aware_update,
    value_and_grad_sparse,
)

__all__ = ["StepConfig", "make_train_step", "make_prefill_step",
           "make_decode_step", "cache_specs", "opt_specs", "batch_specs"]


@dataclasses.dataclass(frozen=True)
class StepConfig:
    remat: str = "full"            # none | full
    aux_weight: float = 0.01
    kv_cache_dtype: Optional[str] = None  # e.g. "int8" (hillclimb knob)
    grad_formats: Optional[dict] = None
    recompute_pattern: bool = False


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, step_cfg: StepConfig,
                    mesh: Mesh, rules: ShardingRules):
    """Build the jit-able train step: (params, opt_state, batch) ->
    (params, opt_state, metrics).

    The body runs under ``use_rules(mesh, rules)`` so every
    ``logical_constraint`` in the model stack resolves against this mesh;
    sparsity enters via ``value_and_grad_sparse`` (layout-metadata-tolerant
    grads) and ``sparse_aware_update`` (post-optimizer re-sparsification).
    Metrics: loss, ce, moe_aux, gnorm — all replicated scalars.
    """

    def train_step(params, opt_state, batch):
        with use_rules(mesh, rules):
            (loss, aux), grads = value_and_grad_sparse(
                lambda p: loss_fn(p, cfg, batch, remat=step_cfg.remat,
                                  aux_weight=step_cfg.aux_weight),
                has_aux=True,
            )(params)
            new_params, new_state, m = sparse_aware_update(
                functools.partial(adamw_update, cfg=opt),
                grads, opt_state, params,
                grad_formats=step_cfg.grad_formats,
                recompute_pattern=step_cfg.recompute_pattern,
            )
        metrics = {"loss": loss, "ce": aux["ce"], "moe_aux": aux["moe_aux"],
                   "gnorm": m["gnorm"]}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, step_cfg: StepConfig, mesh: Mesh,
                      rules: ShardingRules, cache_len: int):
    """Build the prefill step: (params, batch) -> (logits, decode cache).

    Runs the parallel forward under the sharding-rules context while
    collecting per-layer K/V (and MLA latents / SSM end-states) into a
    ``cache_len``-sized cache — the handoff point to ``make_decode_step``.
    """
    def prefill_step(params, batch):
        with use_rules(mesh, rules):
            logits, cache = prefill(
                params, cfg, batch["tokens"], cache_len=cache_len,
                enc_embeds=batch.get("enc_embeds"),
                prefix_embeds=batch.get("prefix_embeds"),
            )
        return logits, cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, step_cfg: StepConfig, mesh: Mesh,
                     rules: ShardingRules):
    """Build the one-token decode step: (params, cache, token, pos) ->
    (logits, new cache).  Donate the cache at the jit call site — it is
    updated in place shard-by-shard under the sequence-sharded layout from
    ``cache_specs``."""
    def decode(params, cache, token, pos):
        with use_rules(mesh, rules):
            logits, new_cache = decode_step(params, cfg, token, cache, pos)
        return logits, new_cache

    return decode


# ---------------------------------------------------------------------------
# sharding assembly
# ---------------------------------------------------------------------------


def opt_specs(p_specs):
    """Optimizer-state specs mirror param specs (ZeRO-3); the step counter
    is replicated.  Moment leaves are None for non-inexact params."""
    return {
        "mu": p_specs,
        "nu": p_specs,
        "step": P(),
    }


def cache_specs(cache_shapes, mesh: Mesh, rules: ShardingRules):
    """Decode-cache specs: batch over the DP axes, *sequence over the TP
    ('model') axis* — sequence-sharded KV cache, the standard way to fit
    multi-hundred-GB caches (XLA inserts the partial-softmax collectives).
    SSM states shard heads over 'model' when divisible."""
    dp = rules.resolve("batch", set(mesh.axis_names))
    tp = rules.resolve("heads", set(mesh.axis_names))

    def visit(path, leaf):
        dims = [None] * leaf.ndim
        # leaves: [L, B, S, ...] seq caches; [L, B, H, P, N] ssm; [L,B,W,C]
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if leaf.ndim >= 3:
            if _divisible(leaf.shape[1], mesh, dp):
                dims[1] = dp
            if "conv" in name or "ssm" in name:
                # no seq axis; shard the widest trailing dim over TP
                for ax in range(leaf.ndim - 1, 1, -1):
                    if _divisible(leaf.shape[ax], mesh, tp):
                        dims[ax] = tp
                        break
            elif leaf.ndim >= 3 and _divisible(leaf.shape[2], mesh, tp):
                dims[2] = tp  # sequence axis
        return P(*dims)

    return jax.tree_util.tree_map_with_path(visit, cache_shapes)


def batch_specs(specs: dict, mesh: Mesh, rules: ShardingRules):
    """Input-batch specs: dim 0 of every entry over the data-parallel axes
    when divisible (the per-array rule lives in ``dist.sharding.batch_spec``;
    this maps a whole ``input_specs`` dict)."""
    return {k: batch_spec(v, rules, mesh) for k, v in specs.items()}

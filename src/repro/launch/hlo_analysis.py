"""Compiled-HLO structural analysis: trip-count-aware FLOPs, HBM-traffic
bytes, collective-operand bytes, and roofline terms.

Why not just ``compiled.cost_analysis()``: XLA's flat cost analysis counts
each ``while`` body **once**, so scan-over-layers programs (everything here)
under-report FLOPs/bytes/collectives by ~n_layers, and its "bytes accessed"
charges a gather with the full table size.  This module re-derives the
costs *structurally from the compiled artifact* (assignment §Roofline —
"derive the three roofline terms from the dry-run's compiled artifact"):

  * the module text is parsed into computations/instructions;
  * ``while`` ops carry ``known_trip_count`` in backend_config (fallback:
    the loop-bound constant in the condition) — body costs multiply by it,
    nested loops compose by recursion;
  * FLOPs = MXU work: 2 * prod(result dims) * prod(contracting dims) per
    ``dot``, wherever it appears (VPU transcendentals are excluded — they
    ride the memory term);
  * bytes = post-fusion HBM traffic: per *control-flow-level* instruction,
    result + operand bytes (fusion internals live in registers/VMEM and are
    not charged; gathers charge gathered rows + indices, not the table);
  * collective bytes = operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, trip-count scaled.

Shapes in the partitioned module are per-device, so every roofline term is
per-device against per-chip peak rates — equivalent to the global/chips
formulation.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = ["collective_bytes", "analyze_hlo", "roofline_terms", "HW",
           "HW_BY_KIND", "DEFAULT_HW_KIND", "hw_for_device",
           "parse_module", "inst_operands"]

#: per-chip constants keyed by ``tune.table.device_kind()`` spelling —
#: TPU v5e numbers are assignment-provided; the cpu entry is a rough
#: host-class model so CI runs don't trip the unmodelled-device warning
HW_BY_KIND = {
    "tpu:tpu_v5e": {
        "peak_flops_bf16": 197e12,   # FLOP/s
        "hbm_bw": 819e9,             # B/s
        "ici_bw": 50e9,              # B/s per link
        "vmem_bytes": 128 * 2**20,   # per-core VMEM budget
    },
    "cpu:cpu": {
        "peak_flops_bf16": 2e12,
        "hbm_bw": 100e9,
        "ici_bw": 50e9,
        "vmem_bytes": 128 * 2**20,   # interpret mode models the v5e budget
    },
}

DEFAULT_HW_KIND = "tpu:tpu_v5e"

#: the historical module-level constant — still the v5e entry, so every
#: existing roofline/benchmark import keeps its exact numbers
HW = HW_BY_KIND[DEFAULT_HW_KIND]


def hw_for_device(kind: str | None = None):
    """-> (hw constants dict, matched: bool).  Unknown/None kinds fall
    back to the TPU v5e entry with ``matched=False`` — the checker turns
    that into the R7 warning rather than guessing numbers."""
    if kind in HW_BY_KIND:
        return HW_BY_KIND[kind], True
    return HW_BY_KIND[DEFAULT_HW_KIND], False

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "f8e8m0fnu": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# `%name = f32[1,2,3]{...} op-name(...)` or tuple results
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w\[\],\s]+?)\s+"
    r"([\w\-]+)(?:\.\d+)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _shape_bytes(shape_text: str) -> int:
    """Total bytes of all array shapes in a type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind (per-device, post-SPMD)."""
    shapes: dict[str, str] = {}
    # pass 1: record result type of every named instruction
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-start":
                kind = c
                break
        if kind is None:
            continue
        # operands: everything inside the first (...) argument list
        args = line.split("(", 1)[1]
        args = args.split("), ")[0] if "), " in args else args.rsplit(")", 1)[0]
        nbytes = 0
        for name in _OPERAND_RE.findall(args):
            if name in shapes:
                nbytes += _shape_bytes(shapes[name])
        if nbytes == 0:
            # fall back to result size (covers unnamed-constant operands)
            nbytes = _shape_bytes(m.group(2))
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


# ---------------------------------------------------------------------------
# structural (trip-count-aware) analyzer
# ---------------------------------------------------------------------------

# computation headers may contain '/*index=N*/' comments in the param list,
# so only anchor on the name + opening paren and the trailing '{'
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
    r"(\([^)]*\)|[\w\[\],]+(?:\{[\d,]*\})?)\s+"
    r"([\w\-]+)\("
)
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{"n":\s*"(\d+)"')
_CALL_RE = re.compile(r"(?:calls|to_apply|condition|body)=%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_NOBYTE_OPS = frozenset({
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "partition-id", "replica-id", "after-all", "while", "conditional",
    "custom-call", "call",
})


@dataclasses.dataclass
class _Inst:
    name: str
    type_text: str
    op: str
    args: str
    line: str


def _balanced(text: str, start: int) -> int:
    """Index just past the paren group opening at ``start``."""
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


_OP_AT = re.compile(r"([\w\-]+)\(")


def _parse_inst(line: str):
    """Robust instruction parse handling nested tuple types
    ('((f32[2], s32[]), f32[4]) while(...)')."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    name, sep, rhs = s.partition(" = ")
    if not sep or not name.strip():
        return None
    name = name.strip().lstrip("%")
    rhs = rhs.strip()
    if rhs.startswith("("):
        end = _balanced(rhs, 0)
        type_text, rest = rhs[:end], rhs[end:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_text, rest = rhs[:sp], rhs[sp + 1:].lstrip()
    m = _OP_AT.match(rest)
    if m is None:
        return None
    op = m.group(1)
    arg_end = _balanced(rest, m.end() - 1)
    args = rest[m.end(): arg_end - 1]
    return _Inst(name, type_text, op, args, line)


def _parse_module(hlo_text: str):
    """-> (computations: {name: [inst]}, shapes: {inst_name: type_text},
    entry_name, fused_comps: set of computations called from fusions)"""
    comps: dict[str, list[_Inst]] = {}
    shapes: dict[str, str] = {}
    entry = None
    fused: set[str] = set()
    cur: list[_Inst] | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if " = " not in line:
            m = _COMP_RE.match(line.strip())
            if m:
                name = m.group(1)
                comps[name] = []
                cur = comps[name]
                if line.strip().startswith("ENTRY"):
                    entry = name
            continue
        inst = _parse_inst(line)
        if inst is None or cur is None:
            continue
        cur.append(inst)
        shapes[inst.name] = inst.type_text
        if inst.op == "fusion":
            cm = re.search(r"calls=%([\w\.\-]+)", line)
            if cm:
                fused.add(cm.group(1))
    return comps, shapes, entry, fused


def _operands(inst: _Inst):
    return _OPERAND_RE.findall(inst.args)


def _trip_count(inst: _Inst, comps, shapes) -> int:
    m = _TRIP_RE.search(inst.line)
    if m:
        return int(m.group(1))
    # fallback: the constant compared against in the condition computation
    cm = re.search(r"condition=%([\w\.\-]+)", inst.line)
    if cm and cm.group(1) in comps:
        for ci in comps[cm.group(1)]:
            k = re.search(r"constant\((\d+)\)", ci.line)
            if k and ci.op == "constant":
                return int(k.group(1))
    return 1


def _fusion_bytes(inst: _Inst, ops_list, comps, shapes) -> int:
    """HBM traffic of a fusion: operands + output, with two refinements —
    a parameter consumed only by gathers is charged the gathered bytes (not
    the table), and a parameter updated in place by dynamic-update-slice is
    charged (and emitted as) the update size (XLA aliases the buffer)."""
    called = None
    cm = re.search(r"calls=%([\w\.\-]+)", inst.line)
    if cm:
        called = comps.get(cm.group(1))
    out_b = _shape_bytes(inst.type_text)
    if called is None:
        return out_b + sum(_shape_bytes(shapes[o]) for o in ops_list
                           if o in shapes)
    # param index -> local name, and local uses
    param_names = {}
    for ci in called:
        if ci.op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", ci.line)
            if pm:
                param_names[int(pm.group(1))] = ci.name
    local_shapes = {ci.name: ci.type_text for ci in called}
    total = 0
    dus_update_b = None
    for idx, oname in enumerate(ops_list):
        if oname not in shapes:
            continue
        full_b = _shape_bytes(shapes[oname])
        lname = param_names.get(idx)
        if lname is None:
            total += full_b
            continue
        uses = [ci for ci in called if lname in _operands(ci)]
        if uses and all(ci.op in _SLICE_OPS and _operands(ci)[0] == lname
                        for ci in uses):
            total += sum(_shape_bytes(ci.type_text) for ci in uses)
        elif uses and all(ci.op == "dynamic-update-slice"
                          and _operands(ci)[0] == lname for ci in uses):
            upd = 0
            for ci in uses:
                o2 = _operands(ci)
                if len(o2) > 1 and o2[1] in local_shapes:
                    upd += _shape_bytes(local_shapes[o2[1]])
            total += upd
            if _shape_bytes(shapes[oname]) == out_b:
                dus_update_b = upd  # in-place aliased output
        else:
            total += full_b
    return total + (dus_update_b if dus_update_b is not None else out_b)


#: ops whose operand-0 is a large buffer of which only a slice moves
_SLICE_OPS = frozenset({"gather", "dynamic-slice", "slice"})
#: tensors at or below this size are assumed VMEM-resident across loop
#: iterations (TPU v5e class VMEM); their traffic charges once per loop
VMEM_RESIDENT_BYTES = 32 * 1024 * 1024


def analyze_hlo(hlo_text: str, vmem_resident: int = VMEM_RESIDENT_BYTES
                ) -> Dict:
    """Trip-count-aware per-device totals:
    {'flops', 'bytes', 'collectives': {kind: bytes, 'total', 'count'},
     'num_whiles', 'max_trip'}

    Bytes model: per control-flow-level instruction, output + operand sizes
    (a produced-then-consumed edge costs write+read — the post-fusion HBM
    round trip), except (a) slice/gather ops charge moved bytes, not their
    source buffer, (b) dynamic-update-slice charges the update (XLA aliases
    the buffer), and (c) inside loop bodies, charges on tensors <=
    ``vmem_resident`` accumulate once per loop entry instead of per
    iteration (VMEM residency of carries/accumulators); explicitly sliced
    data always streams per iteration."""
    comps, shapes, entry, fused = _parse_module(hlo_text)
    memo: dict[tuple, tuple] = {}
    info = {"num_whiles": 0, "max_trip": 1}

    def comp_cost(name: str, in_fusion: bool):
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        flops = 0.0
        stream_b = 0.0   # charged per loop iteration
        once_b = 0.0     # VMEM-resident: charged once per loop entry
        coll = {k: 0.0 for k in _COLLECTIVES}
        ccount = 0
        for inst in comps.get(name, ()):  # pragma: no branch
            op = inst.op
            if op == "dot":
                ops = _operands(inst)
                lhs_shape = shapes.get(ops[0], "") if ops else ""
                cm = _CONTRACT_RE.search(inst.line)
                csize = 1
                if cm and lhs_shape:
                    dims_m = _SHAPE_RE.search(lhs_shape)
                    if dims_m:
                        lhs_dims = [int(d) for d in
                                    dims_m.group(2).split(",") if d.strip()]
                        for ci in cm.group(1).split(","):
                            if ci.strip():
                                csize *= lhs_dims[int(ci)]
                out_elems = 1
                om = _SHAPE_RE.search(inst.type_text)
                if om:
                    for d in om.group(2).split(","):
                        if d.strip():
                            out_elems *= int(d)
                flops += 2.0 * out_elems * csize
            kind = None
            for c in _COLLECTIVES:
                if op == c or op.startswith(c + "-start"):
                    kind = c
                    break
            if kind:
                nb = 0
                for o in _operands(inst):
                    if o in shapes:
                        nb += _shape_bytes(shapes[o])
                if nb == 0:
                    nb = _shape_bytes(inst.type_text)
                coll[kind] += nb
                ccount += 1
            # bytes: control-flow level only, skip plumbing ops
            if not in_fusion and op not in _NOBYTE_OPS and kind is None:
                ops_list = _operands(inst)
                force_stream = op in _SLICE_OPS or op == "dynamic-update-slice"
                if op in _SLICE_OPS and ops_list:
                    ops_list = ops_list[1:]  # moved bytes, not the source
                if op == "dynamic-update-slice" and ops_list:
                    # aliased in-place write: charge the update (read+write)
                    upd = sum(_shape_bytes(shapes[o]) for o in ops_list[1:]
                              if o in shapes)
                    stream_b += 2 * upd
                    continue
                if op == "fusion":
                    fb = _fusion_bytes(inst, ops_list, comps, shapes)
                    if fb <= vmem_resident:
                        once_b += fb
                    else:
                        stream_b += fb
                else:
                    charge = _shape_bytes(inst.type_text) + sum(
                        _shape_bytes(shapes[o]) for o in ops_list
                        if o in shapes
                    )
                    if not force_stream and charge <= vmem_resident:
                        once_b += charge
                    else:
                        stream_b += charge
            # recurse into called computations
            mult = 1
            sub_in_fusion = in_fusion or op == "fusion"
            if op == "while":
                mult = _trip_count(inst, comps, shapes)
                info["num_whiles"] += 1
                info["max_trip"] = max(info["max_trip"], mult)
            for sub in _CALL_RE.findall(inst.line):
                if sub not in comps:
                    continue
                sf, s_stream, s_once, sc, scnt = comp_cost(
                    sub, sub_in_fusion or sub in fused
                )
                flops += mult * sf
                if op == "while":
                    # body streams per iteration; VMEM-resident charges once
                    stream_b += mult * s_stream + s_once
                else:
                    stream_b += mult * s_stream
                    once_b += s_once
                for k in sc:
                    coll[k] += mult * sc[k]
                ccount += mult * scnt
        memo[key] = (flops, stream_b, once_b, coll, ccount)
        return memo[key]

    flops, stream_b, once_b, coll, ccount = comp_cost(entry, False)
    collectives = {k: int(v) for k, v in coll.items()}
    collectives["total"] = int(sum(coll.values()))
    collectives["count"] = int(ccount)
    return {
        "flops": flops,
        "bytes": stream_b + once_b,
        "collectives": collectives,
        "num_whiles": info["num_whiles"],
        "max_trip": info["max_trip"],
    }


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float,
                   device_kind: str | None = None) -> RooflineTerms:
    hw = HW if device_kind is None else hw_for_device(device_kind)[0]
    return RooflineTerms(
        compute_s=flops_per_dev / hw["peak_flops_bf16"],
        memory_s=bytes_per_dev / hw["hbm_bw"],
        collective_s=coll_bytes_per_dev / hw["ici_bw"],
    )


# public parser surface for repro.check's HLO pass
parse_module = _parse_module
inst_operands = _operands

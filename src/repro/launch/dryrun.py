import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (assignment §MULTI-POD DRY-RUN).

Lowers + compiles every (architecture x input-shape) cell against the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — with
jax.ShapeDtypeStruct inputs (no allocation), then records
``compiled.memory_analysis()``, ``compiled.cost_analysis()`` and the
collective-operand bytes parsed from the post-SPMD HLO into a JSON per cell.

NOTE the XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init); do not set it globally.

Usage:
    python -m repro.launch.dryrun --arch qwen1.5-4b --shape train_4k \
        [--multi-pod] [--out experiments/dryrun] [--opt <name>=<val> ...]
    python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import functools
import json
import math
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_arch, input_specs
from repro.configs.registry import runnable_cells, skip_reason
from repro.dist.sharding import ShardingRules, param_specs, tree_shardings
from repro.launch import steps as steps_mod
from repro.launch.hlo_analysis import analyze_hlo, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.models import init_cache, init_lm
from repro.models.common import ModelConfig
from repro.optim import AdamWConfig, adamw_init


def count_params(shapes_tree) -> int:
    return sum(
        int(math.prod(l.shape))
        for l in jax.tree_util.tree_leaves(shapes_tree)
        if hasattr(l, "shape")
    )


def model_flops(cfg: ModelConfig, shape, n_params: int) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference), with N = active
    params for MoE (experts scaled by top_k / num_experts)."""
    # embedding params excluded from N (standard convention)
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    n = n_params - emb
    if cfg.moe is not None:
        e, k = cfg.moe.num_experts, cfg.moe.top_k
        # expert weights exactly: wi [E, D, (2)F] + wo [E, F, D] per layer
        f = cfg.moe.d_expert
        per_layer = e * (cfg.d_model * (2 * f if cfg.gated_mlp else f)
                         + f * cfg.d_model)
        expert_p = cfg.n_layers * per_layer
        n_active = n - expert_p + expert_p * (k / e)
    else:
        n_active = n
    tokens = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               opts: dict):
    cfg = get_arch(arch)
    # model-execution overrides (hillclimb knobs)
    cfg_over = {}
    for k in ("attn_chunk_q", "attn_chunk_k"):
        if k in opts:
            cfg_over[k] = int(opts[k])
    if "attn_dtype" in opts:
        cfg_over["attn_dtype"] = opts["attn_dtype"]
    if "attn_chunk" in opts:
        cfg_over["attn_chunk_q"] = cfg_over["attn_chunk_k"] = \
            int(opts["attn_chunk"])
    if "dtype" in opts:
        cfg_over["dtype"] = opts["dtype"]
    if "kv_cache_dtype" in opts:
        cfg_over["kv_cache_dtype"] = opts["kv_cache_dtype"]
    if cfg.moe is not None and ("moe_combine" in opts or "moe_impl" in opts):
        import dataclasses as _dc
        moe_over = {}
        if "moe_combine" in opts:
            moe_over["combine"] = opts["moe_combine"]
        if "moe_impl" in opts:
            moe_over["impl"] = opts["moe_impl"]
        cfg_over["moe"] = _dc.replace(cfg.moe, **moe_over)
    if cfg.ssm is not None and ("ssm_chunk" in opts or "ssm_dtype" in opts):
        import dataclasses as _dc
        ssm_over = {}
        if "ssm_chunk" in opts:
            ssm_over["chunk"] = int(opts["ssm_chunk"])
        if "ssm_dtype" in opts:
            ssm_over["acc_dtype"] = opts["ssm_dtype"]
        cfg_over["ssm"] = _dc.replace(cfg.ssm, **ssm_over)
    if cfg_over:
        cfg = cfg.scaled(**cfg_over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules(**{k: v for k, v in opts.items()
                             if k in ShardingRules.__dataclass_fields__})
    step_cfg = steps_mod.StepConfig(
        remat=opts.get("remat", "full"),
        kv_cache_dtype=opts.get("kv_cache_dtype"),
    )

    key = jax.random.PRNGKey(0)
    p_shapes = jax.eval_shape(functools.partial(init_lm, cfg=cfg), key)
    if opts.get("sparse"):
        # integrate the paper's technique into the lowered program:
        #   masked  -> FixedMaskTensor n:m:g weights (paper-faithful
        #              masked sparse training, Figs 2/9)
        #   nmg     -> GroupedNMTensor compressed weights (beyond-paper:
        #              compressed storage/optimizer/collectives)
        from repro.core.builder import SparsityBuilder
        from repro.core.layouts import FixedMaskTensor, GroupedNMTensor
        from repro.core.sparsifiers import GroupedNMSparsifier

        mode = opts["sparse"]
        n_, m_, g_ = (int(v) for v in opts.get("nm", "2:4:16").split(":"))
        sp = GroupedNMSparsifier(n_, m_, g_, gr=int(opts.get("gr", 8)),
                                 sparse_dim=0)
        layout = FixedMaskTensor if mode == "masked" else GroupedNMTensor

        def sparsify(p):
            sb = SparsityBuilder()
            sb.set_weight("*mlp.w*", sp, layout)
            sb.set_weight("*attn.wq", sp, layout)
            sb.set_weight("*attn.wo", sp, layout)
            return sb.sparsify_params(p)

        p_shapes = jax.eval_shape(sparsify, p_shapes)
    p_spec = param_specs(p_shapes, rules, mesh)
    p_sh = tree_shardings(p_spec, mesh)
    specs = input_specs(cfg, shape)
    b_spec = steps_mod.batch_specs(specs, mesh, rules)
    b_sh = {k: NamedSharding(mesh, s) for k, s in b_spec.items()}

    if shape.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, p_shapes)
        o_spec = steps_mod.opt_specs(p_spec)
        # None moment leaves (int metadata) -> replicated placeholder spec
        def fix(spec_leaf, shape_leaf):
            return spec_leaf
        o_sh = {
            "mu": tree_shardings(o_spec["mu"], mesh),
            "nu": tree_shardings(o_spec["nu"], mesh),
            "step": NamedSharding(mesh, P()),
        }
        opt = AdamWConfig()
        fn = steps_mod.make_train_step(cfg, opt, step_cfg, mesh, rules)
        jfn = jax.jit(
            fn,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        args = (p_shapes, opt_shapes, specs)
    elif shape.kind == "prefill":
        fn = steps_mod.make_prefill_step(cfg, step_cfg, mesh, rules,
                                         cache_len=shape.seq_len)
        jfn = jax.jit(fn, in_shardings=(p_sh, b_sh), out_shardings=None)
        args = (p_shapes, specs)
    else:  # decode
        B = shape.global_batch
        enc_len = 1500 if cfg.n_enc_layers > 0 else 0
        cache_shapes = jax.eval_shape(
            functools.partial(init_cache, cfg, B, shape.seq_len,
                              enc_len=enc_len)
        )
        c_spec = steps_mod.cache_specs(cache_shapes, mesh, rules)
        c_sh = tree_shardings(c_spec, mesh)
        fn = steps_mod.make_decode_step(cfg, step_cfg, mesh, rules)
        jfn = jax.jit(
            fn,
            in_shardings=(p_sh, c_sh, b_sh["token"], None),
            out_shardings=(None, c_sh),
            donate_argnums=(1,),
        )
        args = (p_shapes, cache_shapes, specs["token"],
                jax.ShapeDtypeStruct((), jnp.int32))

    return cfg, shape, mesh, jfn, args, p_shapes


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             opts: dict, tag: str = "baseline") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "opts": {k: str(v) for k, v in opts.items()}, "ok": False}
    reason = skip_reason(arch, shape_name)
    if reason:
        rec["skipped"] = reason
        _write(rec, out_dir)
        return rec
    t0 = time.time()
    try:
        cfg, shape, mesh, jfn, args, p_shapes = build_cell(
            arch, shape_name, multi_pod, opts
        )
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):  # older jax: one dict per program
                cost = cost[0] if cost else {}
            hlo = compiled.as_text()
        struct = analyze_hlo(hlo)
        coll = struct["collectives"]
        n_chips = math.prod(mesh.devices.shape)
        n_params = count_params(p_shapes)
        # structural (trip-count-aware) per-device costs; raw XLA
        # cost_analysis kept for reference (it counts while bodies once)
        flops_dev = float(struct["flops"])
        bytes_dev = float(struct["bytes"])
        terms = roofline_terms(flops_dev, bytes_dev, float(coll["total"]))
        mf = model_flops(cfg, shape, n_params)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "chips": n_chips,
            "n_params": n_params,
            "flops_per_dev": flops_dev,
            "bytes_per_dev": bytes_dev,
            "flops_per_dev_xla_raw": float(cost.get("flops", 0.0)),
            "bytes_per_dev_xla_raw": float(cost.get("bytes accessed", 0.0)),
            "num_whiles": struct["num_whiles"],
            "max_trip": struct["max_trip"],
            "collective_bytes_per_dev": coll,
            "memory_analysis": {
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", None),
                "peak": getattr(mem, "peak_memory_in_bytes", None),
                "repr": str(mem),
            },
            "roofline": {
                "compute_s": terms.compute_s,
                "memory_s": terms.memory_s,
                "collective_s": terms.collective_s,
                "dominant": terms.dominant,
            },
            "model_flops_total": mf,
            "model_flops_per_dev": mf / n_chips,
            "useful_flops_ratio": (mf / n_chips) / max(flops_dev, 1.0),
            "hlo_bytes_len": len(hlo),
        })
    except Exception as e:  # record the failure — these are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    _write(rec, out_dir)
    return rec


def _write(rec, out_dir):
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
    if rec.get("tag") and rec["tag"] != "baseline":
        name += f"_{rec['tag']}"
    (p / (name.replace("/", "-") + ".json")).write_text(
        json.dumps(rec, indent=1, default=str)
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--opt", action="append", default=[],
                    help="hillclimb option name=value (e.g. remat=none)")
    args = ap.parse_args()

    opts = {}
    for kv in args.opt:
        k, v = kv.split("=", 1)
        opts[k] = None if v == "None" else v

    if args.all:
        for arch, shape, reason in runnable_cells():
            rec = run_cell(arch, shape, args.multi_pod, args.out, opts,
                           args.tag)
            status = ("SKIP: " + reason) if reason else \
                ("ok" if rec.get("ok") else "FAIL: " + rec.get("error", "?"))
            print(f"{arch:22s} {shape:12s} {rec['mesh']:8s} {status}",
                  flush=True)
    else:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.out, opts,
                       args.tag)
        print(json.dumps({k: v for k, v in rec.items()
                          if k not in ("traceback", "hlo")}, indent=1,
                         default=str))
        if not rec.get("ok") and not rec.get("skipped"):
            print(rec.get("traceback", ""))
            raise SystemExit(1)


if __name__ == "__main__":
    main()

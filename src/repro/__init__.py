"""repro — STen-JAX: productive and efficient sparsity for JAX/TPU at pod
scale.  Reproduction + extension of Ivanov et al., "STen: Productive and
Efficient Sparsity in PyTorch" (2023).

``repro.sten`` is the user-facing namespace mirroring the paper's API.
"""

__version__ = "1.0.0"

"""Structured tracing core: spans, events, and the flight recorder.

The serving/training stack makes latency-critical decisions on the host —
the SLO controller switches sparsity tiers, the scheduler admits and
preempts, the tuner routes kernels — and until now none of them were
visible on a common timeline.  This module is the timeline:

* :func:`span` — a context manager that records one *complete* interval
  (Chrome ``ph: "X"`` semantics: begin timestamp + duration) on a named
  track, with arbitrary attributes;
* :func:`event` — an instantaneous marker (``ph: "i"``) for decisions
  (tier switch, watchdog trip, kernel route, fault injection);
* :func:`complete` — a retroactive span for intervals whose endpoints the
  caller already timestamped (the engine knows a request's arrival /
  admission / finish times; it emits the "queued" span at admission);
* the **flight recorder** — a bounded ring buffer (``collections.deque``
  with ``maxlen``) holding the most recent ``capacity`` records.  Memory
  is bounded by construction and the oldest records are overwritten
  first, so the recorder can stay on in production and still hold the
  last few seconds of history when something goes wrong.

Cost model: tracing is **off by default** and every recording function
checks the module-level ``_ENABLED`` flag first.  When disabled,
:func:`event` returns immediately and :func:`span` returns a shared
no-op context-manager singleton — no record, no recorder touch, no
allocation beyond the caller's own kwargs.  When enabled, a record is
one small tuple appended to a deque; timestamps come from
``time.perf_counter`` (monotonic), stored as integer microseconds
relative to the recorder epoch set by :func:`enable`.

Records are tuples ``(ph, name, track, ts_us, dur_us, attrs)`` where
``ph`` follows the Chrome trace-event phase vocabulary (``"X"`` complete
span, ``"i"`` instant) — ``repro.obs.export`` turns them into
Chrome/Perfetto JSON, JSONL, or a text summary.

The postmortem hook: :func:`postmortem` dumps the recorder to a JSON
file named after the failure reason.  ``ServeEngine`` calls it when it
raises :class:`~repro.serve.errors.EngineOverloadError` and the
benchmarks call it on gate failures — a perf regression then starts
from a file read instead of a rerun.
"""

from __future__ import annotations

import collections
import time
from typing import Optional

__all__ = [
    "enable", "disable", "enabled", "set_capacity",
    "span", "event", "complete", "counter_event",
    "records", "clear", "dropped", "capacity",
    "dump", "postmortem", "reset",
]

#: default flight-recorder capacity (records).  A record is a 6-tuple of
#: small scalars — ~200 bytes with its attrs dict — so the default bounds
#: the recorder around tens of MB even under pathological event rates.
DEFAULT_CAPACITY = 65536

_ENABLED = False
_EPOCH: float = 0.0          # perf_counter seconds at enable()
_CAPACITY = DEFAULT_CAPACITY
_REC: collections.deque = collections.deque(maxlen=_CAPACITY)
_TOTAL = 0                   # records ever appended (dropped = total - held)


def enabled() -> bool:
    return _ENABLED


def enable(capacity: Optional[int] = None) -> None:
    """Turn tracing on.  Sets the recorder epoch (timestamps are relative
    to this call) when the recorder is empty; re-enabling while records
    are held keeps the original epoch, so a disable/enable cycle (e.g. an
    overhead probe toggling tracing mid-run) stays on one monotonic
    timeline.  When ``capacity`` is given, re-bounds the ring buffer
    (discarding held records)."""
    global _ENABLED, _EPOCH
    if capacity is not None:
        set_capacity(capacity)
    if not _REC:
        _EPOCH = time.perf_counter()
    _ENABLED = True


def disable() -> None:
    """Turn tracing off.  Held records stay readable until :func:`clear`."""
    global _ENABLED
    _ENABLED = False


def set_capacity(capacity: int) -> None:
    """Re-bound the ring buffer.  Discards held records (a resize cannot
    meaningfully preserve overwrite-oldest ordering across bounds)."""
    global _CAPACITY, _REC, _TOTAL
    _CAPACITY = max(1, int(capacity))
    _REC = collections.deque(maxlen=_CAPACITY)
    _TOTAL = 0


def capacity() -> int:
    return _CAPACITY


def reset() -> None:
    """Test hygiene: tracing off, recorder empty, default capacity."""
    global _ENABLED
    _ENABLED = False
    set_capacity(DEFAULT_CAPACITY)


def _now_us() -> int:
    return int((time.perf_counter() - _EPOCH) * 1e6)


def _append(rec: tuple) -> None:
    global _TOTAL
    _REC.append(rec)
    _TOTAL += 1


def event(name: str, track: str = "engine", **attrs) -> None:
    """Record an instantaneous event (``ph: "i"``) on ``track``."""
    if not _ENABLED:
        return
    _append(("i", name, track, _now_us(), 0, attrs or None))


def counter_event(name: str, track: str, attrs: Optional[dict]) -> None:
    """Pre-built-attrs spelling of :func:`event` for callers (the registry
    counter families) that already hold a dict — skips the kwargs pack."""
    if not _ENABLED:
        return
    _append(("i", name, track, _now_us(), 0, attrs))


def complete(name: str, t0_s: float, t1_s: float, track: str = "engine",
             **attrs) -> None:
    """Record a retroactive complete span from absolute ``perf_counter``
    seconds (the engine's ``_t0 + relative`` timestamps)."""
    if not _ENABLED:
        return
    ts = int((t0_s - _EPOCH) * 1e6)
    _append(("X", name, track, ts,
             max(0, int((t1_s - t0_s) * 1e6)), attrs or None))


class _Span:
    """Live span: timestamps on enter, records one complete event on exit.
    Exceptions propagate; the span still records (with ``error`` set)."""

    __slots__ = ("name", "track", "attrs", "t0")

    def __init__(self, name, track, attrs):
        self.name = name
        self.track = track
        self.attrs = attrs

    def __enter__(self):
        self.t0 = _now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        attrs = self.attrs
        if exc_type is not None:
            attrs = dict(attrs or ())
            attrs["error"] = exc_type.__name__
        if _ENABLED:  # disabled mid-span: drop rather than half-record
            _append(("X", self.name, self.track, self.t0,
                     _now_us() - self.t0, attrs or None))
        return False


class _NullSpan:
    """Shared no-op context manager returned while tracing is disabled —
    the zero-allocation fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False


_NULL_SPAN = _NullSpan()


def span(name: str, track: str = "engine", **attrs):
    """Context manager recording one complete span on ``track``.  Returns
    the shared no-op singleton when tracing is disabled."""
    if not _ENABLED:
        return _NULL_SPAN
    return _Span(name, track, attrs)


# -- recorder introspection --------------------------------------------------


def records() -> list:
    """The held records, oldest first (a copy — safe to iterate while
    recording continues)."""
    return list(_REC)


def clear() -> None:
    global _TOTAL
    _REC.clear()
    _TOTAL = 0


def dropped() -> int:
    """Records overwritten by the ring bound since the last clear."""
    return _TOTAL - len(_REC)


def dump(path: str, *, registry_snapshot: Optional[dict] = None) -> str:
    """Write the recorder as Chrome/Perfetto trace JSON (see
    ``repro.obs.export``).  Returns ``path``."""
    from repro.obs.export import to_chrome_trace
    from repro.ioutil import atomic_write_json

    atomic_write_json(path, to_chrome_trace(
        records(), registry_snapshot=registry_snapshot, dropped=dropped()))
    return path


def postmortem(reason: str, path: Optional[str] = None) -> Optional[str]:
    """Dump the flight recorder on a failure.  No-op (returns None) when
    tracing is disabled or nothing was recorded — the hook must be safe
    to leave on every error path."""
    if not _ENABLED or not _REC:
        return None
    from repro.obs.registry import REGISTRY

    safe = "".join(c if c.isalnum() or c in "-_" else "_" for c in reason)
    return dump(path or f"obs_postmortem_{safe}.json",
                registry_snapshot=REGISTRY.snapshot())

"""Exporters for the flight recorder: Chrome/Perfetto, JSONL, Prometheus.

The recorder (``repro.obs.trace``) holds tuples
``(ph, name, track, ts_us, dur_us, attrs)``.  This module turns them
into things tools understand:

* :func:`to_chrome_trace` — the Chrome ``trace_event`` JSON format
  (https://ui.perfetto.dev loads it directly).  Each recorder *track*
  becomes a thread row (``tid``) under one process (``pid=1``), named by
  a ``ph: "M"`` ``thread_name`` metadata event, so the timeline reads:
  one row per request (``req:<uid>``), one engine row, one controller
  row, one faults row, one kernel row, one train row.
* :func:`to_jsonl` — one JSON object per line, for grep/jq pipelines.
* :func:`prometheus_text` — text exposition of a registry snapshot.
* :func:`phase_breakdown` — span-name aggregation (count/total/mean ms),
  the summary that lands in ``BENCH_serve.json`` and CI job output.
* :func:`validate_chrome_trace` — the schema check used by tests and the
  CI obs-smoke job: required fields on every event, and ``"X"`` spans on
  a given row must nest (disjoint or contained, never partially
  overlapping).
"""

from __future__ import annotations

import json
from typing import List, Optional

__all__ = [
    "to_chrome_trace", "to_jsonl", "prometheus_text",
    "phase_breakdown", "validate_chrome_trace", "load_trace",
]

#: stable row order for the well-known tracks; request rows (and any
#: other dynamic tracks) follow in first-appearance order.
_CANON_TRACKS = ("engine", "controller", "faults", "kernel", "train",
                 "registry")


def _tid_map(records) -> dict:
    tids = {}
    for t in _CANON_TRACKS:
        tids[t] = len(tids) + 1
    for rec in records:
        track = rec[2]
        if track not in tids:
            tids[track] = len(tids) + 1
    return tids


def to_chrome_trace(records, *, registry_snapshot: Optional[dict] = None,
                    dropped: int = 0) -> dict:
    """Render recorder tuples as a Chrome ``trace_event`` JSON document."""
    tids = _tid_map(records)
    events: List[dict] = []
    for track, tid in tids.items():
        events.append({"ph": "M", "ts": 0, "pid": 1, "tid": tid,
                       "name": "thread_name", "args": {"name": track}})
    for ph, name, track, ts, dur, attrs in records:
        ev = {"ph": ph, "ts": ts, "pid": 1, "tid": tids[track],
              "name": name}
        if ph == "X":
            ev["dur"] = dur
        elif ph == "i":
            ev["s"] = "t"  # thread-scoped instant
        if attrs:
            ev["args"] = dict(attrs)
        events.append(ev)
    meta = {"tool": "repro.obs", "dropped_records": dropped}
    if registry_snapshot is not None:
        meta["registry"] = registry_snapshot
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "metadata": meta}


def to_jsonl(records) -> str:
    """One JSON object per recorder tuple, oldest first."""
    lines = []
    for ph, name, track, ts, dur, attrs in records:
        obj = {"ph": ph, "name": name, "track": track, "ts_us": ts}
        if ph == "X":
            obj["dur_us"] = dur
        if attrs:
            obj["attrs"] = dict(attrs)
        lines.append(json.dumps(obj, sort_keys=True))
    return "\n".join(lines) + ("\n" if lines else "")


def _prom_name(s: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in s)


def _prom_label(s: str) -> str:
    return s.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def prometheus_text(snapshot: dict, prefix: str = "repro") -> str:
    """Text exposition of a ``TelemetryRegistry.snapshot()`` dict.

    Scalars become untyped samples; family dicts become one sample per
    key under a ``key`` label; histogram snapshots expand into
    ``_bucket{le=...}`` / ``_sum`` / ``_count`` series.
    """
    out: List[str] = []
    for name, val in sorted(snapshot.items()):
        metric = f"{prefix}_{_prom_name(name)}"
        if isinstance(val, dict) and "buckets" in val:
            out.append(f"# TYPE {metric} histogram")
            for le, c in val["buckets"].items():
                out.append(f'{metric}_bucket{{le="{_prom_label(le)}"}} {c}')
            out.append(f"{metric}_sum {val['sum']}")
            out.append(f"{metric}_count {val['count']}")
        elif isinstance(val, dict):
            out.append(f"# TYPE {metric} counter")
            for k, v in sorted(val.items()):
                out.append(f'{metric}{{key="{_prom_label(k)}"}} {v}')
        else:
            out.append(f"# TYPE {metric} gauge")
            out.append(f"{metric} {val}")
    return "\n".join(out) + ("\n" if out else "")


def phase_breakdown(records) -> dict:
    """Aggregate ``"X"`` spans by name: count, total ms, mean ms.

    This is the "where did the time go" summary: prefill vs decode_chunk
    vs queued, per span name, sorted by total descending.
    """
    agg = {}
    for ph, name, _track, _ts, dur, _attrs in records:
        if ph != "X":
            continue
        c, t = agg.get(name, (0, 0))
        agg[name] = (c + 1, t + dur)
    out = {}
    for name, (c, t_us) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        out[name] = {"count": c, "total_ms": round(t_us / 1e3, 3),
                     "mean_ms": round(t_us / 1e3 / c, 4)}
    return out


def load_trace(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def validate_chrome_trace(doc: dict) -> List[str]:
    """Schema-check a Chrome trace document.  Returns a list of problem
    strings — empty means valid.  Checks: top-level shape, required
    fields per event (``ph/ts/pid/tid/name``, ``dur`` on ``"X"``), and
    proper nesting of ``"X"`` spans within each ``tid`` (two spans on one
    row must be disjoint or one must contain the other)."""
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    spans_by_tid = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            problems.append(f"event[{i}] not an object")
            continue
        for field in ("ph", "ts", "pid", "tid", "name"):
            if field not in ev:
                problems.append(f"event[{i}] ({ev.get('name')!r}) missing "
                                f"required field {field!r}")
        ph = ev.get("ph")
        if ph == "X":
            if not isinstance(ev.get("dur"), int) or ev["dur"] < 0:
                problems.append(
                    f"event[{i}] ({ev.get('name')!r}) X-span without "
                    "non-negative integer dur")
            else:
                spans_by_tid.setdefault(ev.get("tid"), []).append(
                    (ev["ts"], ev["ts"] + ev["dur"], ev.get("name")))
        elif ph not in ("i", "I", "M", "C", "B", "E"):
            problems.append(f"event[{i}] unknown phase {ph!r}")
    for tid, spans in spans_by_tid.items():
        spans.sort()
        stack = []  # (start, end, name) of open enclosing spans
        for s, e, name in spans:
            while stack and s >= stack[-1][1]:
                stack.pop()
            if stack and e > stack[-1][1]:
                problems.append(
                    f"tid {tid}: span {name!r} [{s},{e}] partially overlaps "
                    f"{stack[-1][2]!r} [{stack[-1][0]},{stack[-1][1]}]")
            stack.append((s, e, name))
    return problems

"""CLI for recorded traces: ``python -m repro.obs <cmd> trace.json``.

Subcommands:

* ``summarize`` — phase breakdown (span name → count/total/mean ms),
  instant-event counts per track, registry snapshot highlights, and the
  dropped-record count.  The default when you just want to know where
  the time went without opening Perfetto.
* ``validate`` — run the Chrome-trace schema check; exit 1 with the
  problem list on failure (this is what CI's obs-smoke job calls).
* ``convert`` — re-export a Chrome trace as JSONL (``--to jsonl``) or a
  Prometheus text exposition of its embedded registry snapshot
  (``--to prom``), to stdout or ``--out PATH``.
"""

from __future__ import annotations

import argparse
import collections
import sys

from repro.obs.export import (
    load_trace, phase_breakdown, prometheus_text, validate_chrome_trace,
)


def _records_from_doc(doc: dict) -> list:
    """Invert ``to_chrome_trace``: Chrome events back to recorder tuples."""
    names = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            names[ev["tid"]] = ev.get("args", {}).get("name", str(ev["tid"]))
    recs = []
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph not in ("X", "i"):
            continue
        recs.append((ph, ev.get("name"), names.get(ev.get("tid"),
                                                   str(ev.get("tid"))),
                     ev.get("ts", 0), ev.get("dur", 0), ev.get("args")))
    return recs


def _summarize(doc: dict) -> str:
    recs = _records_from_doc(doc)
    lines = []
    meta = doc.get("metadata", {})
    n_spans = sum(1 for r in recs if r[0] == "X")
    n_inst = sum(1 for r in recs if r[0] == "i")
    lines.append(f"events: {n_spans} spans, {n_inst} instants"
                 f" (dropped: {meta.get('dropped_records', 0)})")
    phases = phase_breakdown(recs)
    if phases:
        lines.append("\nphase breakdown (spans):")
        lines.append(f"  {'name':<24} {'count':>7} {'total_ms':>10} "
                     f"{'mean_ms':>9}")
        for name, row in phases.items():
            lines.append(f"  {name:<24} {row['count']:>7} "
                         f"{row['total_ms']:>10.3f} {row['mean_ms']:>9.4f}")
    by_track = collections.Counter()
    for ph, name, track, _ts, _dur, _attrs in recs:
        if ph == "i":
            by_track[(track, name)] += 1
    if by_track:
        lines.append("\ninstant events (track/name):")
        for (track, name), n in sorted(by_track.items()):
            lines.append(f"  {track}/{name}: {n}")
    reg = meta.get("registry")
    if reg:
        lines.append("\nregistry snapshot keys: " + ", ".join(sorted(reg)))
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.obs",
                                 description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name in ("summarize", "validate"):
        p = sub.add_parser(name)
        p.add_argument("trace", help="Chrome trace JSON from --trace/dump")
    pc = sub.add_parser("convert")
    pc.add_argument("trace")
    pc.add_argument("--to", choices=("jsonl", "prom"), default="jsonl")
    pc.add_argument("--out", default=None, help="output path (default stdout)")
    args = ap.parse_args(argv)

    doc = load_trace(args.trace)
    if args.cmd == "validate":
        problems = validate_chrome_trace(doc)
        if problems:
            for p in problems:
                print(f"INVALID: {p}", file=sys.stderr)
            return 1
        n = sum(1 for e in doc.get("traceEvents", [])
                if e.get("ph") in ("X", "i"))
        print(f"OK: {args.trace} valid ({n} events)")
        return 0
    if args.cmd == "summarize":
        print(_summarize(doc))
        return 0
    # convert
    if args.to == "jsonl":
        from repro.obs.export import to_jsonl
        text = to_jsonl(_records_from_doc(doc))
    else:
        reg = doc.get("metadata", {}).get("registry")
        if reg is None:
            print("trace has no embedded registry snapshot", file=sys.stderr)
            return 1
        text = prometheus_text(reg)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {args.out}")
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""repro.obs — tracing, flight recorder, and telemetry for the stack.

Three pieces (see ``docs/observability.md`` for the user guide):

* ``repro.obs.trace`` — span/event API over a bounded ring-buffer flight
  recorder.  Off by default; ``enable()`` to record.
* ``repro.obs.registry`` — the unified :class:`TelemetryRegistry` that
  absorbs the dispatch/kernel/engine/trace-event counter stores.
* ``repro.obs.export`` — Chrome/Perfetto, JSONL, and Prometheus
  exporters plus schema validation and the phase-breakdown summary.

``python -m repro.obs`` summarizes, converts, or validates a recorded
trace file.
"""

from repro.obs.trace import (  # noqa: F401
    enable, disable, enabled, span, event, complete,
    records, clear, dropped, dump, postmortem,
)
from repro.obs.registry import (  # noqa: F401
    REGISTRY, TelemetryRegistry, snapshot_diff,
)
from repro.obs.export import (  # noqa: F401
    to_chrome_trace, to_jsonl, prometheus_text,
    phase_breakdown, validate_chrome_trace,
)

__all__ = [
    "enable", "disable", "enabled", "span", "event", "complete",
    "records", "clear", "dropped", "dump", "postmortem",
    "REGISTRY", "TelemetryRegistry", "snapshot_diff",
    "to_chrome_trace", "to_jsonl", "prometheus_text",
    "phase_breakdown", "validate_chrome_trace",
]

"""Unified telemetry registry: typed counters, gauges, histograms.

Before this module the stack held four disjoint metric stores —
``ServeEngine.stats`` (a plain dict), ``core/dispatch.py``'s
``_DISPATCH_COUNTS``, ``kernels/ops.py``'s ``_KERNEL_COUNTS`` (both bare
``collections.Counter``), and ``serve/tracecount.py``'s trace-event
counter.  Each had its own reset function, its own conftest line, and no
common snapshot.  The :class:`TelemetryRegistry` absorbs all four:

* **Counter** — monotonically increasing scalar (``inc``);
* **Gauge** — last-write-wins scalar (``set``);
* **Histogram** — fixed-bucket observation counts plus sum/count, enough
  for Prometheus exposition and p50-ish summaries without keeping raws;
* **CounterFamily** — a ``collections.Counter`` subclass keyed by
  tuples/strings.  This is the compatibility layer: the existing
  ``_KERNEL_COUNTS[(kernel, path)] += 1`` call sites keep working
  verbatim because ``Counter.__iadd__`` on an item is ``__setitem__``,
  which we override to (optionally) also emit a flight-recorder event —
  so every kernel route and every JIT trace shows up on the timeline for
  free, at zero call-site churn.

The registry is deliberately pure-stdlib with a lazy import of
``repro.obs.trace`` only inside the event hook: ``core/dispatch`` and
``kernels/ops`` import this module at module scope, so it must not pull
in anything heavy or circular.

Snapshots are plain nested dicts (JSON-ready).  ``snapshot_diff`` gives
per-benchmark deltas; :func:`reset` clears contents *in place* so
module-level references held by dispatch/ops/engine survive the conftest
hygiene fixture.
"""

from __future__ import annotations

import collections
import threading
from typing import Dict, Iterable, Optional, Tuple, Union

__all__ = [
    "Counter", "Gauge", "Histogram", "CounterFamily", "MirroredCounters",
    "TelemetryRegistry", "REGISTRY", "snapshot_diff",
]

MetricKey = Union[str, Tuple]


def _key_str(key: MetricKey) -> str:
    if isinstance(key, tuple):
        return "/".join(_key_str(k) for k in key)
    return str(key)


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def snapshot(self):
        return self.value


class Gauge:
    """Last-write-wins scalar."""

    __slots__ = ("name", "help", "value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0

    def snapshot(self):
        return self.value


#: default histogram bucket bounds, in seconds — spans per-token decode
#: latencies (sub-ms) through prefill and full-request walls.
DEFAULT_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)


class Histogram:
    """Fixed-bucket histogram with cumulative-style snapshot.

    Buckets hold non-cumulative counts internally; ``snapshot`` reports
    ``le``-labelled cumulative counts plus ``sum``/``count`` so the
    Prometheus exposition can render it directly.
    """

    __slots__ = ("name", "help", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self.counts = [0] * (len(self.buckets) + 1)  # +1 for +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def reset(self) -> None:
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def snapshot(self):
        cum, out = 0, {}
        for b, c in zip(self.buckets, self.counts):
            cum += c
            out[f"{b:g}"] = cum
        out["+Inf"] = cum + self.counts[-1]
        return {"buckets": out, "sum": self.sum, "count": self.count}


class CounterFamily(collections.Counter):
    """Keyed counter compatible with existing ``Counter`` call sites.

    ``fam[key] += 1`` works unchanged (it is ``__getitem__`` then
    ``__setitem__``); on *increase* the family optionally emits a
    flight-recorder instant event named ``trace_as`` on ``track`` with
    the key flattened into attrs.  Decreases and wholesale
    ``clear``/``update``/``copy`` (used by ``predict_route``'s
    snapshot/restore) never emit.
    """

    def __init__(self, *args, name: str = "", help: str = "",
                 trace_as: Optional[str] = None, track: str = "registry",
                 **kwargs):
        self.name = name
        self.help = help
        self.trace_as = trace_as
        self.track = track
        self._muted = 0
        super().__init__(*args, **kwargs)

    def __setitem__(self, key, value):
        if self.trace_as is not None and not self._muted:
            old = super().get(key, 0)
            if value > old:
                from repro.obs import trace as _trace
                if _trace.enabled():
                    _trace.counter_event(
                        self.trace_as, self.track,
                        {"key": _key_str(key), "n": value - old})
        super().__setitem__(key, value)

    # Counter.copy() calls self.__class__(self); our __init__ accepts the
    # mapping positionally, but the copy should be a plain Counter so the
    # checker's snapshot/restore dance never double-emits events.
    def copy(self):
        return collections.Counter(self)

    def update(self, *args, **kwargs):
        # Bulk restore path (predict_route) — not new activity; stay silent.
        self._muted += 1
        try:
            super().update(*args, **kwargs)
        finally:
            self._muted -= 1

    def reset(self) -> None:
        self.clear()

    def snapshot(self):
        return {_key_str(k): v for k, v in self.items()}


class MirroredCounters(dict):
    """A dict of named counters (the engine's ``stats``) that mirrors
    positive deltas into a :class:`CounterFamily` so the registry snapshot
    includes engine stats without the engine changing its accounting.
    Plain-dict reads/iteration behave identically to the original."""

    def __init__(self, initial: dict, family: "CounterFamily"):
        super().__init__(initial)
        self._family = family

    def __setitem__(self, key, value):
        old = self.get(key, 0)
        if isinstance(value, (int, float)) and value > old:
            self._family[key] += value - old
        super().__setitem__(key, value)


class TelemetryRegistry:
    """Registry of named metrics with idempotent constructors.

    ``counter``/``gauge``/``histogram``/``family`` return the existing
    metric when the name is already registered (so repeated imports and
    engine re-instantiation share one instrument).  ``reset`` zeroes
    contents in place — module-level references stay valid.
    """

    def __init__(self):
        self._metrics: Dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_make(self, name, factory, kind):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = factory()
                self._metrics[name] = m
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {kind.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_make(name, lambda: Counter(name, help), Counter)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_make(name, lambda: Gauge(name, help), Gauge)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(
            name, lambda: Histogram(name, help, buckets), Histogram)

    def family(self, name: str, help: str = "",
               trace_as: Optional[str] = None,
               track: str = "registry") -> CounterFamily:
        return self._get_or_make(
            name,
            lambda: CounterFamily(name=name, help=help,
                                  trace_as=trace_as, track=track),
            CounterFamily)

    def metrics(self) -> Dict[str, object]:
        return dict(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready nested dict of every registered metric's state."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()


def snapshot_diff(before: dict, after: dict) -> dict:
    """Per-run delta of two :meth:`TelemetryRegistry.snapshot` dicts.

    Scalars subtract; family dicts subtract per-key keeping non-zero
    entries; histogram snapshots subtract sum/count (bucket deltas are
    rarely useful per-run, so only the totals diff).  Metrics absent from
    ``before`` diff against zero.
    """
    out = {}
    for name, av in after.items():
        bv = before.get(name)
        if isinstance(av, dict) and "buckets" in av:
            bsum = bv["sum"] if isinstance(bv, dict) else 0.0
            bcnt = bv["count"] if isinstance(bv, dict) else 0
            d = {"sum": av["sum"] - bsum, "count": av["count"] - bcnt}
            if d["count"]:
                out[name] = d
        elif isinstance(av, dict):
            bd = bv if isinstance(bv, dict) else {}
            d = {k: v - bd.get(k, 0) for k, v in av.items()
                 if v - bd.get(k, 0)}
            if d:
                out[name] = d
        else:
            d = av - (bv if isinstance(bv, (int, float)) else 0)
            if d:
                out[name] = d
    return out


#: the process-wide registry.  dispatch/ops/engine/slo/faults all hang
#: their instruments off this instance; the conftest hygiene fixture
#: resets it between tests.
REGISTRY = TelemetryRegistry()

"""The ``sten``-style user API (paper §3) in one namespace.

>>> from repro import sten
>>> w = sten.dense_to_grouped_nm(W, n=1, m=4, g=16)
>>> y = sten.linear(x, w)                       # dispatches to the kernel
>>> sb = sten.SparsityBuilder()
>>> sb.set_weight("mlp.wi", sten.GroupedNMSparsifier(1, 4, 16))
>>> sparse_params, apply = sb.get_sparse_model(params, model.apply)
"""

from repro.core import *  # noqa: F401,F403
from repro.core import (  # explicit re-exports for clarity
    SparsityBuilder,
    sparsified_op,
    register_layout,
    register_op_impl,
    register_sparsifier_implementation,
)


def torch_tensor_to_csr(sparsifier, x):
    """Paper §3.1 convenience spelling: sparsify a dense tensor to CSR."""
    from repro.core.layouts import CsrTensor
    from repro.core.sparsifiers import apply_sparsifier

    return apply_sparsifier(sparsifier, x, CsrTensor)

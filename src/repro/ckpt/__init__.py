from repro.ckpt.checkpoint import CheckpointManager, load_pytree, save_pytree

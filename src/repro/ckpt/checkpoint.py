"""Fault-tolerant checkpointing: sharded npz + manifest, async writer,
atomic commit, integrity hashes, elastic restore.

Layout of a checkpoint directory::

    <root>/step_000120/
        shard_00000.npz      # this process's param/opt leaves
        MANIFEST.json        # treedef, leaf index, content hashes, meta
    <root>/LATEST            # atomic pointer (written last)

Design points for 1000+-node fleets:
  * every process writes only its own addressable shards (here: one process,
    whole tree — the per-leaf layout and manifest generalize);
  * the manifest is committed *after* all data, and LATEST after the
    manifest — a crashed writer can never produce a readable-but-corrupt
    checkpoint (restore validates hashes);
  * async save: the train loop hands off host copies and keeps stepping;
  * elastic restore: leaves are resharded to whatever mesh the restoring
    job uses (values are stored unsharded per leaf here, so any mesh works);
  * sparse layouts are pytrees, so sparse checkpoints need zero extra code
    — layout metadata rides in the treedef.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "load_pytree", "CheckpointManager"]


def _leaf_paths(tree):
    from repro.core.builder import path_name
    from repro.core.layouts import SparsityLayout

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(path_name(p), v) for p, v in flat]


def save_pytree(tree, directory: str | pathlib.Path, *, meta: Optional[dict] = None):
    """Synchronous atomic checkpoint write."""
    d = pathlib.Path(directory)
    tmp = d.with_name(d.name + ".tmp")
    tmp.mkdir(parents=True, exist_ok=True)

    leaves = _leaf_paths(tree)
    arrays = {}
    index = []
    hasher_all = hashlib.sha256()
    for i, (name, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
            # numpy npz cannot store ml_dtypes (bfloat16 etc.): store the
            # raw bits and record the logical dtype in the manifest
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        key = f"leaf_{i:05d}"
        arrays[key] = arr
        h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
        hasher_all.update(h.encode())
        index.append({"name": name, "key": key, "shape": list(arr.shape),
                      "dtype": logical_dtype, "sha": h})
    np.savez(tmp / "shard_00000.npz", **arrays)
    manifest = {
        "version": 1,
        "created": time.time(),
        "num_leaves": len(index),
        "index": index,
        "tree_hash": hasher_all.hexdigest()[:16],
        "meta": meta or {},
    }
    (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=1))
    if d.exists():
        import shutil

        shutil.rmtree(d)
    tmp.rename(d)  # atomic commit
    return manifest


def load_pytree(template, directory: str | pathlib.Path, *,
                shardings=None, validate: bool = True):
    """Restore into the structure of ``template`` (arrays or
    ShapeDtypeStructs).  With ``shardings`` the leaves are device_put onto
    the restoring job's mesh — elastic restore onto any device count."""
    d = pathlib.Path(directory)
    manifest = json.loads((d / "MANIFEST.json").read_text())
    data = np.load(d / "shard_00000.npz")

    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    if len(manifest["index"]) != len(leaves_t):
        raise ValueError(
            f"checkpoint has {len(manifest['index'])} leaves, template has "
            f"{len(leaves_t)} — structure mismatch"
        )
    out = []
    sh_leaves = (jax.tree_util.tree_leaves(shardings)
                 if shardings is not None else [None] * len(leaves_t))
    for entry, tmpl, sh in zip(manifest["index"], leaves_t, sh_leaves):
        arr = data[entry["key"]]
        if validate:
            h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if h != entry["sha"]:
                raise IOError(f"checkpoint leaf {entry['name']} hash mismatch")
        if str(arr.dtype) != entry["dtype"]:
            # bit-stored ml_dtypes leaf: view back to the logical dtype
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, entry["dtype"], None)
                                    or entry["dtype"]))
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"leaf {entry['name']}: checkpoint shape {arr.shape} != "
                f"template {tmpl.shape}"
            )
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["meta"]


class CheckpointManager:
    """Async, rotating checkpoint manager with a LATEST pointer."""

    def __init__(self, root: str | pathlib.Path, *, keep: int = 3):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def step_dir(self, step: int) -> pathlib.Path:
        return self.root / f"step_{step:08d}"

    def save(self, step: int, tree, *, meta: Optional[dict] = None,
             blocking: bool = False):
        """Device->host copy happens on the caller thread (cheap, and the
        arrays are then immutable); serialization + fsync on a worker."""
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )
        meta = dict(meta or {}, step=step)

        def work():
            try:
                save_pytree(host_tree, self.step_dir(step), meta=meta)
                (self.root / "LATEST.tmp").write_text(str(step))
                (self.root / "LATEST.tmp").rename(self.root / "LATEST")
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def latest_step(self) -> Optional[int]:
        p = self.root / "LATEST"
        if not p.exists():
            return None
        step = int(p.read_text().strip())
        return step if self.step_dir(step).exists() else None

    def restore_latest(self, template, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, meta = load_pytree(template, self.step_dir(step),
                                 shardings=shardings)
        return step, tree, meta

    def _gc(self):
        dirs = sorted(self.root.glob("step_*"))
        for d in dirs[: -self.keep]:
            import shutil

            shutil.rmtree(d, ignore_errors=True)

"""Version-portable ``shard_map``.

jax moved ``shard_map`` from ``jax.experimental.shard_map`` to the top
level and renamed its replication-check kwarg (``check_rep`` ->
``check_vma``) across releases.  Callers import :func:`shard_map` from here
and may pass either kwarg name; the shim forwards to whatever this jax
provides.
"""

from __future__ import annotations

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map(f=None, **kw):
    """``jax.shard_map`` / ``jax.experimental.shard_map.shard_map``, with
    ``check_vma``/``check_rep`` accepted interchangeably.  Usable directly
    or as ``functools.partial(shard_map, mesh=..., ...)`` decorator."""
    for alias in ("check_vma", "check_rep"):
        if alias in kw and alias != _CHECK_KW:
            kw[_CHECK_KW] = kw.pop(alias)
    if f is None:
        return lambda fn: _shard_map(fn, **kw)
    return _shard_map(f, **kw)

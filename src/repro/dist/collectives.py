"""Sparse-gradient collectives (paper §6.1 + beyond-paper fast path).

The paper's distributed masked-sparse training exchanges gradients the
portable way: densify, all-reduce the dense buffer, re-sparsify
(:func:`densify_allreduce_resparsify`).  Because a
:class:`~repro.core.layouts.FixedMaskTensor`'s pattern is *fixed* across
steps and identical on every data-parallel replica, the exchange only needs
the value buffer — :func:`fixed_mask_value_allreduce` skips the densify and
the mask re-apply entirely (and, for genuinely compressed layouts, would
move nnz-sized payloads; see dist/compression.py for the top-k variant).

All reductions are *mean* reductions (the data-parallel gradient
convention), implemented with a real ``pmean`` under ``shard_map`` so the
collective appears in lowered HLO.  Under the single-controller test
harness the inputs are replicated over the mesh axis; on a multi-host fleet
the same functions apply per-replica contributions.
"""

from __future__ import annotations

import functools

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.layouts import FixedMaskTensor
from repro.dist.compat import shard_map

__all__ = [
    "allreduce_mean",
    "densify_allreduce_resparsify",
    "fixed_mask_value_allreduce",
]


def allreduce_mean(x, mesh: Mesh, axis: str):
    """Mean-all-reduce a dense array over one mesh axis.

    The input is treated as each replica's full (unsharded) contribution;
    the body runs per-device and ``pmean``s over ``axis``.
    """
    @functools.partial(
        shard_map, mesh=mesh, in_specs=(P(),), out_specs=P(),
        check_vma=False,
    )
    def _mean(v):
        return jax.lax.pmean(v, axis)

    return _mean(x)


def densify_allreduce_resparsify(g: FixedMaskTensor, mesh: Mesh,
                                 axis: str) -> FixedMaskTensor:
    """The paper-faithful exchange: ``to_dense`` -> all-reduce -> re-mask.

    Moves a full dense buffer per layer and re-applies the mask afterwards
    (the re-sparsify step of SameFormatSparsifier specialized to a fixed
    pattern).  Correct for any mask configuration, including replicas whose
    masks disagree mid-recompute.
    """
    dense = allreduce_mean(g.to_dense(), mesh, axis)
    mask = g.mask
    return FixedMaskTensor(dense * mask.astype(dense.dtype), mask, g.origin)


def fixed_mask_value_allreduce(g: FixedMaskTensor, mesh: Mesh,
                               axis: str) -> FixedMaskTensor:
    """Beyond-paper fast path: all-reduce *values only* under a shared mask.

    Valid whenever every replica holds the same mask — true between pattern
    recomputes in masked sparse training (the common case; recomputes are
    collective-scheduled).  Skips the densify and the post-reduce masking:
    masked-out value slots may accumulate garbage, but ``to_dense`` masks
    them out by construction, so the result equals
    :func:`densify_allreduce_resparsify` exactly when masks agree.
    """
    return FixedMaskTensor(
        allreduce_mean(g.val, mesh, axis), g.mask, g.origin
    )

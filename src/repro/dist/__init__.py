"""Distributed sparsity: sharding rules, sparse collectives, compression,
and elasticity (see docs/architecture.md §distributed).

Submodules:
  * ``sharding``    — logical-axis rules, param/batch specs, constraints
  * ``collectives`` — densify-allreduce-resparsify + value-only fast path
  * ``compression`` — top-k + error-feedback gradient exchange
  * ``elastic``     — straggler watchdog and remesh planning
  * ``compat``      — version-portable ``shard_map``
"""

from repro.dist.collectives import (
    allreduce_mean,
    densify_allreduce_resparsify,
    fixed_mask_value_allreduce,
)
from repro.dist.compression import compressed_allreduce, ef_step
from repro.dist.elastic import StragglerWatchdog, plan_remesh
from repro.dist.sharding import (
    ShardingRules,
    active_rules,
    batch_spec,
    logical_constraint,
    param_specs,
    tree_shardings,
    use_rules,
)

"""Elasticity & fault-tolerance primitives for the training loop.

Two pieces the launch layer composes (launch/train.py):

  * :class:`StragglerWatchdog` — per-host step-time tracking that flags
    hosts running persistently slower than the fleet median, the trigger
    for evicting a sick host and re-meshing;
  * :func:`plan_remesh` — given the surviving chip count, the largest
    ``(data, model)`` mesh that preserves the model-parallel degree (model
    shards must stay intact because params are sharded over them; the
    data-parallel degree is free to shrink).

Both are plain Python (no jax state): they run on the controller between
steps, and checkpoints (ckpt/checkpoint.py) carry the actual state across
the restart.
"""

from __future__ import annotations

import statistics
from collections import deque
from typing import Deque, List

__all__ = ["StragglerWatchdog", "plan_remesh"]


def plan_remesh(n_chips: int, model_parallel: int):
    """Largest ``(data, model)`` mesh shape on ``n_chips`` surviving chips.

    Keeps ``model_parallel`` fixed (param shards must stay whole) and
    floors the data-parallel degree; chips beyond ``data * model`` idle
    until the fleet heals.  Raises ``ValueError`` when fewer chips survive
    than one model-parallel group needs.
    """
    if n_chips < model_parallel:
        raise ValueError(
            f"cannot remesh: {n_chips} chips < model_parallel="
            f"{model_parallel} (one full model shard group is required)"
        )
    return (n_chips // model_parallel, model_parallel)


class StragglerWatchdog:
    """Flags hosts whose recent step times exceed the fleet median.

    ``observe(host, seconds)`` records one step; :meth:`stragglers` returns
    the hosts whose median over the last ``window`` observations is more
    than ``ratio`` times the across-host median — persistent slowness, not
    one-step jitter.  Silent until every host has ``min_steps``
    observations (cold-start compile steps would otherwise trip it).
    """

    def __init__(self, n_hosts: int, *, min_steps: int = 5,
                 ratio: float = 2.0, window: int = 20):
        self.n_hosts = n_hosts
        self.min_steps = min_steps
        self.ratio = ratio
        self.window = window
        # bounded per-host history: only the last `window` steps are read
        self._times: List[Deque[float]] = [
            deque(maxlen=window) for _ in range(n_hosts)
        ]
        self._seen: List[int] = [0] * n_hosts

    def observe(self, host: int, seconds: float) -> None:
        """Record one step duration for ``host``."""
        self._times[host].append(float(seconds))
        self._seen[host] += 1

    def stragglers(self) -> List[int]:
        """Hosts currently flagged as persistently slow (sorted).

        Each warmed-up host is compared against the median of the *other*
        warmed-up hosts — including a host in its own reference would make a
        2-host straggler (or half a fleet) mathematically unflaggable.
        Hosts still below ``min_steps`` are excluded from consideration
        (cold-start compiles) but do not silence the rest of the fleet.
        """
        warm = [h for h in range(self.n_hosts)
                if self._seen[h] >= self.min_steps]
        if len(warm) < 2:
            return []
        meds = {h: statistics.median(self._times[h]) for h in warm}
        out = []
        for h in warm:
            ref = statistics.median([meds[o] for o in warm if o != h])
            if meds[h] > self.ratio * ref:
                out.append(h)
        return out

"""Top-k gradient compression with error feedback (sparse grad exchange).

For *unstructured* gradient sparsification (Hoefler et al. 2021 §"sparse
gradient exchange"; paper §3.4's ``set_weight_grad`` makes this a
first-class STen hook) the densify-exchange-resparsify route wastes
bandwidth: only the top-k entries matter.  :func:`ef_step` selects them and
banks the complement in an error-feedback residual so nothing is lost over
time; :func:`compressed_allreduce` exchanges the (values, indices) payload
and returns the dense mean.

Shapes are static (k is a Python int derived from ``k_fraction``), so both
functions trace cleanly under jit.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.dist.collectives import allreduce_mean

__all__ = ["ef_step", "compressed_allreduce"]


def ef_step(grad, memory, *, k_fraction: float):
    """One error-feedback compression step.

    Adds the residual ``memory`` to ``grad``, keeps the ``k_fraction``
    largest-magnitude entries as a ``(values, flat_indices)`` payload, and
    returns the new residual holding exactly the complement:
    ``scatter(values, indices) + new_memory == grad + memory``.

    Returns ``((values [k], indices [k] int32), new_memory)``.
    """
    acc = (grad + memory).reshape(-1)
    k = max(1, min(acc.shape[0], int(acc.shape[0] * k_fraction)))
    _, idx = jax.lax.top_k(jnp.abs(acc), k)
    idx = idx.astype(jnp.int32)
    vals = acc[idx]
    new_memory = acc.at[idx].set(0).reshape(grad.shape)
    return (vals, idx), new_memory


def compressed_allreduce(vals, idx, shape, mesh: Mesh, axis: str):
    """Mean-all-reduce top-k payloads into a dense array of ``shape``.

    Each replica contributes ``(vals, idx)`` from :func:`ef_step`; payloads
    are scattered into a dense accumulator which is mean-reduced over
    ``axis``.  (Scatter-then-reduce keeps the implementation layout-free; a
    bandwidth-optimal version would all-gather the k-sized payloads and
    scatter once — same result, fewer bytes.)
    """
    size = int(math.prod(shape))
    dense = jnp.zeros((size,), vals.dtype).at[idx].add(vals)
    return allreduce_mean(dense, mesh, axis).reshape(shape)

"""Logical-axis sharding rules for sparse and dense param pytrees.

Model code names *logical* axes ("batch", "seq", "heads", "ff", "expert",
"vocab", "embed"); this module maps them onto *mesh* axes ("pod", "data",
"model") so the model stack stays mesh-agnostic (see models/common.py).
Three pieces:

  * :class:`ShardingRules` — a frozen dataclass holding the logical->mesh
    assignment, with :meth:`ShardingRules.resolve` filtering each rule down
    to the axes a concrete mesh actually has (so the same rules object works
    on the 2-axis host mesh and the 3-axis multi-pod production mesh);
  * :func:`use_rules` / :func:`active_rules` — trace-time context management
    so :func:`logical_constraint` calls inside model code can find the
    active (mesh, rules) pair without threading it through every function;
  * :func:`param_specs` / :func:`batch_spec` / :func:`tree_shardings` —
    path-pattern mapping from a params pytree to ``PartitionSpec`` /
    ``NamedSharding`` trees.  Sparse layout leaves are first-class: a
    :class:`~repro.core.layouts.FixedMaskTensor`'s value and mask receive
    *identical* specs (an exchange or matmul over mismatched value/mask
    shards would silently de-align the sparsity pattern), while compressed
    layouts (n:m:g, CSR, COO) replicate — their buffers do not follow the
    dense dims, so replication is the safe default until a layout-aware
    partitioner exists.

Every sharded dim is divisibility-checked against the mesh axes assigned to
it and dropped (replicated) when it does not divide — smoke-scale configs
keep working on wide meshes without per-config rule surgery.
"""

from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Any, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.layouts import FixedMaskTensor, SparsityLayout

__all__ = [
    "Axes",
    "ShardingRules",
    "use_rules",
    "active_rules",
    "divisible",
    "logical_constraint",
    "param_specs",
    "batch_spec",
    "tree_shardings",
]

#: a logical-axis assignment: no sharding, one mesh axis, or several
Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical-axis -> mesh-axis assignment.

    Fields may be ``None`` (replicate), a mesh-axis name, a tuple of names,
    or a comma-separated string (the CLI hillclimb form, e.g.
    ``--opt heads=data,model``).  Defaults give data parallelism over
    ("pod", "data") and tensor/expert parallelism over "model" — the
    production layout the dry-run grid assumes.
    """

    batch: Axes = ("pod", "data")     # token/batch dims of activations
    seq: Axes = None                  # sequence dim (None: no seq-parallel)
    embed: Axes = None                # d_model dim of weights
    heads: Axes = "model"             # attention-head (projection out) dims
    ff: Axes = "model"                # MLP hidden dims
    vocab: Axes = "model"             # vocabulary dims (embedding / lm_head)
    expert: Axes = "model"            # MoE expert dim (expert parallelism)

    def resolve(self, logical: str, avail: Any) -> Axes:
        """Resolve a logical axis to the mesh axes present in ``avail``.

        Returns ``None`` (replicate), a single axis name, or a tuple of
        names.  Unknown logical names resolve to ``None`` so model code can
        constrain axes that a given rules object does not govern.
        """
        spec = getattr(self, logical, None)
        if spec is None:
            return None
        if isinstance(spec, str):
            spec = tuple(s.strip() for s in spec.split(",") if s.strip())
        axes = tuple(a for a in spec if a in avail)
        if not axes:
            return None
        return axes if len(axes) > 1 else axes[0]


# ---------------------------------------------------------------------------
# active-rules context (trace-time, thread-local)
# ---------------------------------------------------------------------------

_ACTIVE = threading.local()


@contextmanager
def use_rules(mesh: Mesh, rules: ShardingRules):
    """Install ``(mesh, rules)`` as the active sharding context.

    Entered inside step functions *before* the model forward so that
    :func:`logical_constraint` calls in model code resolve against the right
    mesh.  The context is a trace-time construct: it only needs to be live
    while jax traces the function, not while the compiled program runs.
    """
    prev = getattr(_ACTIVE, "ctx", None)
    _ACTIVE.ctx = (mesh, rules)
    try:
        yield (mesh, rules)
    finally:
        _ACTIVE.ctx = prev


def active_rules() -> Optional[Tuple[Mesh, ShardingRules]]:
    """The (mesh, rules) installed by :func:`use_rules`, or ``None``."""
    return getattr(_ACTIVE, "ctx", None)


# ---------------------------------------------------------------------------
# spec construction helpers
# ---------------------------------------------------------------------------


def _axes_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    axes = axes if isinstance(axes, tuple) else (axes,)
    k = 1
    for a in axes:
        k *= mesh.shape[a]
    return k


def divisible(total: int, mesh: Mesh, axes: Axes) -> bool:
    """True when ``total`` divides evenly over the mesh axes in ``axes``
    (``None`` trivially divides).  ``axes`` must already be resolved —
    ``None``, a mesh-axis name, or a tuple of names."""
    return total % _axes_size(mesh, axes) == 0


def _flat_axes(dim: Axes) -> Tuple[str, ...]:
    if dim is None:
        return ()
    return dim if isinstance(dim, tuple) else (dim,)


def _key_str(entry) -> str:
    """Best-effort readable name for a tree-path entry."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


class _SpecBuilder:
    """Accumulates a PartitionSpec for one leaf with safety checks:
    divisibility of the dim by the assigned mesh axes, and no mesh axis
    used on two dims of the same leaf."""

    def __init__(self, shape, rules: ShardingRules, mesh: Mesh, avail):
        self.shape = tuple(shape)
        self.rules = rules
        self.mesh = mesh
        self.avail = avail
        self.dims: list = [None] * len(self.shape)

    def put(self, from_end: int, logical: str):
        """Assign ``logical``'s mesh axes to the ``from_end``-th dim counted
        from the last (1 == last dim).  Leading scan/stack dims therefore
        never shift the assignment."""
        i = len(self.shape) - from_end
        if i < 0 or self.dims[i] is not None:
            return
        ax = self.rules.resolve(logical, self.avail)
        if ax is None:
            return
        used = {a for d in self.dims for a in _flat_axes(d)}
        if any(a in used for a in _flat_axes(ax)):
            return
        if self.shape[i] % _axes_size(self.mesh, ax) != 0:
            return
        self.dims[i] = ax

    def spec(self) -> P:
        return P(*self.dims)


def _dense_leaf_spec(parts, shape, rules: ShardingRules, mesh: Mesh,
                     avail) -> P:
    """Path-pattern spec for one dense array leaf.

    Matching is on the param's dict-key path (e.g. ``layers/attn/wq``) and
    always counts dims from the end, so scan-stacked ``[L, ...]`` leaves and
    un-stacked leaves share one rule table.
    """
    b = _SpecBuilder(shape, rules, mesh, avail)
    name = parts[-1] if parts else ""
    in_moe = "moe" in parts
    in_attn = "attn" in parts or "xattn" in parts
    if name == "embedding":
        b.put(2, "vocab")
        b.put(1, "embed")
    elif name == "lm_head":
        b.put(1, "vocab")
        b.put(2, "embed")
    elif in_moe:
        if name == "wi":          # [E, D, F']
            b.put(3, "expert")
            b.put(1, "ff")
        elif name == "wo":        # [E, F, D]
            b.put(3, "expert")
            b.put(2, "ff")
        elif name == "res_wi":    # [D, F']
            b.put(1, "ff")
        elif name == "res_wo":    # [F, D]
            b.put(2, "ff")
        # router stays replicated: tiny, and every rank routes every token
    elif in_attn:
        if name in ("wq", "wk", "wv", "wuq", "wuk", "wuv", "bq", "bk", "bv"):
            b.put(1, "heads")     # projection-out (heads*hd) dim
        elif name == "wo":        # [H*hd, D]
            b.put(2, "heads")
    elif name == "wi" and "mlp" in parts:
        b.put(1, "ff")            # [D, F']
    elif name == "wo" and "mlp" in parts:
        b.put(2, "ff")            # [F, D]
    # norms, biases, ssm params, rope tables: replicated
    return b.spec()


def param_specs(params, rules: ShardingRules, mesh: Mesh):
    """Map a params pytree to a matching tree of ``PartitionSpec``s.

    Accepts concrete arrays or ``jax.eval_shape`` output (anything with
    ``.shape``).  Sparse layout nodes are handled explicitly:

      * :class:`FixedMaskTensor` keeps its dense shape, so the dense rule
        fires once and the *same* spec is applied to both the value and the
        mask child — the mask/value co-sharding invariant the collectives
        rely on;
      * other layouts (compressed buffers) replicate every child.

    The returned tree has the exact treedef of ``params`` (layout nodes are
    rebuilt with spec children), so it is valid for ``jax.device_put`` and
    ``jax.jit`` in/out shardings after :func:`tree_shardings`.
    """
    avail = set(mesh.axis_names)

    def visit(path, leaf):
        parts = [_key_str(k) for k in path]
        if isinstance(leaf, FixedMaskTensor):
            spec = _dense_leaf_spec(parts, leaf.shape, rules, mesh, avail)
            return jax.tree_util.tree_map(lambda _: spec, leaf)
        if isinstance(leaf, SparsityLayout):
            return jax.tree_util.tree_map(lambda _: P(), leaf)
        if leaf is None or not hasattr(leaf, "shape"):
            return None
        return _dense_leaf_spec(parts, leaf.shape, rules, mesh, avail)

    return jax.tree_util.tree_map_with_path(
        visit, params, is_leaf=lambda x: isinstance(x, SparsityLayout)
    )


def batch_spec(x, rules: ShardingRules, mesh: Mesh) -> P:
    """Spec for one batch array: dim 0 over the data-parallel axes (when
    divisible), everything else replicated."""
    shape = tuple(getattr(x, "shape", ()))
    dims = [None] * len(shape)
    dp = rules.resolve("batch", set(mesh.axis_names))
    if shape and dp is not None and shape[0] % _axes_size(mesh, dp) == 0:
        dims[0] = dp
    return P(*dims)


def tree_shardings(specs, mesh: Mesh):
    """Convert a tree of ``PartitionSpec``s into ``NamedSharding``s on
    ``mesh`` (structure preserved; non-spec leaves pass through)."""
    def to_sharding(s):
        if isinstance(s, P):
            return NamedSharding(mesh, s)
        return s

    return jax.tree_util.tree_map(
        to_sharding, specs, is_leaf=lambda s: isinstance(s, P)
    )


# ---------------------------------------------------------------------------
# in-model constraints
# ---------------------------------------------------------------------------


def logical_constraint(x, logical_axes):
    """``with_sharding_constraint`` by logical-axis names.

    ``logical_axes`` is one entry per dim of ``x``: a logical-axis name or
    ``None``.  Resolution uses the :func:`use_rules` context; with no active
    context (single-device smoke runs, unit tests) this is the identity, so
    model code can sprinkle constraints unconditionally.  Dims whose size
    does not divide the assigned mesh axes, and mesh axes already consumed
    by an earlier dim, degrade to replicated rather than erroring.
    """
    ctx = active_rules()
    if ctx is None:
        return x
    mesh, rules = ctx
    avail = set(mesh.axis_names)
    dims: list = [None] * x.ndim
    used: set = set()
    for i, name in enumerate(logical_axes):
        if name is None:
            continue
        ax = rules.resolve(name, avail)
        if ax is None or any(a in used for a in _flat_axes(ax)):
            continue
        if x.shape[i] % _axes_size(mesh, ax) != 0:
            continue
        dims[i] = ax
        used.update(_flat_axes(ax))
    if not used:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*dims))
    )

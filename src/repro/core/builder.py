"""SparsityBuilder — sparsifying existing models (paper §3.4, §4.1).

STen uses torch.fx tracing to find intermediate tensors of an existing model
and replaces operators with dispatcher wrappers.  JAX has no general symbolic
tracer over arbitrary Python, so STen-JAX uses **named intermediate tags**:
model code calls ``tag("block.gelu", x)`` at tensor-producing sites (our model
zoo does this at every activation/projection worth sparsifying), and a
``SparsityBuilder`` plan activated around the forward pass decides — at trace
time — whether that site sparsifies, with which (inline, tmp, external, out)
format.  ``trace_intermediates`` enumerates the taggable sites of a model the
way ``torch.fx`` + ``named_modules`` would (name, shape, dtype), so users can
discover names without reading model code.

Weights are sparsified directly on the params pytree (paths are
``a.b.c``-joined pytree keys, with fnmatch globs supported), mirroring how
"PyTorch Parameters are easily accessible and modifiable" (§4.1).
"""

from __future__ import annotations

import dataclasses
import fnmatch
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.dispatch import OutFormat
from repro.core.layouts import DenseTensor, SparsityLayout
from repro.core.sparsifiers import (
    KeepAll,
    SameFormatSparsifier,
    Sparsifier,
    apply_sparsifier,
)

__all__ = [
    "SparsityBuilder",
    "SparsityPlan",
    "tag",
    "trace_intermediates",
    "path_name",
    "flatten_with_names",
]

_ACTIVE = threading.local()


def path_name(path) -> str:
    """Join a jax tree path into an 'a.b.c' name."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return ".".join(parts)


def flatten_with_names(tree):
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, SparsityLayout)
    )[0]
    return [(path_name(p), v) for p, v in leaves]


@dataclasses.dataclass
class WeightRule:
    pattern: str
    initial_sparsifier: Sparsifier
    out_format: type
    grad_fmt: Optional[OutFormat] = None


@dataclasses.dataclass
class IntermRule:
    pattern: str
    fmt: OutFormat
    grad_fmt: Optional[OutFormat] = None


@dataclasses.dataclass
class SparsityPlan:
    """The compiled sparsification plan consulted by ``tag`` at trace time."""

    weight_rules: list
    interm_rules: list
    recording: Optional[list] = None  # set by trace_intermediates

    def interm_rule_for(self, name: str) -> Optional[IntermRule]:
        for r in self.interm_rules:
            if fnmatch.fnmatch(name, r.pattern):
                return r
        return None

    def weight_rule_for(self, name: str) -> Optional[WeightRule]:
        for r in self.weight_rules:
            if fnmatch.fnmatch(name, r.pattern):
                return r
        return None

    def __enter__(self):
        _ACTIVE.plan = self
        return self

    def __exit__(self, *exc):
        _ACTIVE.plan = None


def tag(name: str, x: jnp.ndarray, key: Optional[jax.Array] = None):
    """Named intermediate hook.  A no-op (identity) unless a plan is active
    and has a rule matching ``name`` — then the (inline, tmp, external, out)
    output format is applied and the *masked dense* value is returned so the
    surrounding (dense) model code keeps working: this is exactly STen's
    masked-dense emulation path for intermediate tensors."""
    plan: Optional[SparsityPlan] = getattr(_ACTIVE, "plan", None)
    if plan is None:
        return x
    if plan.recording is not None:
        plan.recording.append((name, tuple(x.shape), str(x.dtype)))
        return x
    rule = plan.interm_rule_for(name)
    if rule is None:
        return x
    fmt = rule.fmt
    y = x
    if not isinstance(fmt.inline, KeepAll):
        y = fmt.inline(y, key)
    if not isinstance(fmt.external, KeepAll):
        out = apply_sparsifier(fmt.external, y, fmt.out_layout, key=key)
        y = out.to_dense() if isinstance(out, SparsityLayout) else out
    return y


def tag_layout(name: str, x: jnp.ndarray, key: Optional[jax.Array] = None):
    """Like ``tag`` but returns the layout instance (for sparse-aware
    callers that continue with sten ops)."""
    plan: Optional[SparsityPlan] = getattr(_ACTIVE, "plan", None)
    if plan is None or plan.recording is not None:
        return tag(name, x, key)
    rule = plan.interm_rule_for(name)
    if rule is None:
        return x
    fmt = rule.fmt
    y = fmt.inline(x, key) if not isinstance(fmt.inline, KeepAll) else x
    return apply_sparsifier(fmt.external, y, fmt.out_layout, key=key)


def trace_intermediates(fn: Callable, *args, **kwargs):
    """Enumerate taggable intermediate sites: returns
    [(name, shape, dtype), ...] — the JAX stand-in for fx tracing (§4.1)."""
    plan = SparsityPlan([], [], recording=[])
    with plan:
        jax.eval_shape(lambda *a, **k: fn(*a, **k), *args, **kwargs)
    return list(plan.recording)


class SparsityBuilder:
    """Paper §3.4 API: mark weights/intermediates sparse, then build the
    sparse model.

    >>> sb = SparsityBuilder()
    >>> sb.set_weight("mlp.w1", GroupedNMSparsifier(1, 4, 16), FixedMaskTensor)
    >>> sb.set_interm("mlp.gelu", inline_sparsifier=ScalarThreshold(0.1))
    >>> sparse_params, sparse_apply = sb.get_sparse_model(params, apply_fn)
    """

    def __init__(self):
        self._weights: list[WeightRule] = []
        self._interms: list[IntermRule] = []

    # -- weights ----------------------------------------------------------
    def set_weight(self, name: str, initial_sparsifier: Sparsifier,
                   out_format: type = None, grad_fmt: OutFormat | None = None):
        from repro.core.layouts import FixedMaskTensor

        self._weights.append(
            WeightRule(name, initial_sparsifier, out_format or FixedMaskTensor,
                       grad_fmt)
        )
        return self

    def set_weight_grad(self, name: str, fmt: OutFormat):
        for r in self._weights:
            if r.pattern == name:
                r.grad_fmt = fmt
                return self
        self._weights.append(WeightRule(name, KeepAll(), DenseTensor, fmt))
        return self

    # -- intermediates ----------------------------------------------------
    def set_interm(self, name: str, inline_sparsifier: Sparsifier = KeepAll(),
                   tmp_format: type = DenseTensor,
                   external_sparsifier: Sparsifier = KeepAll(),
                   out_format: type = DenseTensor,
                   grad_fmt: OutFormat | None = None):
        self._interms.append(
            IntermRule(
                name,
                OutFormat(inline_sparsifier, tmp_format, external_sparsifier,
                          out_format),
                grad_fmt,
            )
        )
        return self

    def set_interm_grad(self, name: str, fmt: OutFormat):
        self._interms.append(IntermRule(name, OutFormat(), fmt))
        return self

    # -- build ------------------------------------------------------------
    def plan(self) -> SparsityPlan:
        return SparsityPlan(list(self._weights), list(self._interms))

    def sparsify_params(self, params, key: Optional[jax.Array] = None):
        """Apply weight rules to a params pytree: matching leaves are
        replaced by sparse layout instances (the ``SparseParameterWrapper``
        equivalent — in JAX the layout *is* the parameter)."""
        plan = self.plan()

        def visit(path, leaf):
            name = path_name(path)
            rule = plan.weight_rule_for(name)
            if rule is None or isinstance(leaf, SparsityLayout):
                return leaf
            if getattr(leaf, "ndim", 0) == 3:
                # scan-stacked [L, ...] weight: sparsify per layer (the
                # paper's *local* pruning) and re-stack the layout pytree —
                # lax.scan then slices per-layer layouts back out naturally.
                parts = [
                    apply_sparsifier(rule.initial_sparsifier, leaf[i],
                                     rule.out_format, key=key)
                    for i in range(leaf.shape[0])
                ]
                import jax.numpy as _jnp

                return jax.tree_util.tree_map(
                    lambda *xs: _jnp.stack(xs), *parts
                )
            return apply_sparsifier(
                rule.initial_sparsifier, leaf, rule.out_format, key=key
            )

        return jax.tree_util.tree_map_with_path(visit, params)

    def get_sparse_model(self, params, apply_fn: Callable,
                         key: Optional[jax.Array] = None):
        """Returns (sparse_params, sparse_apply).  ``sparse_apply`` runs
        ``apply_fn`` with the sparsity plan active so intermediate tags
        fire; weights were already converted to layouts."""
        sparse_params = self.sparsify_params(params, key=key)
        plan = self.plan()

        def sparse_apply(p, *args, **kwargs):
            with plan:
                return apply_fn(p, *args, **kwargs)

        return sparse_params, sparse_apply

    # -- introspection -----------------------------------------------------
    def grad_formats(self) -> dict[str, OutFormat]:
        return {
            r.pattern: r.grad_fmt for r in self._weights if r.grad_fmt is not None
        }

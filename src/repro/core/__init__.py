"""STen-JAX core: the sparsity programming model (layouts, operators,
sparsifiers) from *STen: Productive and Efficient Sparsity in PyTorch*,
re-implemented natively for JAX.  See DESIGN.md for the adaptation notes.
"""

from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    SparsityLayout,
    all_layouts,
    nm_patterns,
    register_layout,
)
from repro.core.sparsifiers import (
    BlockwiseFractionSparsifier,
    GroupedNMSparsifier,
    KeepAll,
    NMSparsifier,
    RandomFractionSparsifier,
    SameFormatSparsifier,
    ScalarFractionSparsifier,
    ScalarThresholdSparsifier,
    Sparsifier,
    apply_sparsifier,
    register_sparsifier_implementation,
)
from repro.core.convert import as_layout, convert, lossless_targets
from repro.core.dispatch import (
    OutFormat,
    SparseFallbackWarning,
    dispatch,
    register_op_impl,
    register_patched_op,
    sparse_op_table,
    sparsified_op,
)
from repro.core import ops  # registers built-in implementations
from repro.core.ops import add, gelu, linear, matmul, relu
from repro.core.builder import (
    SparsityBuilder,
    SparsityPlan,
    flatten_with_names,
    tag,
    trace_intermediates,
)
from repro.core.autograd import (
    dense_grad_of,
    masked_grad,
    sparsify_grads,
    straight_through,
)
from repro.core.nmg import (
    dense_to_grouped_nm,
    energy,
    grouped_nm_mask,
    grouped_nm_to_dense,
    nm_mask,
    unstructured_mask,
)

"""Gradient plumbing for sparse layouts (paper §4.5 + §3.4 grad formats).

Two facts make STen's backprop story simpler in JAX than in PyTorch:

1.  Every layout's ``to_dense`` is a differentiable jnp composition, so
    ``jax.grad`` of any loss through sparse parameters works out of the box —
    the cotangent of a layout is a layout-structured pytree whose ``val``
    leaf carries the gradient w.r.t. the *stored* values.  Index/mask leaves
    are integer/bool and get symbolic-zero cotangents.  This is the
    "transparent backpropagation" of §4.5 without any autograd extension.

2.  JAX requires cotangent pytrees to mirror primal structure, so STen's
    *independent gradient formats* (a CSR weight with an n:m gradient, §3.4)
    are applied where the gradient becomes a value: just before the optimizer
    consumes it.  ``sparsify_grads`` does that, driven by the
    ``grad_out_fmt``s collected by the SparsityBuilder.

``masked_grad``/``straight_through`` implement the two standard conventions
for gradients of pruned weights during masked sparse training.
"""

from __future__ import annotations

import fnmatch
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.builder import path_name
from repro.core.dispatch import OutFormat
from repro.core.layouts import FixedMaskTensor, SparsityLayout
from repro.core.sparsifiers import KeepAll, apply_sparsifier

__all__ = [
    "grad_values",
    "dense_grad_of",
    "sparsify_grads",
    "masked_grad",
    "straight_through",
]


def grad_values(grad_leaf):
    """The value-carrying array of a layout cotangent."""
    if isinstance(grad_leaf, FixedMaskTensor):
        return grad_leaf.val
    if isinstance(grad_leaf, SparsityLayout):
        return getattr(grad_leaf, "val", getattr(grad_leaf, "data", None))
    return grad_leaf


def dense_grad_of(primal, grad_leaf):
    """Densify a layout-structured cotangent into the dense-space gradient
    (scatter values at the primal's nonzero locations)."""
    if not isinstance(primal, SparsityLayout):
        return grad_leaf
    if isinstance(primal, FixedMaskTensor):
        g = grad_leaf.val if isinstance(grad_leaf, FixedMaskTensor) else grad_leaf
        return g * primal.mask.astype(g.dtype)
    # generic: rebuild a same-layout tensor holding grad values, densify
    vals = grad_values(grad_leaf)
    clone = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(primal),
        [
            vals if l is _value_leaf(primal) else l
            for l in jax.tree_util.tree_leaves(primal)
        ],
    )
    return clone.to_dense()


def _value_leaf(layout):
    return getattr(layout, "val", getattr(layout, "data", None))


def sparsify_grads(grads, grad_formats: dict[str, OutFormat],
                   key: Optional[jax.Array] = None):
    """Apply per-weight gradient output formats (paper §3.4
    ``set_weight_grad``): the named gradients are re-sparsified with the
    format's external sparsifier before the optimizer sees them."""
    if not grad_formats:
        return grads

    def visit(path, g):
        name = path_name(path)
        for pattern, fmt in grad_formats.items():
            if fnmatch.fnmatch(name, pattern):
                if fmt is None or isinstance(fmt.external, KeepAll):
                    return g
                if isinstance(g, FixedMaskTensor) and g.mask is None:
                    # cotangent from value_and_grad_sparse: integer/bool
                    # metadata carries float0 -> None; the val leaf already
                    # holds the dense-space gradient (chain rule through
                    # to_dense applied the mask)
                    dense = g.val
                elif isinstance(g, SparsityLayout):
                    dense = g.to_dense()
                else:
                    dense = g
                out = apply_sparsifier(fmt.external, dense, fmt.out_layout,
                                       key=key)
                # keep pytree structure: return masked dense values.  The
                # static ``origin`` aux must ride along — dropping it would
                # desync the cotangent treedef from the primal params (the
                # optimizer flattens grads with the params' treedef).
                masked = out.to_dense() if isinstance(out, SparsityLayout) else out
                if isinstance(g, FixedMaskTensor):
                    return FixedMaskTensor(masked, g.mask, g.origin)
                return masked
        return g

    return jax.tree_util.tree_map_with_path(
        visit, grads, is_leaf=lambda x: isinstance(x, SparsityLayout)
    )


def masked_grad(grad: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Gradient convention A: pruned weights receive no gradient (the mask
    gates the backward pass, matching masked-dense forward semantics)."""
    return grad * mask.astype(grad.dtype)


def straight_through(grad: jnp.ndarray) -> jnp.ndarray:
    """Gradient convention B (STE): gradients flow to pruned weights too, so
    they may regrow when the mask is recomputed (used by iterative magnitude
    pruning so pruning decisions can be revisited)."""
    return grad

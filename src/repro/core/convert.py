"""Lossless layout-conversion graph (paper §4.4).

STen only auto-converts between layouts when the conversion is provably
lossless, to avoid silent information loss.  Every layout -> Dense is
lossless by construction (``to_dense`` reproduces exact values); Dense ->
{CSR, COO, FixedMask} are lossless; structured formats (NM, GroupedNM) are
lossless *from* but lossy *to* (their sparsifier drops values), so they are
never auto-converted *into*.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    SparsityLayout,
)

__all__ = ["convert", "lossless_targets", "as_layout", "conversion_log",
           "reset_conversion_log"]

#: every convert() that actually ran (short-circuits excluded), as
#: (source layout name, target layout name, dense shape) — the static
#: checker's R2 pass reads this to spot the same weight being converted
#: repeatedly inside one traced program
_CONVERSION_LOG: list = []


def conversion_log() -> list:
    return list(_CONVERSION_LOG)


def reset_conversion_log() -> None:
    _CONVERSION_LOG.clear()


def as_layout(x) -> SparsityLayout:
    return x if isinstance(x, SparsityLayout) else DenseTensor(jnp.asarray(x))


#: layouts reachable losslessly from each layout (besides itself)
_LOSSLESS: dict[type, tuple[type, ...]] = {
    DenseTensor: (CsrTensor, CooTensor, FixedMaskTensor),
    CsrTensor: (DenseTensor, CooTensor, FixedMaskTensor),
    CooTensor: (DenseTensor, CsrTensor, FixedMaskTensor),
    FixedMaskTensor: (DenseTensor, CsrTensor, CooTensor),
    NMTensor: (DenseTensor, FixedMaskTensor, CsrTensor, CooTensor),
    GroupedNMTensor: (DenseTensor, FixedMaskTensor, CsrTensor, CooTensor),
}


def lossless_targets(layout_cls: type) -> tuple[type, ...]:
    return (layout_cls,) + _LOSSLESS.get(layout_cls, (DenseTensor,))


def convert(x, target: type):
    """Losslessly convert ``x`` to layout class ``target``.

    Raises TypeError when the conversion would be lossy (never silently
    drops values — paper §4.4)."""
    x = as_layout(x)
    if isinstance(x, target):
        return x
    if target not in lossless_targets(type(x)):
        raise TypeError(
            f"no lossless conversion {type(x).__name__} -> {target.__name__}"
        )
    dense = x.to_dense()
    _CONVERSION_LOG.append(
        (type(x).__name__, target.__name__, tuple(map(int, dense.shape)))
    )
    if target is DenseTensor:
        return DenseTensor(dense)
    if target is FixedMaskTensor:
        return FixedMaskTensor(dense, dense != 0)
    if target is CsrTensor:
        return CsrTensor.from_dense(dense)
    if target is CooTensor:
        return CooTensor.from_dense(dense)
    raise TypeError(f"unhandled conversion target {target}")

"""Built-in sparse operator implementations (paper §4.4: STen ships support
for common operators — here matmul/linear/add and friends — registered with
the dispatcher; everything else reaches the dense fallback with a warning).

All implementations are differentiable jnp compositions: gradients w.r.t. the
stored values of any layout flow through ``to_dense``/gathers automatically,
which is how STen-JAX gets the paper's "backpropagation is transparently
supported" for free (DESIGN.md §2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import importlib

# import the module object directly (the package re-exports a function named
# ``dispatch``, which would shadow the submodule on attribute-style imports)
disp = importlib.import_module("repro.core.dispatch")
from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    SparsityLayout,
)
from repro.core.sparsifiers import ScalarThresholdSparsifier

__all__ = ["matmul", "add", "linear", "relu", "gelu", "sum_"]

# ---------------------------------------------------------------------------
# dense references (fallback targets)
# ---------------------------------------------------------------------------

disp.register_dense_reference("matmul", jnp.matmul)
disp.register_dense_reference("add", jnp.add)
disp.register_dense_reference("relu", jax.nn.relu)
disp.register_dense_reference("gelu", jax.nn.gelu)
disp.register_dense_reference("sum", jnp.sum)
disp.register_dense_reference(
    "linear", lambda x, w, b=None: jnp.matmul(x, w) + (0 if b is None else b)
)


# ---------------------------------------------------------------------------
# CSR implementations (torch.sparse-equivalent basics)
# ---------------------------------------------------------------------------


@disp.register_op_impl("matmul", inp=(CsrTensor, DenseTensor), out=DenseTensor)
def _csr_dense_mm(a: CsrTensor, b):
    """CSR[M,K] @ dense[K,N] via gather + segment-sum over stored entries."""
    b = b.to_dense() if isinstance(b, SparsityLayout) else jnp.asarray(b)
    rows, cols = a.shape
    positions = jnp.arange(a.nnz_cap)
    row_ids = jnp.clip(
        jnp.searchsorted(a.indptr, positions, side="right") - 1, 0, rows - 1
    )
    valid = positions < a.indptr[-1]
    contrib = jnp.where(valid, a.data, 0)[:, None] * jnp.take(b, a.indices, axis=0)
    out = jax.ops.segment_sum(contrib, row_ids, num_segments=rows)
    return out


@disp.register_op_impl("matmul", inp=(DenseTensor, CsrTensor), out=DenseTensor)
def _dense_csr_mm(a, b: CsrTensor):
    """dense[M,K] @ CSR[K,N]: scatter columns of the sparse operand."""
    a = a.to_dense() if isinstance(a, SparsityLayout) else jnp.asarray(a)
    rows, cols = b.shape
    positions = jnp.arange(b.nnz_cap)
    row_ids = jnp.clip(
        jnp.searchsorted(b.indptr, positions, side="right") - 1, 0, rows - 1
    )
    valid = positions < b.indptr[-1]
    vals = jnp.where(valid, b.data, 0)
    # out[:, c] += a[:, r] * v  for each stored (r, c, v)
    gathered = jnp.take(a, row_ids, axis=1) * vals[None, :]  # [M, nnz]
    out = jnp.zeros((a.shape[0], cols), gathered.dtype)
    return out.at[:, b.indices].add(gathered)


@disp.register_op_impl("add", inp=(CooTensor, CooTensor), out=CooTensor)
def _coo_add(a: CooTensor, b: CooTensor):
    """Keep-all sparse add: nonzero union via coordinate concatenation
    (paper §3.3: 'the sum of two sparse tensors with a keep-all sparsifier
    produces ... the union of the nonzeros of the inputs')."""
    assert a.shape == b.shape
    data = jnp.concatenate([a.data, b.data])
    coords = jnp.concatenate([a.coords, b.coords], axis=1)
    return CooTensor(data, coords, a.shape)


# ---------------------------------------------------------------------------
# Masked-dense implementations (training workhorse)
# ---------------------------------------------------------------------------


@disp.register_op_impl("matmul", inp=(DenseTensor, FixedMaskTensor),
                       out=DenseTensor)
def _dense_masked_mm(a, w: FixedMaskTensor):
    a = a.to_dense() if isinstance(a, SparsityLayout) else jnp.asarray(a)
    return jnp.matmul(a, w.to_dense())


@disp.register_op_impl("matmul", inp=(FixedMaskTensor, DenseTensor),
                       out=DenseTensor)
def _masked_dense_mm(a: FixedMaskTensor, b):
    b = b.to_dense() if isinstance(b, SparsityLayout) else jnp.asarray(b)
    return jnp.matmul(a.to_dense(), b)


@disp.register_op_impl("linear", inp=(DenseTensor, FixedMaskTensor),
                       out=DenseTensor)
def _linear_masked(x, w: FixedMaskTensor, b=None):
    x = x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)
    y = jnp.matmul(x, w.to_dense())
    return y if b is None else y + b


# ---------------------------------------------------------------------------
# n:m:g implementations (the paper's §5 fast path)
# ---------------------------------------------------------------------------


@disp.register_op_impl("matmul", inp=(GroupedNMTensor, DenseTensor),
                       out=DenseTensor)
def _nmg_dense_mm(a: GroupedNMTensor, b):
    from repro.kernels import ops as kops

    b = b.to_dense() if isinstance(b, SparsityLayout) else jnp.asarray(b)
    if a.sparse_dim % 2 != 1:
        raise NotImplementedError(
            "GroupedNM matmul needs sparse_dim=1 on the left operand; "
            "store the weight transposed or use 'linear'."
        )
    # shape-routed: decode-shaped (narrow) right operands hit the GEMV
    # kernel, wide ones the column-tiled SpMM (kernels/ops.py)
    return kops.nmg_matmul(a, b)


@disp.register_op_impl("linear", inp=(DenseTensor, GroupedNMTensor),
                       out=DenseTensor)
def _linear_nmg(x, w: GroupedNMTensor, b=None):
    from repro.kernels import ops as kops

    x = x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)
    if w.sparse_dim % 2 != 0:
        raise NotImplementedError(
            "n:m:g linear expects the weight sparse along its input axis "
            "(sparse_dim=0) with groups along the output axis."
        )
    y = kops.nmg_linear(x, w)
    return y if b is None else y + b


@disp.register_op_impl("matmul", inp=(NMTensor, DenseTensor), out=DenseTensor)
def _nm_dense_mm(a: NMTensor, b):
    """Plain n:m (last-axis sparse) matmul: gather B rows per block."""
    b = b.to_dense() if isinstance(b, SparsityLayout) else jnp.asarray(b)
    M, K = a.shape
    nblocks = a.val.shape[-2]
    base = jnp.arange(nblocks, dtype=jnp.int32) * a.m
    cols = (base[:, None] + a.idx).reshape(M, -1)       # [M, nb*n]
    K_pad = nblocks * a.m
    b_p = jnp.pad(b, ((0, K_pad - K), (0, 0)))
    gathered = jnp.take(b_p, cols.reshape(-1), axis=0).reshape(M, -1, b.shape[1])
    vals = a.val.reshape(M, -1)
    return jnp.einsum("mk,mkn->mn", vals.astype(jnp.float32),
                      gathered.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Fused inline-sparsifier implementation (paper §3.3 streaming fusion)
# ---------------------------------------------------------------------------


@disp.register_op_impl("matmul", inp=(DenseTensor, DenseTensor),
                       out=FixedMaskTensor, inline=ScalarThresholdSparsifier)
def _fused_matmul_threshold(sparsifier, a, b):
    from repro.kernels import ops as kops

    a = a.to_dense() if isinstance(a, SparsityLayout) else jnp.asarray(a)
    b = b.to_dense() if isinstance(b, SparsityLayout) else jnp.asarray(b)
    val, mask = kops.matmul_threshold(a, b, float(sparsifier.threshold))
    return FixedMaskTensor(val, mask)


_fused_matmul_threshold._sten_fused = True


# ---------------------------------------------------------------------------
# Public functional API (sten.* ops)
# ---------------------------------------------------------------------------


def matmul(a, b, **kw):
    return disp.dispatch("matmul", a, b, **kw)


def add(a, b, **kw):
    return disp.dispatch("add", a, b, **kw)


def linear(x, w, b=None, **kw):
    # bias passes as a keyword so the 2-operand layout signature matches
    return disp.dispatch("linear", x, w, b=b, **kw)


def relu(x, **kw):
    return disp.dispatch("relu", x, **kw)


def gelu(x, **kw):
    return disp.dispatch("gelu", x, **kw)


def sum_(x, **kw):
    return disp.dispatch("sum", x, **kw)

"""Dense <-> n:m:g conversion algorithms (paper §5.2).

The conversion objective: given dense X, find X_hat in n:m:g format maximizing
``||X_hat||_1`` (the paper uses the L1 norm — equivalently the *energy*
``||X_hat||_1 / ||X||_1`` of Fig 7).  Per chunk this is an assignment problem:
chunk position j carries the compile-time pattern P_j, and we choose which
original m-block sits at each position.

Implemented methods (all paper-faithful):
  * ``greedy``    — the paper's CPU algorithm: compute all C(m,n)^2 (block,
                    pattern) scores, process them from highest to lowest,
                    first-fit assign.  Processing in descending order with
                    first-fit is identical to repeatedly taking the best
                    available pair, which is how we vectorize it in XLA
                    (a C-step fori_loop over a [batch, C, C] score tensor).
  * ``swap``      — the paper's GPU algorithm: start from an arbitrary
                    assignment and apply pairwise swaps while they improve
                    the preserved magnitude.  We seed it with ``greedy`` and
                    run it as a bounded while_loop, so it is always >= greedy.
  * ``exact``     — brute force over all C! permutations (tests only, C<=6),
                    used as the optimality oracle for property tests.

These run as XLA programs, so the "performance critical" conversion after
each optimizer update (paper §5.2) is jit-compatible and fuses into the
training step.  kernels/nm_mask.py provides the Pallas fast path for the
fixed-pattern case.
"""

from __future__ import annotations

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.layouts import (
    GroupedNMTensor,
    NMTensor,
    build_spmm_plan,
    nm_patterns,
    pattern_onehots,
    pad_to_multiple,
)

__all__ = [
    "dense_to_grouped_nm",
    "grouped_nm_to_dense",
    "energy",
    "nm_mask",
    "unstructured_mask",
    "blocked_mask",
    "grouped_nm_mask",
]


def energy(x_hat, x) -> jnp.ndarray:
    """Paper §6.1: energy = ||X_hat||_1 / ||X||_1, in [0, 1]."""
    num = jnp.sum(jnp.abs(x_hat))
    den = jnp.sum(jnp.abs(x))
    return num / jnp.maximum(den, jnp.finfo(jnp.float32).tiny)


# ---------------------------------------------------------------------------
# Mask constructors for the comparison sparsities of Fig 7
# ---------------------------------------------------------------------------


def unstructured_mask(x, sparsity) -> jnp.ndarray:
    """Global magnitude top-k mask (scalar fraction sparsifier, Table 1).

    ``sparsity`` may be a Python float (static k via top_k) or a traced
    scalar (the in-jit GMP ramp).  Both spellings derive k with the same
    f32 operation sequence and keep ``|x| >= (k-th largest |x|)``, so they
    select bitwise-identical masks for the same sparsity level.
    """
    flat = jnp.abs(x).reshape(-1)
    size = flat.shape[0]
    if isinstance(sparsity, (float, int)):
        k = int(np.clip(np.round(
            np.float32(size) * (np.float32(1.0) - np.float32(sparsity))
        ), 1, size))
        thresh = jax.lax.top_k(flat, k)[0][-1]
    else:
        k = jnp.clip(
            jnp.round(
                jnp.float32(size)
                * (jnp.float32(1.0) - jnp.asarray(sparsity, jnp.float32))
            ).astype(jnp.int32),
            1, size,
        )
        thresh = jnp.sort(flat)[size - k]
    return (jnp.abs(x) >= thresh).astype(x.dtype)


def nm_mask(x, n: int, m: int) -> jnp.ndarray:
    """Per-block top-n mask along the last axis (per-block fraction)."""
    k = x.shape[-1]
    xp = pad_to_multiple(x, m, axis=-1)
    blocks = xp.reshape(*xp.shape[:-1], -1, m)
    _, idx = jax.lax.top_k(jnp.abs(blocks), n)
    onehot = jnp.sum(jax.nn.one_hot(idx, m, dtype=x.dtype), axis=-2)
    mask = onehot.reshape(*xp.shape[:-1], -1)[..., :k]
    return mask


def blocked_mask(x, block: int, sparsity: float) -> jnp.ndarray:
    """Block-wise fraction sparsifier (Table 1): drop whole blocks of
    ``block`` consecutive elements (last axis) with smallest L1."""
    k = x.shape[-1]
    xp = pad_to_multiple(x, block, axis=-1)
    blocks = jnp.abs(xp).reshape(*xp.shape[:-1], -1, block)
    scores = jnp.sum(blocks, axis=-1).reshape(-1)
    keep = max(1, int(round(scores.shape[0] * (1.0 - sparsity))))
    thresh = jax.lax.top_k(scores, keep)[0][-1]
    bmask = (jnp.sum(blocks, axis=-1) >= thresh).astype(x.dtype)
    mask = jnp.repeat(bmask, block, axis=-1)
    mask = mask.reshape(*xp.shape[:-1], -1)[..., :k]
    return mask


# ---------------------------------------------------------------------------
# n:m:g assignment
# ---------------------------------------------------------------------------


def _greedy_assign(scores: jnp.ndarray, g: int) -> jnp.ndarray:
    """Paper's CPU algorithm (§5.2): the C(m,n)^2*g (block, pattern) scores
    are processed from highest to lowest; a block takes a pattern only if the
    block is still unassigned and the pattern's group is not yet full
    (capacity g).  Descending-order first-fit == iterated global argmax,
    which is how we vectorize it: CG fori_loop steps over [B, CG, C] scores.

    scores: [B, CG, C] (block, pattern) -> perm [B, CG] int32 mapping chunk
    position p (pattern p // g) to the original block index placed there.
    """
    B, CG, C = scores.shape
    NEG = jnp.asarray(-jnp.inf, scores.dtype)
    bidx = jnp.arange(B)

    def body(_, state):
        sc, perm, cap = state
        flat = sc.reshape(B, CG * C)
        best = jnp.argmax(flat, axis=1)
        b, p = best // C, best % C
        # next free slot of pattern p: positions p*g .. p*g + g-1
        slot = p * g + (g - cap[bidx, p])
        perm = perm.at[bidx, slot].set(b.astype(jnp.int32))
        cap = cap.at[bidx, p].add(-1)
        sc = sc.at[bidx, b, :].set(NEG)                     # block taken
        full = cap[bidx, p] == 0
        sc = jnp.where(full[:, None, None],
                       sc.at[bidx, :, p].set(NEG), sc)      # pattern full
        return sc, perm, cap

    perm0 = jnp.full((B, CG), -1, jnp.int32)
    cap0 = jnp.full((B, C), g, jnp.int32)
    _, perm, _ = jax.lax.fori_loop(0, CG, body, (scores, perm0, cap0))
    return perm


def _swap_refine(scores: jnp.ndarray, perm: jnp.ndarray, g: int,
                 max_iters: int = 128) -> jnp.ndarray:
    """Paper's GPU algorithm (§5.2): attempt to exchange nonzero patterns
    between chunk positions while the swap improves the preserved magnitude.
    Vectorized: each iteration applies the single best positive swap per
    chunk; terminates when no chunk improves (bounded by ``max_iters``)."""
    B, CG, C = scores.shape
    bidx = jnp.arange(B)
    # expand pattern scores to positions: spos[b, blk, pos] = scores[b, blk, pos//g]
    spos = jnp.repeat(scores, g, axis=2)  # [B, CG, CG]

    def gain_and_best(perm):
        cur = spos[bidx[:, None], perm, jnp.arange(CG)[None]]  # [B, CG]
        cross_ij = spos[
            bidx[:, None, None], perm[:, None, :], jnp.arange(CG)[None, :, None]
        ]  # cross_ij[b, i, j] = spos[b, perm[b, j], i]
        delta = (
            cross_ij
            + jnp.swapaxes(cross_ij, 1, 2)
            - cur[:, :, None]
            - cur[:, None, :]
        )
        delta = jnp.where(jnp.eye(CG, dtype=bool)[None], -jnp.inf, delta)
        flat = delta.reshape(B, CG * CG)
        best = jnp.argmax(flat, axis=1)
        return flat[bidx, best], best // CG, best % CG

    def cond(state):
        it, perm, improved = state
        return jnp.logical_and(it < max_iters, improved)

    def body(state):
        it, perm, _ = state
        gn, i, j = gain_and_best(perm)
        do = gn > 1e-12
        pi = perm[bidx, i]
        pj = perm[bidx, j]
        new_perm = perm.at[bidx, i].set(jnp.where(do, pj, pi))
        new_perm = new_perm.at[bidx, j].set(jnp.where(do, pi, pj))
        return it + 1, new_perm, jnp.any(do)

    _, perm, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0), perm, jnp.asarray(True))
    )
    return perm


def _exact_assign(scores: np.ndarray, g: int) -> np.ndarray:
    """Brute-force optimal assignment (oracle for tests; CG <= 8)."""
    B, CG, C = scores.shape
    best = np.zeros((B, CG), np.int32)
    for b in range(B):
        best_cost, best_perm = -np.inf, None
        for p in itertools.permutations(range(CG)):
            cost = sum(scores[b, blk, pos // g] for pos, blk in enumerate(p))
            if cost > best_cost:
                best_cost, best_perm = cost, p
        best[b] = np.array(best_perm, np.int32)
    return best


def dense_to_grouped_nm(x, n: int, m: int, g: int, gr: int = 1,
                        sparse_dim: int = -1, method: str = "greedy"
                        ) -> GroupedNMTensor:
    """Convert dense 2-D ``x`` to n:m:g (paper §5.2).

    ``sparse_dim`` selects the axis carrying the n:m structure (chunks of
    C(m,n)*g m-blocks along it).  ``gr`` (TPU adaptation) shares chunk
    permutations across ``gr`` consecutive fibers; gr=1 is the paper's
    format.
    """
    x = jnp.asarray(x)
    assert x.ndim == 2, "n:m:g conversion operates on matrices"
    sd = sparse_dim % 2
    orig_shape = tuple(x.shape)
    xc = x.T if sd == 0 else x  # canonical [R, K(sparse)]
    R, K = xc.shape
    C = math.comb(m, n)
    CG = C * g
    xp = pad_to_multiple(pad_to_multiple(xc, gr, 0), m * CG, 1)
    R_pad, K_pad = xp.shape
    Gr, nchunks = R_pad // gr, K_pad // (m * CG)
    pat_onehot = jnp.asarray(pattern_onehots(n, m), xp.dtype)  # memoized

    # per-(fiber-group, chunk, block) magnitudes: [Gr, nchunks, CG, m]
    mags = jnp.abs(xp).reshape(Gr, gr, nchunks, CG, m).sum(axis=1)
    # scores[b, blk, pat] = sum_l mags[b, blk, P[pat, l]]
    scores = jnp.einsum("bkm,pm->bkp", mags.reshape(Gr * nchunks, CG, m),
                        pat_onehot)

    if method == "greedy":
        perm = _greedy_assign(scores, g)
    elif method == "swap":
        perm = _swap_refine(scores, _greedy_assign(scores, g), g)
    elif method == "exact":
        perm = jnp.asarray(
            _exact_assign(np.asarray(jax.device_get(scores)), g)
        )
    else:
        raise ValueError(f"unknown n:m:g conversion method {method!r}")

    perm = perm.reshape(Gr, nchunks, CG)  # local block index per position
    chunk_base = (jnp.arange(nchunks, dtype=jnp.int32) * CG)[None, :, None]
    blk_idx = perm + chunk_base  # global m-block index, [Gr, nchunks, CG]

    # the kernel gather plan is the same index math the value gather needs:
    # build it once here and carry it on the tensor, so nmg_spmm/nmg_gemv
    # stop re-deriving cols from blk_idx on every call
    plan = build_spmm_plan(blk_idx, n, m, g)

    # gather values: val[r, c*CG + p, l] = xp[r, blk_idx[r//gr, c, p]*m
    #                                          + P[p//g, l]]
    cols_rows = jnp.repeat(plan.cols, gr, axis=0)  # [R_pad, nblocks*n]
    flat_vals = jnp.take_along_axis(xp, cols_rows, axis=1)
    val = flat_vals.reshape(R_pad, nchunks * CG, n)

    return GroupedNMTensor(
        val=val,
        blk_idx=blk_idx,
        n=n,
        m=m,
        g=g,
        gr=gr,
        dense_shape=orig_shape,
        sparse_dim=sd,
        plan=plan,
    )


def grouped_nm_to_dense(t: GroupedNMTensor) -> jnp.ndarray:
    """Paper §5.2: n:m:g -> dense is a single pass reordering by the stored
    index (implemented as the layout's differentiable to_dense)."""
    return t.to_dense()


def grouped_nm_mask(x, n: int, m: int, g: int, gr: int = 1,
                    sparse_dim: int = -1, method: str = "greedy"
                    ) -> jnp.ndarray:
    """Boolean mask of the entries an n:m:g conversion would keep.  Used for
    masked training (FixedMaskTensor) and the Fig 7 energy comparison."""
    t = dense_to_grouped_nm(x, n, m, g, gr=gr, sparse_dim=sparse_dim,
                            method=method)
    ones = GroupedNMTensor(
        val=jnp.ones_like(t.val), blk_idx=t.blk_idx, n=t.n, m=t.m, g=t.g,
        gr=t.gr, dense_shape=t.dense_shape, sparse_dim=t.sparse_dim,
        plan=t.plan,
    )
    return ones.to_dense().astype(x.dtype)

"""Operator registry & sparse dispatch (paper §3.2, §4.4, Figs 3-4).

STen's PyTorch dispatcher intercepts tensor-extension calls at runtime.  In
JAX everything is staged, so dispatch happens **at trace time** on the layout
*classes* of the operands — after ``jit`` there is literally zero dispatch
overhead, which removes the "STen runtime" slice of the paper's Fig 11
latency breakdown by construction.

Lookup order (mirrors Fig 3):
  1. exact registered implementation for (op, input-layout signature);
  2. lossless conversion of inputs to a registered signature (minimum number
     of conversions; never lossy — paper §4.4).  Ties among candidates that
     need the same number of conversions are broken by the *measured*
     conversion costs of the active tuning table when one is installed
     (``set_conversion_cost_model`` — ``repro.tune`` wires this up), and by
     registration order otherwise;
  3. dense fallback: densify all operands, call the reference dense op, and
     warn (``warnings.warn`` with ``SparseFallbackWarning``).

Sparse operators (= operator + output format) are built with
``sparsified_op(orig_op, out_fmt, grad_out_fmt)`` where each output format is
the 4-tuple ``(inline_sparsifier, tmp_layout, external_sparsifier,
out_layout)`` of paper §3.3.  Implementations may register themselves as
*fused* for a given inline sparsifier class, in which case the dispatcher
skips the separate inline-sparsifier application.
"""

from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

import importlib

# module object import (the package re-exports a function named ``convert``)
conv = importlib.import_module("repro.core.convert")
from repro.obs import registry as _obs_registry
from repro.core.layouts import DenseTensor, SparsityLayout
from repro.core.sparsifiers import (
    KeepAll,
    SameFormatSparsifier,
    Sparsifier,
    apply_sparsifier,
)

__all__ = [
    "SparseFallbackWarning",
    "register_op_impl",
    "register_patched_op",
    "dispatch",
    "sparsified_op",
    "OutFormat",
    "sparse_op_table",
    "dispatch_counters",
    "reset_dispatch_counters",
    "predict_route",
    "set_conversion_cost_model",
    "conversion_cost_model",
]


class SparseFallbackWarning(UserWarning):
    """Raised when no sparse implementation exists and STen falls back to a
    dense implementation (paper §3.2: 'falls back to a dense implementation
    with masks and issues a warning')."""


# (op_name, in_sig tuple, inline_sparsifier_cls_or_None) -> impl
_OP_IMPLS: dict[tuple, Callable] = {}
#: reference dense callables per op name (the fallback implementations)
_DENSE_OPS: dict[str, Callable] = {}
#: external callables patched into the dispatcher (paper §4.4 patching API)
_PATCHED: dict[Callable, str] = {}

# dispatch-outcome telemetry:
# ("impl" | "dense_fallback" | "cost_model_override", op, sig) -> count
# ("cost_model_override" marks a conversion tie the measured-cost model
# decided differently from registration order).  Dispatch happens at
# *trace* time, so these count compilations, not calls — which is exactly
# the no-fallback evidence the serving perf smoke wants ("did any
# projection in this run trace through the dense fallback?").
# The store is a ``repro.obs`` registry family (a Counter subclass), so it
# lands in the unified telemetry snapshot and — when the flight recorder
# is enabled — each dispatch decision becomes a timestamped event on the
# kernel track.  Increment/copy/clear semantics are unchanged.
_DISPATCH_COUNTS = _obs_registry.REGISTRY.family(
    "dispatch",
    help="trace-time dispatch outcomes: (outcome, op, layout signature)",
    trace_as="dispatch", track="kernel")


def dispatch_counters() -> dict:
    """{(outcome, op_name, (layout names...)): trace count}."""
    return dict(_DISPATCH_COUNTS)


#: (op, sig-names) pairs whose dense-fallback warning already fired — the
#: counter above still increments per trace (that's the telemetry), but
#: the *warning* fires once per process per signature so a scan-over-layers
#: retrace doesn't emit n_layers identical lines
_WARNED_FALLBACKS: set = set()


def reset_dispatch_counters() -> None:
    _DISPATCH_COUNTS.clear()
    _WARNED_FALLBACKS.clear()


def _count_dispatch(outcome: str, op_name: str, sig: tuple) -> None:
    _DISPATCH_COUNTS[
        (outcome, op_name, tuple(c.__name__ for c in sig))
    ] += 1


# Conversion-cost model: optional callable (src_cls, dst_cls) -> float|None
# breaking ties among conversion candidates that need the same *number* of
# conversions.  None (the default, and for unmeasured pairs) keeps the
# historical registration-order tie-break, so installing a model can only
# refine — never contradict — the fewest-conversions rule.
_CONVERSION_COST: Optional[Callable[[type, type], Optional[float]]] = None


def set_conversion_cost_model(
    fn: Optional[Callable[[type, type], Optional[float]]]
) -> None:
    """Install (or clear, with None) the conversion-cost tie-breaker.
    ``repro.tune.routing.conversion_cost`` is the intended model: measured
    lossless-conversion costs from the active tuning table."""
    global _CONVERSION_COST
    _CONVERSION_COST = fn


def conversion_cost_model():
    return _CONVERSION_COST


def _canonical_name(op) -> str:
    if isinstance(op, str):
        return op
    name = getattr(op, "__name__", None)
    if name is None:  # functools.partial etc.
        name = repr(op)
    return name


def register_dense_reference(op_name: str, fn: Callable):
    _DENSE_OPS[op_name] = fn


def register_op_impl(op, inp: Sequence[type], out: type | None = None,
                     inline: type | None = None):
    """Decorator: register a sparse implementation for ``op``.

    ``inp`` is the tuple of input layout classes; ``out`` (optional) the
    produced layout class; ``inline`` (optional) a streaming/blocking
    sparsifier class the implementation fuses (paper §3.3).
    """
    op_name = _canonical_name(op)
    if callable(op) and op_name not in _DENSE_OPS:
        # the registered symbol doubles as the dense reference: signatures
        # with no sparse implementation nor conversion path fall back to it
        # (with a SparseFallbackWarning) instead of raising
        register_dense_reference(op_name, op)

    def deco(fn):
        key = (op_name, tuple(inp), inline)
        if key in _OP_IMPLS:
            raise ValueError(f"duplicate op impl {key}")
        _OP_IMPLS[key] = fn
        fn._sten_out_layout = out
        return fn

    return deco


def register_patched_op(fn: Callable, op_name: str | None = None):
    """Paper §4.4 'patching API': route an arbitrary callable through the
    sparse dispatcher when any argument is a sparse layout.  Returns the
    wrapped callable."""
    name = op_name or _canonical_name(fn)
    _DENSE_OPS.setdefault(name, fn)
    _PATCHED[fn] = name

    def wrapped(*args, **kwargs):
        if any(isinstance(a, SparsityLayout) for a in args):
            return dispatch(name, *args, **kwargs)
        return fn(*args, **kwargs)

    wrapped.__name__ = name
    return wrapped


def sparse_op_table() -> dict:
    """Introspection: the registered sparse-op table (for docs/tests)."""
    return dict(_OP_IMPLS)


def _signature(args) -> tuple:
    sig = []
    for a in args:
        if isinstance(a, SparsityLayout):
            sig.append(type(a))
        else:
            sig.append(DenseTensor)
    return tuple(sig)


def _find_impl(op_name: str, sig: tuple, inline: type | None):
    """Exact then conversion-based lookup.  Returns (impl, conversions) where
    conversions is a tuple of target layout classes per arg (None = as-is),
    or (None, None)."""
    key = (op_name, sig, inline)
    if key in _OP_IMPLS:
        return _OP_IMPLS[key], None
    # conversion search: all registered signatures for this op & inline,
    # scored by number of converted arguments (fewest wins).
    candidates = []
    for (name, s, inl), impl in _OP_IMPLS.items():
        if name != op_name or inl is not inline or len(s) != len(sig):
            continue
        nconv = 0
        cost: Optional[float] = 0.0  # None once any needed pair is unmeasured
        ok = True
        for have, want in zip(sig, s):
            if have is want:
                continue
            if want in conv.lossless_targets(have):
                nconv += 1
                c = (_CONVERSION_COST(have, want)
                     if _CONVERSION_COST is not None else None)
                cost = None if (c is None or cost is None) \
                    else cost + float(c)
            else:
                ok = False
                break
        if ok:
            candidates.append((nconv, cost, s, impl))
    if not candidates:
        return None, None
    # fewest conversions always wins; min() takes the first minimum, so
    # registration order breaks ties exactly as it always has
    best_n = min(t[0] for t in candidates)
    pool = [t for t in candidates if t[0] == best_n]
    chosen = pool[0]
    # measured costs refine the tie only when every tied candidate is fully
    # measured: costs are microseconds, so comparing a measured sum against
    # a candidate with unmeasured (unknown-cost) conversions would be
    # unit-nonsense — incomparable ties keep registration order
    if len(pool) > 1 and all(t[1] is not None for t in pool):
        chosen = min(pool, key=lambda t: t[1])
        if chosen[3] is not pool[0][3]:
            _count_dispatch("cost_model_override", op_name, sig)
    _, _, target_sig, impl = chosen
    return impl, target_sig


def dispatch(op, *args, inline: Optional[Sparsifier] = None,
             dense_fn: Optional[Callable] = None, **kwargs):
    """Dispatch ``op`` on (possibly sparse) ``args``.

    Returns whatever the implementation returns (a dense array or a layout
    instance).  ``dense_fn`` overrides the dense fallback implementation.
    """
    op_name = _canonical_name(op)
    sig = _signature(args)
    inline_cls = type(inline) if inline is not None else None

    # all-dense fast path: plain dense op, no sparse registry involved
    # (PyTorch-STen similarly only intercepts calls with sparse operands)
    if not any(isinstance(a, SparsityLayout) for a in args):
        fallback = dense_fn or _DENSE_OPS.get(op_name) or (
            op if callable(op) else None
        )
        if fallback is not None:
            out = fallback(*args, **kwargs)
            if inline is not None and not isinstance(inline, KeepAll):
                out = inline(out)
            return out

    # 1 & 2: exact or conversion-reachable sparse implementation
    impl, target_sig = _find_impl(op_name, sig, inline_cls)
    if impl is None and inline_cls is not None:
        # fall back to non-fused implementation; inline sparsifier will be
        # applied separately by the caller (sparsified_op).
        impl, target_sig = _find_impl(op_name, sig, None)
        if impl is not None:
            impl = _with_post_sparsifier(impl, inline)
    if impl is not None:
        _count_dispatch("impl", op_name, sig)
        if target_sig is not None:
            args = tuple(
                a if isinstance(a, t) else conv.convert(a, t)
                for a, t in zip(args, target_sig)
            )
        if inline_cls is not None and getattr(impl, "_sten_fused", False):
            return impl(inline, *args, **kwargs)
        return impl(*args, **kwargs)

    # 3: dense fallback
    fallback = dense_fn or _DENSE_OPS.get(op_name) or (op if callable(op) else None)
    if fallback is None:
        raise NotImplementedError(
            f"no sparse implementation nor dense fallback for op {op_name!r} "
            f"with signature {[c.__name__ for c in sig]}"
        )
    if any(isinstance(a, SparsityLayout) and not isinstance(a, DenseTensor)
           for a in args):
        # DenseTensor wrappers densify for free — only warn when a *sparse*
        # layout is about to be materialized
        _count_dispatch("dense_fallback", op_name, sig)
        warn_key = (op_name, tuple(c.__name__ for c in sig))
        if warn_key not in _WARNED_FALLBACKS:
            _WARNED_FALLBACKS.add(warn_key)
            warnings.warn(
                f"sten: falling back to dense implementation of {op_name!r} "
                f"for signature {[c.__name__ for c in sig]}",
                SparseFallbackWarning,
                stacklevel=2,
            )
    dense_args = tuple(
        a.to_dense() if isinstance(a, SparsityLayout) else a for a in args
    )
    out = fallback(*dense_args, **kwargs)
    if inline is not None and not isinstance(inline, KeepAll):
        out = inline(out)
    return out


def predict_route(op, sig, *, inline: type | None = None) -> dict:
    """Predict, without calling anything, how :func:`dispatch` would route
    ``op`` over a signature of layout classes (instances are accepted and
    reduced to their classes).  Returns::

        {"outcome": "impl" | "dense_fallback",
         "op": name, "sig": (layout names...),
         "target_sig": (layout names...) | None,   # conversions applied
         "conversions": ((from, to), ...),
         "warns": bool}                            # fallback would warn

    This is the checker's static view of the dispatcher — the same
    ``_find_impl`` lookup the runtime runs, with the counter side effects
    snapshotted away so prediction never pollutes the telemetry."""
    op_name = _canonical_name(op)
    sig = tuple(
        s if isinstance(s, type) else type(conv.as_layout(s)) for s in sig
    )
    saved = _DISPATCH_COUNTS.copy()
    try:
        impl, target_sig = _find_impl(op_name, sig, inline)
        if impl is None and inline is not None:
            impl, target_sig = _find_impl(op_name, sig, None)
    finally:
        _DISPATCH_COUNTS.clear()
        _DISPATCH_COUNTS.update(saved)
    names = tuple(c.__name__ for c in sig)
    if impl is not None:
        conversions = tuple(
            (h.__name__, w.__name__)
            for h, w in zip(sig, target_sig or sig) if h is not w
        )
        return {"outcome": "impl", "op": op_name, "sig": names,
                "target_sig": tuple(c.__name__ for c in target_sig)
                if target_sig else None,
                "conversions": conversions, "warns": False}
    warns = any(issubclass(c, SparsityLayout) and c is not DenseTensor
                for c in sig)
    return {"outcome": "dense_fallback", "op": op_name, "sig": names,
            "target_sig": None, "conversions": (), "warns": warns}


def _with_post_sparsifier(impl, sparsifier):
    def wrapped(*args, **kwargs):
        out = impl(*args, **kwargs)
        if sparsifier is not None and not isinstance(sparsifier, KeepAll):
            out = sparsifier(out)
        return out

    wrapped._sten_out_layout = getattr(impl, "_sten_out_layout", None)
    return wrapped


# ---------------------------------------------------------------------------
# Sparse operators: operator + output format (paper §3.3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OutFormat:
    """Output format 4-tuple (paper §3.3): inline sparsifier applied inside
    the operator, materialized in ``tmp_layout``, then the external
    sparsifier produces ``out_layout``."""

    inline: Sparsifier = KeepAll()
    tmp_layout: type = DenseTensor
    external: Sparsifier = KeepAll()
    out_layout: type = DenseTensor

    @classmethod
    def coerce(cls, fmt):
        if isinstance(fmt, OutFormat):
            return fmt
        return cls(*fmt)


def sparsified_op(orig_op, out_fmt, grad_out_fmt=None,
                  dense_fn: Optional[Callable] = None):
    """Build a sparse operator from ``orig_op`` and output format(s) —
    the JAX spelling of ``sten.sparsified_op``.

    Single-output ops take a single OutFormat (or 4-tuple); the returned
    callable dispatches to registered sparse implementations (with fusion of
    the inline sparsifier when available), applies the external sparsifier,
    and returns the final layout instance.

    ``grad_out_fmt`` is recorded on the returned callable; gradient
    sparsification in JAX happens where gradients materialize (the optimizer
    update — see optim/sparse_update.py), since JAX cotangents mirror primal
    pytree structure (DESIGN.md §2).
    """
    fmt = OutFormat.coerce(out_fmt[0] if isinstance(out_fmt, (list, tuple))
                           and out_fmt and isinstance(out_fmt[0], (OutFormat, tuple))
                           else out_fmt)

    def op(*args, key: Optional[jax.Array] = None, **kwargs):
        tmp = dispatch(orig_op, *args, inline=fmt.inline, dense_fn=dense_fn,
                       **kwargs)
        # materialize in tmp layout
        if not isinstance(tmp, SparsityLayout):
            tmp = conv.as_layout(tmp)
        if fmt.tmp_layout is not None and not isinstance(tmp, fmt.tmp_layout):
            tmp = conv.convert(tmp, fmt.tmp_layout)
        # external sparsifier -> output layout
        if isinstance(fmt.external, KeepAll) and isinstance(tmp, fmt.out_layout):
            return tmp
        return apply_sparsifier(fmt.external, tmp, fmt.out_layout, key=key)

    op.grad_out_fmt = grad_out_fmt
    op.out_fmt = fmt
    op.__name__ = f"sparse_{_canonical_name(orig_op)}"
    return op

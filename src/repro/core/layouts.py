"""Sparsity layouts — the first leg of the STen programming model (paper §3.1).

A *sparsity layout* augments a tensor with a storage format.  In STen-JAX every
layout is a pytree-registered dataclass so it flows through ``jit`` / ``pjit`` /
``grad`` / ``scan`` unchanged.  This replaces STen's PyTorch mechanism of
wrapping custom tensors in single-element dummy tensors to satisfy the C++
autograd core (paper §4.2) — JAX autograd is pytree-native, so no wrapper is
needed.

Unstructured formats (CSR/COO) are **capacity padded**: XLA requires static
shapes, so ``nnz_cap`` is part of the layout metadata and the tail is
zero-filled.  Structured formats (n:m, n:m:g) are naturally shape-static,
which is one reason they map well to TPUs.

``to_dense`` is implemented with differentiable jnp ops for every layout, so
gradients w.r.t. the stored values flow automatically (see core/autograd.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, ClassVar, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "SparsityLayout",
    "DenseTensor",
    "CsrTensor",
    "CooTensor",
    "FixedMaskTensor",
    "NMTensor",
    "GroupedNMTensor",
    "SpmmPlan",
    "build_spmm_plan",
    "register_layout",
    "all_layouts",
    "nm_patterns",
    "pos_pattern_offsets",
    "pattern_onehots",
    "pad_to_multiple",
]

_LAYOUT_REGISTRY: dict[str, type] = {}


def register_layout(cls):
    """Class decorator: register ``cls`` as a sparsity layout and a pytree.

    The class must define ``tree_flatten`` / ``tree_unflatten`` and
    ``to_dense``.  This is the extension point the paper's §3.1 example
    (``CscTensor``) exercises — see tests/test_extensibility.py for the
    JAX equivalent of that example.
    """
    if not hasattr(cls, "to_dense"):
        raise TypeError(f"layout {cls.__name__} must define to_dense()")
    jax.tree_util.register_pytree_node(
        cls, cls.tree_flatten, cls.tree_unflatten
    )
    _LAYOUT_REGISTRY[cls.__name__] = cls
    return cls


def all_layouts() -> dict[str, type]:
    return dict(_LAYOUT_REGISTRY)


def pad_to_multiple(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    """Zero-pad ``x`` along ``axis`` to the next multiple of ``mult``."""
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


class SparsityLayout:
    """Base class for sparsity layouts (informal protocol).

    Required: ``to_dense() -> jnp.ndarray``, ``shape``, ``dtype``.
    Optional: ``density()`` (fraction of stored values), ``nnz``.
    """

    #: subclasses set this; used by the dispatcher for error messages
    layout_name: ClassVar[str] = "abstract"

    @property
    def shape(self):  # pragma: no cover - abstract
        raise NotImplementedError

    @property
    def dtype(self):  # pragma: no cover - abstract
        raise NotImplementedError

    def to_dense(self) -> jnp.ndarray:  # pragma: no cover - abstract
        raise NotImplementedError

    # Convenience mirrors of the dense tensor API so layouts can be used
    # in shape-polymorphic code (paper §4.4 "override the method or
    # attribute ... with the same name as in the corresponding dense
    # tensor").
    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


# ---------------------------------------------------------------------------
# Dense (the trivial layout; KeepAll sparsifier default)
# ---------------------------------------------------------------------------


@register_layout
@dataclasses.dataclass
class DenseTensor(SparsityLayout):
    """Trivial layout: a dense jnp array.  Exists so the dispatcher can treat
    dense and sparse operands uniformly."""

    data: jnp.ndarray
    layout_name: ClassVar[str] = "dense"

    @property
    def shape(self):
        return tuple(self.data.shape)

    @property
    def dtype(self):
        return self.data.dtype

    def to_dense(self):
        return self.data

    def density(self):
        return 1.0

    def tree_flatten(self):
        return (self.data,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def _as_array(x):
    return x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)


# ---------------------------------------------------------------------------
# CSR — capacity padded
# ---------------------------------------------------------------------------


@register_layout
@dataclasses.dataclass
class CsrTensor(SparsityLayout):
    """Compressed Sparse Row with a static nonzero capacity.

    ``data``/``indices`` have length ``nnz_cap`` (>= true nnz); padding
    entries carry value 0 and column 0 and live past ``indptr[-1]``.
    2-D only (matrices), like torch.sparse_csr.
    """

    data: jnp.ndarray      # [nnz_cap]
    indices: jnp.ndarray   # [nnz_cap] int32 column ids
    indptr: jnp.ndarray    # [rows + 1] int32
    dense_shape: tuple     # static
    layout_name: ClassVar[str] = "csr"

    @property
    def shape(self):
        return tuple(self.dense_shape)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nnz_cap(self):
        return self.data.shape[0]

    def to_dense(self):
        rows, cols = self.dense_shape
        # row id per stored entry: count of indptr boundaries passed
        positions = jnp.arange(self.nnz_cap)
        row_ids = jnp.searchsorted(self.indptr, positions, side="right") - 1
        row_ids = jnp.clip(row_ids, 0, rows - 1)
        valid = positions < self.indptr[-1]
        flat_idx = row_ids * cols + self.indices
        vals = jnp.where(valid, self.data, 0)
        out = jnp.zeros(rows * cols, self.data.dtype).at[flat_idx].add(vals)
        return out.reshape(rows, cols)

    def density(self):
        return float(jax.device_get(self.indptr[-1])) / max(1, self.size)

    @classmethod
    def from_dense(cls, x, nnz_cap: int | None = None) -> "CsrTensor":
        """Exact (lossless) dense->CSR conversion.  Traceable: uses a fixed
        capacity (defaults to the true nnz rounded up to a multiple of 8,
        computed eagerly when ``x`` is concrete)."""
        x = _as_array(x)
        assert x.ndim == 2, "CsrTensor is 2-D"
        rows, cols = x.shape
        mask = x != 0
        nnz_per_row = jnp.sum(mask, axis=1, dtype=jnp.int32)
        indptr = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(nnz_per_row, dtype=jnp.int32)]
        )
        if nnz_cap is None:
            total = int(jax.device_get(indptr[-1]))
            nnz_cap = max(8, int(math.ceil(total / 8.0)) * 8)
        # stable sort puts nonzeros of each row first, in column order
        order = jnp.argsort(~mask, axis=1, stable=True)
        sorted_vals = jnp.take_along_axis(x, order, axis=1)
        # flatten row-major, then compact valid entries to the front
        keep = jnp.take_along_axis(mask, order, axis=1)
        flat_vals = sorted_vals.reshape(-1)
        flat_cols = order.reshape(-1).astype(jnp.int32)
        flat_keep = keep.reshape(-1)
        dest = jnp.cumsum(flat_keep) - 1
        # dropped or beyond-capacity -> scratch slot (never clamp into data)
        dest = jnp.where(flat_keep & (dest < nnz_cap), dest, nnz_cap)
        data = jnp.zeros((nnz_cap + 1,), x.dtype).at[dest].set(flat_vals)[:-1]
        indices = (
            jnp.zeros((nnz_cap + 1,), jnp.int32).at[dest].set(flat_cols)[:-1]
        )
        return cls(data, indices, indptr, (rows, cols))

    def tree_flatten(self):
        return (self.data, self.indices, self.indptr), (self.dense_shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


# ---------------------------------------------------------------------------
# COO — capacity padded
# ---------------------------------------------------------------------------


@register_layout
@dataclasses.dataclass
class CooTensor(SparsityLayout):
    """Coordinate format with static capacity; N-dimensional."""

    data: jnp.ndarray     # [nnz_cap]
    coords: jnp.ndarray   # [ndim, nnz_cap] int32
    dense_shape: tuple
    layout_name: ClassVar[str] = "coo"

    @property
    def shape(self):
        return tuple(self.dense_shape)

    @property
    def dtype(self):
        return self.data.dtype

    @property
    def nnz_cap(self):
        return self.data.shape[0]

    def to_dense(self):
        strides = np.array(
            [int(np.prod(self.dense_shape[i + 1 :])) for i in range(len(self.dense_shape))],
            dtype=np.int32,
        )
        flat_idx = jnp.sum(self.coords * strides[:, None], axis=0)
        out = jnp.zeros(int(np.prod(self.dense_shape)), self.data.dtype)
        out = out.at[flat_idx].add(self.data)
        return out.reshape(self.dense_shape)

    def density(self):
        return float(jax.device_get(jnp.sum(self.data != 0))) / max(1, self.size)

    @classmethod
    def from_dense(cls, x, nnz_cap: int | None = None) -> "CooTensor":
        x = _as_array(x)
        flat = x.reshape(-1)
        mask = flat != 0
        if nnz_cap is None:
            total = int(jax.device_get(jnp.sum(mask)))
            nnz_cap = max(8, int(math.ceil(total / 8.0)) * 8)
        dest = jnp.cumsum(mask) - 1
        dest = jnp.where(mask & (dest < nnz_cap), dest, nnz_cap)
        data = jnp.zeros((nnz_cap + 1,), x.dtype).at[dest].set(flat)[:-1]
        flat_pos = jnp.zeros((nnz_cap + 1,), jnp.int32).at[dest].set(
            jnp.arange(flat.shape[0], dtype=jnp.int32)
        )[:-1]
        coords = []
        rem = flat_pos
        for dim in reversed(x.shape):
            coords.append(rem % dim)
            rem = rem // dim
        coords = jnp.stack(list(reversed(coords)), axis=0)
        return cls(data, coords, tuple(x.shape))

    def tree_flatten(self):
        return (self.data, self.coords), (self.dense_shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


# ---------------------------------------------------------------------------
# FixedMaskTensor — masked-dense emulation (the paper's training workhorse)
# ---------------------------------------------------------------------------


@register_layout
@dataclasses.dataclass
class FixedMaskTensor(SparsityLayout):
    """Dense values + boolean mask.  The paper's §5.3 ``FixedMaskTensor``:
    used for masked sparse training/fine-tuning where the sparsity pattern
    changes slowly.  Offers no storage saving (by design) but preserves
    sparsity semantics, and its fixed pattern enables the value-only
    all-reduce fast path (dist/collectives.py).

    ``origin`` (optional, static aux) records the sparsifier that produced
    the mask so SameFormatSparsifier pattern *recomputes* use the native
    algorithm (e.g. the n:m:g assignment) rather than generic magnitude —
    the paper's 'new sparsification is more expensive for formats with
    complex constraints' (Fig 9).
    """

    val: jnp.ndarray
    mask: jnp.ndarray  # same shape, bool (or 0/1 of val dtype)
    origin: Any = None
    layout_name: ClassVar[str] = "fixed_mask"

    @property
    def shape(self):
        return tuple(self.val.shape)

    @property
    def dtype(self):
        return self.val.dtype

    def to_dense(self):
        return self.val * self.mask.astype(self.val.dtype)

    def density(self):
        return float(jax.device_get(jnp.mean(self.mask.astype(jnp.float32))))

    @classmethod
    def from_dense(cls, x) -> "FixedMaskTensor":
        x = _as_array(x)
        return cls(x, (x != 0))

    def tree_flatten(self):
        return (self.val, self.mask), (self.origin,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


# ---------------------------------------------------------------------------
# n:m (un-grouped) — e.g. NVIDIA 2:4
# ---------------------------------------------------------------------------

# The three pattern-table memoizations below are deliberately *unbounded*
# (contrast the LRU-bounded jitted-closure caches in repro/serve): each
# entry is a tiny read-only numpy constant — O(C(m,n) * max(n, m)) ints —
# keyed by the handful of (n, m[, g]) formats a process ever uses, holds
# no device buffers or compiled programs, and is consulted on every
# conversion and kernel trace, so eviction could only ever trade a few
# hundred bytes for rebuild work on a hot path.


@functools.lru_cache(maxsize=None)
def nm_patterns(n: int, m: int) -> np.ndarray:
    """All C(m, n) nonzero patterns (index tuples), in *revolving-door* order
    so adjacent patterns differ in exactly one position (paper §5.1: "the
    nonzero pattern between adjacent groups differs in only one location, so
    that we need save and initialize only one vector register").

    Returns a read-only int32 array [C(m,n), n] of in-block offsets, each
    row sorted.  Memoized: the table is a compile-time constant consulted by
    every conversion and kernel trace, so it is built once per (n, m).
    """
    combos = _revolving_door(m, n)
    arr = np.array([sorted(c) for c in combos], dtype=np.int32)
    arr.setflags(write=False)
    return arr


@functools.lru_cache(maxsize=None)
def pos_pattern_offsets(n: int, m: int, g: int) -> np.ndarray:
    """In-block offsets per chunk *position* (read-only int32 [C*g, n]):
    chunk position p carries pattern ``p // g`` (the format invariant), so
    this is ``nm_patterns`` with each row repeated g times."""
    arr = np.repeat(nm_patterns(n, m), g, axis=0)
    arr.setflags(write=False)
    return arr


@functools.lru_cache(maxsize=None)
def pattern_onehots(n: int, m: int) -> np.ndarray:
    """One-hot pattern table (read-only f32 [C, m]): row p has ones at the
    in-block offsets pattern p keeps.  Used for the conversion's score
    einsum and carried on :class:`SpmmPlan` for matmul-style gathers."""
    C = math.comb(m, n)
    pats = nm_patterns(n, m)
    oh = np.zeros((C, m), np.float32)
    oh[np.repeat(np.arange(C), n), pats.reshape(-1)] = 1.0
    oh.setflags(write=False)
    return oh


def _revolving_door(m: int, n: int) -> list[tuple[int, ...]]:
    """Generate n-subsets of range(m) in revolving-door Gray order."""
    if n == 0:
        return [()]
    if n == m:
        return [tuple(range(m))]
    # Recurrence: A(m,n) = A(m-1,n) then reversed A(m-1,n-1) each + {m-1}
    first = _revolving_door(m - 1, n)
    second = [c + (m - 1,) for c in reversed(_revolving_door(m - 1, n - 1))]
    return first + second


@register_layout
@dataclasses.dataclass
class NMTensor(SparsityLayout):
    """Plain n:m sparsity along the last axis: each consecutive block of m
    elements stores exactly n values.  Shape-static: nnz == size * n / m.
    """

    val: jnp.ndarray   # [..., nblocks, n]
    idx: jnp.ndarray   # [..., nblocks, n] int32 in-block offsets (sorted)
    n: int
    m: int
    dense_shape: tuple
    layout_name: ClassVar[str] = "nm"

    @property
    def shape(self):
        return tuple(self.dense_shape)

    @property
    def dtype(self):
        return self.val.dtype

    def to_dense(self):
        *lead, k = self.dense_shape
        k_pad = self.val.shape[-2] * self.m
        nblocks = self.val.shape[-2]
        base = jnp.arange(nblocks, dtype=jnp.int32) * self.m  # [nblocks]
        cols = base[:, None] + self.idx  # [..., nblocks, n]
        flat_cols = cols.reshape(*cols.shape[:-2], -1)
        flat_vals = self.val.reshape(*self.val.shape[:-2], -1)
        out = jnp.zeros((*self.val.shape[:-2], k_pad), self.val.dtype)
        out = _scatter_last(out, flat_cols, flat_vals)
        return out[..., :k]

    def density(self):
        return self.n / self.m

    @classmethod
    def from_dense(cls, x, n: int, m: int) -> "NMTensor":
        """Magnitude-based per-block top-n (the paper's per-block fraction
        sparsifier, Table 1 — a *blocking* sparsifier)."""
        x = _as_array(x)
        k = x.shape[-1]
        xp = pad_to_multiple(x, m, axis=-1)
        blocks = xp.reshape(*xp.shape[:-1], -1, m)
        _, idx = jax.lax.top_k(jnp.abs(blocks), n)
        idx = jnp.sort(idx, axis=-1).astype(jnp.int32)
        val = jnp.take_along_axis(blocks, idx, axis=-1)
        return cls(val, idx, n, m, tuple(x.shape))

    def tree_flatten(self):
        return (self.val, self.idx), (self.n, self.m, self.dense_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


def _scatter_last(out, cols, vals):
    """Scatter ``vals`` into ``out`` along the last axis at ``cols``.
    Batched over leading dims via vmap composition."""
    def scat1(o, c, v):
        return o.at[c].add(v)

    fn = scat1
    for _ in range(out.ndim - 1):
        fn = jax.vmap(fn)
    return fn(out, cols, vals)


# ---------------------------------------------------------------------------
# n:m:g — the paper's novel grouped n:m layout (§5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SpmmPlan:
    """Precomputed gather plan for the n:m:g matmul kernels (serving fast
    path).  Built once at conversion time (``dense_to_grouped_nm``) instead
    of being re-derived from ``blk_idx`` on every kernel call:

      cols        [Gr, nblocks*n] int32 — for each fiber-group and stored
                  value, the *original* (dense) K-axis row of B it multiplies
                  (compressed-column index: ``blk_idx * m + pattern offset``).
      pat_onehot  [C*g, m] int8 — one-hot of the in-block offsets each chunk
                  position keeps (``pattern_onehots`` repeated g times);
                  enables matmul-style gathers on backends where dynamic
                  gathers are slow.

    Both are pytree leaves so the plan flows through jit/scan/stacked-layer
    params unchanged; they are derived data — any transform that rewrites
    ``blk_idx`` must rebuild (or drop) the plan.  Both are deliberately
    *integer* leaves: autograd gives them symbolic-zero cotangents and the
    optimizer skips them, exactly like ``blk_idx`` (a float leaf here would
    silently receive weight decay).
    """

    cols: jnp.ndarray
    pat_onehot: jnp.ndarray

    def tree_flatten(self):
        return (self.cols, self.pat_onehot), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    SpmmPlan, SpmmPlan.tree_flatten, SpmmPlan.tree_unflatten
)


def build_spmm_plan(blk_idx: jnp.ndarray, n: int, m: int, g: int) -> SpmmPlan:
    """Derive the kernel gather plan from a ``blk_idx`` permutation table."""
    Gr, nchunks, CG = blk_idx.shape
    pos = jnp.asarray(pos_pattern_offsets(n, m, g))          # [CG, n]
    cols = blk_idx[..., None] * m + pos[None, None]          # [Gr, nc, CG, n]
    onehot = jnp.asarray(
        np.repeat(pattern_onehots(n, m), g, axis=0).astype(np.int8)
    )
    return SpmmPlan(
        cols=cols.reshape(Gr, nchunks * CG * n).astype(jnp.int32),
        pat_onehot=onehot,
    )


@register_layout
@dataclasses.dataclass
class GroupedNMTensor(SparsityLayout):
    """Grouped n:m (``n:m:g``) sparsity (paper §5, Fig 5).

    The canonical 2-D view is ``[R, K]`` with the **sparse dim = K** (last
    axis).  Along K, m-element blocks are collected into *chunks* of
    ``C(m,n) * g`` blocks.  Within a chunk every nonzero pattern appears
    exactly ``g`` times ("each nonzero pattern is repeated g times, forming a
    group"), in the fixed revolving-door pattern order: chunk position ``p``
    carries pattern ``p // g``.  Blocks are permuted within the chunk to
    maximize preserved magnitude, and ``blk_idx`` records the *original*
    m-block index at each position.  Larger g = larger chunks = more freedom
    = energy closer to plain n:m (paper Fig 7).

    TPU adaptation knob (DESIGN.md §2.1): ``gr`` shares the chunk
    permutation across ``gr`` consecutive rows, which is what lets the MXU
    kernel amortize its B-row gathers across a row tile.  ``gr=1`` is
    exactly the paper's per-fiber format (the CPU/AVX kernel needs no
    sharing); TPU configs use gr = 8..128.  The energy cost of gr > 1 is
    measured in benchmarks/fig7_energy.py.

    Storage (K padded to a multiple of m*C(m,n)*g, R to a multiple of gr):
      val      [R_pad, nblocks, n]            compressed values, permuted order
      blk_idx  [R_pad // gr, nchunks, C*g]    original block index per position
    The pattern table ``nm_patterns(n, m)`` and the position->pattern map are
    compile-time constants — the key property the TPU kernel exploits.
    """

    val: jnp.ndarray
    blk_idx: jnp.ndarray
    n: int
    m: int
    g: int
    gr: int
    dense_shape: tuple   # original (pre-transpose, pre-pad) shape
    sparse_dim: int
    #: optional precomputed kernel gather plan (derived from blk_idx);
    #: conversion fills it in, transforms that rewrite blk_idx must rebuild
    plan: Optional[SpmmPlan] = None
    layout_name: ClassVar[str] = "grouped_nm"

    @property
    def shape(self):
        return tuple(self.dense_shape)

    @property
    def dtype(self):
        return self.val.dtype

    @property
    def num_patterns(self):
        return math.comb(self.m, self.n)

    def density(self):
        return self.n / self.m

    def _canonical_dims(self):
        # canonical: cols = sparse dim
        sd = self.sparse_dim % 2
        gd = 1 - sd
        r, k = self.dense_shape[gd], self.dense_shape[sd]
        return sd, gd, r, k

    def gather_plan(self) -> SpmmPlan:
        """The kernel gather plan: the precomputed one when the conversion
        attached it, else derived on the fly from ``blk_idx`` (trace-safe)."""
        if self.plan is not None:
            return self.plan
        return build_spmm_plan(self.blk_idx, self.n, self.m, self.g)

    def to_dense(self):
        sd, gd, r, k = self._canonical_dims()
        C = self.num_patterns
        CG = C * self.g
        R_pad, nblocks, n = self.val.shape
        nchunks = nblocks // CG
        # in-block offsets per chunk position (static): pattern p//g
        pos_pat = jnp.tile(
            jnp.asarray(pos_pattern_offsets(self.n, self.m, self.g)),
            (nchunks, 1),
        )
        # original block per (row, position): [R_pad, nblocks]
        orig_block = self.blk_idx.reshape(R_pad // self.gr, nblocks)
        orig_block_rows = jnp.repeat(orig_block, self.gr, axis=0)
        cols = orig_block_rows[..., None] * self.m + pos_pat[None]  # [R_pad, nb, n]
        flat_cols = cols.reshape(R_pad, -1)
        flat_vals = self.val.reshape(R_pad, -1)
        k_pad = nblocks * self.m
        out = jnp.zeros((R_pad, k_pad), self.val.dtype)
        out = _scatter_last(out, flat_cols, flat_vals)
        out = out[:r, :k]
        if sd == 0:  # sparse dim was rows -> transpose back
            out = out.T
        return out

    def tree_flatten(self):
        # ``plan`` is a child so its index arrays ride along under jit/scan
        # (None flattens to an empty subtree, keeping plan-free tensors
        # structurally distinct from planned ones)
        return (self.val, self.blk_idx, self.plan), (
            self.n, self.m, self.g, self.gr, self.dense_shape, self.sparse_dim,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, blk_idx, plan = children
        return cls(val, blk_idx, *aux, plan=plan)

    @classmethod
    def from_dense(cls, x, n: int, m: int, g: int, gr: int = 1,
                   sparse_dim: int = -1, method: str = "greedy"
                   ) -> "GroupedNMTensor":
        # implemented in core/nmg.py to keep this module layout-only
        from repro.core import nmg
        return nmg.dense_to_grouped_nm(
            _as_array(x), n=n, m=m, g=g, gr=gr, sparse_dim=sparse_dim,
            method=method,
        )

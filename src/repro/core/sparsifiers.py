"""Sparsifiers — the third leg of the STen programming model (paper §3.3).

A sparsifier decides which output values of an operator to keep.  Following
Table 1 of the paper they are classified by how much data they need before
they can produce output:

  * **streaming**      1 pass, O(1) memory   (keep-all, random fraction,
                       scalar threshold) — candidates for inlining into
                       operators (see kernels/fused_sparse_matmul.py).
  * **blocking**       2 passes, O(b) memory (per-block fraction = n:m,
                       grouped n:m) — candidates for inlining.
  * **materializing**  2 passes, O(nnz)      (scalar fraction = magnitude,
                       block-wise fraction, complex weight sparsifiers).

Every sparsifier exposes its semantic core as ``mask(x, key=None)``; layout-
specific implementations are registered in a global registry keyed by
``(sparsifier class, input layout, output layout)`` — the JAX analogue of
``sten.register_sparsifier_implementation``.  Unregistered combinations fall
back to mask + lossless conversion, mirroring STen's dense fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import nmg
from repro.core.layouts import (
    CooTensor,
    CsrTensor,
    DenseTensor,
    FixedMaskTensor,
    GroupedNMTensor,
    NMTensor,
    SparsityLayout,
)

__all__ = [
    "Sparsifier",
    "KeepAll",
    "RandomFractionSparsifier",
    "ScalarThresholdSparsifier",
    "NMSparsifier",
    "GroupedNMSparsifier",
    "ScalarFractionSparsifier",
    "BlockwiseFractionSparsifier",
    "SameFormatSparsifier",
    "register_sparsifier_implementation",
    "apply_sparsifier",
    "lookup_sparsifier_impl",
]

STREAMING = "streaming"
BLOCKING = "blocking"
MATERIALIZING = "materializing"


class Sparsifier:
    """Base class.  ``kind`` is the Table-1 classification; ``passes`` the
    number of passes over the tensor it requires."""

    kind = STREAMING
    passes = 1

    def mask(self, x: jnp.ndarray, key: Optional[jax.Array] = None):
        raise NotImplementedError

    def __call__(self, x, key=None):
        """Default action: dense in, masked dense out."""
        x = x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)
        return x * self.mask(x, key).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class KeepAll(Sparsifier):
    """Trivial sparsifier: keeps every produced value (paper Table 1).  The
    default for dense tensors, and the identity 'inline sparsifier' in an
    output format tuple."""

    kind = STREAMING
    passes = 1

    def mask(self, x, key=None):
        return jnp.ones_like(x, dtype=jnp.bool_)


@dataclasses.dataclass(frozen=True)
class RandomFractionSparsifier(Sparsifier):
    """Drop values with probability ``fraction`` (dropout-style)."""

    fraction: float = 0.5
    kind = STREAMING
    passes = 1

    def mask(self, x, key=None):
        if key is None:
            key = jax.random.PRNGKey(0)
        return jax.random.uniform(key, x.shape) >= self.fraction


@dataclasses.dataclass(frozen=True)
class ScalarThresholdSparsifier(Sparsifier):
    """Keep |x| >= threshold (ReLU-style streaming selection)."""

    threshold: float = 0.0
    kind = STREAMING
    passes = 1

    def mask(self, x, key=None):
        return jnp.abs(x) >= self.threshold


@dataclasses.dataclass(frozen=True)
class NMSparsifier(Sparsifier):
    """Per-block fraction (Table 1): keep the top-n of each m-block along the
    last axis — plain n:m sparsity [NVIDIA A100; Zhou et al.]."""

    n: int = 2
    m: int = 4
    kind = BLOCKING
    passes = 2

    def mask(self, x, key=None):
        return nmg.nm_mask(x, self.n, self.m).astype(jnp.bool_)


@dataclasses.dataclass(frozen=True)
class GroupedNMSparsifier(Sparsifier):
    """The paper's n:m:g sparsifier (§5.2).  ``gr`` is the TPU row-sharing
    width (gr=1 == the paper's per-fiber format; see DESIGN.md §2.1)."""

    n: int = 2
    m: int = 4
    g: int = 16
    gr: int = 1
    method: str = "greedy"
    sparse_dim: int = -1   # weights stored [K, N] use 0 (the input axis)
    kind = BLOCKING
    passes = 2

    def mask(self, x, key=None):
        fn = lambda xx: nmg.grouped_nm_mask(  # noqa: E731
            xx, self.n, self.m, self.g, gr=self.gr,
            sparse_dim=self.sparse_dim, method=self.method,
        ).astype(jnp.bool_)
        if x.ndim == 3:  # scan-stacked [L, ...] weights: per-layer masks
            return jax.vmap(fn)(x)
        return fn(x)


@dataclasses.dataclass(frozen=True)
class ScalarFractionSparsifier(Sparsifier):
    """Magnitude pruning (Table 1, materializing): keep the top
    (1 - fraction) of values by |x| globally over the tensor."""

    fraction: float = 0.5
    kind = MATERIALIZING
    passes = 2

    def mask(self, x, key=None):
        return nmg.unstructured_mask(x, self.fraction).astype(jnp.bool_)


@dataclasses.dataclass(frozen=True)
class BlockwiseFractionSparsifier(Sparsifier):
    """Block-wise fraction (Table 1): drop whole blocks with the smallest
    combined magnitude (filter/block pruning)."""

    fraction: float = 0.5
    block: int = 4
    kind = MATERIALIZING
    passes = 2

    def mask(self, x, key=None):
        return nmg.blocked_mask(x, self.block, self.fraction).astype(jnp.bool_)


@dataclasses.dataclass(frozen=True)
class SameFormatSparsifier(Sparsifier):
    """Re-sparsify a new (dense) value into the same format as a reference
    sparse tensor (paper §4: applied after optimizer updates since functional
    updates produce a new tensor).

    ``fixed_pattern=True`` reuses the reference's nonzero pattern (the cheap
    path that dominates training — paper Fig 9 'fixed sparsification');
    ``False`` recomputes the pattern with the layout's native sparsifier
    ('new sparsification').
    """

    fixed_pattern: bool = True
    kind = BLOCKING
    passes = 1

    def resparsify(self, ref, new_dense: jnp.ndarray):
        new_dense = (
            new_dense.to_dense()
            if isinstance(new_dense, SparsityLayout)
            else jnp.asarray(new_dense)
        )
        if isinstance(ref, FixedMaskTensor):
            if self.fixed_pattern:
                return FixedMaskTensor(new_dense * ref.mask, ref.mask,
                                       ref.origin)
            if ref.origin is not None:
                # native recompute (e.g. the n:m:g assignment — Fig 9's
                # 'new sparsification' for complex formats)
                mask = ref.origin.mask(new_dense)
                return FixedMaskTensor(new_dense * mask, mask, ref.origin)
            # generic: recompute at the reference's density via magnitude
            # ranks (traceable even with data-dependent nnz)
            k = jnp.sum(ref.mask.astype(jnp.int32))
            flat = jnp.abs(new_dense).reshape(-1)
            order = jnp.argsort(-flat)
            ranks = jnp.zeros_like(order).at[order].set(
                jnp.arange(order.shape[0]))
            mask = (ranks < k).reshape(new_dense.shape)
            return FixedMaskTensor(new_dense * mask, mask, ref.origin)
        if isinstance(ref, GroupedNMTensor):
            if self.fixed_pattern:
                return _regather_grouped_nm(ref, new_dense)
            return nmg.dense_to_grouped_nm(
                new_dense, n=ref.n, m=ref.m, g=ref.g, gr=ref.gr,
                sparse_dim=ref.sparse_dim,
            )
        if isinstance(ref, NMTensor):
            if self.fixed_pattern:
                return _regather_nm(ref, new_dense)
            return NMTensor.from_dense(new_dense, ref.n, ref.m)
        if isinstance(ref, CsrTensor):
            if self.fixed_pattern:
                rows, cols = ref.shape
                positions = jnp.arange(ref.nnz_cap)
                row_ids = jnp.clip(
                    jnp.searchsorted(ref.indptr, positions, side="right") - 1,
                    0, rows - 1,
                )
                valid = positions < ref.indptr[-1]
                data = jnp.where(valid, new_dense[row_ids, ref.indices], 0)
                return CsrTensor(data.astype(ref.dtype), ref.indices,
                                 ref.indptr, ref.dense_shape)
            return CsrTensor.from_dense(new_dense, nnz_cap=ref.nnz_cap)
        if isinstance(ref, CooTensor):
            if self.fixed_pattern:
                data = new_dense[tuple(ref.coords)]
                # padding slots (coord origin + stored zero) stay zero
                pad = (ref.coords.sum(0) == 0) & (ref.data == 0)
                data = jnp.where(pad, 0, data)
                return CooTensor(data.astype(ref.dtype), ref.coords,
                                 ref.dense_shape)
            return CooTensor.from_dense(new_dense, nnz_cap=ref.nnz_cap)
        if isinstance(ref, DenseTensor):
            return DenseTensor(new_dense)
        raise TypeError(f"SameFormatSparsifier: unsupported ref {type(ref)}")


def _regather_nm(ref: NMTensor, dense: jnp.ndarray) -> NMTensor:
    from repro.core.layouts import pad_to_multiple

    xp = pad_to_multiple(dense, ref.m, axis=-1)
    blocks = xp.reshape(*xp.shape[:-1], -1, ref.m)
    val = jnp.take_along_axis(blocks, ref.idx, axis=-1)
    return NMTensor(val, ref.idx, ref.n, ref.m, ref.dense_shape)


def _regather_grouped_nm(ref: GroupedNMTensor, dense: jnp.ndarray
                         ) -> GroupedNMTensor:
    """Fixed-pattern re-gather: keep blk_idx, re-read values from ``dense``.
    This is the fast path used after most optimizer steps.  The gather
    indices come straight from the tensor's :class:`SpmmPlan` (the pattern
    is unchanged, so the plan stays valid and is carried forward)."""
    import math as _math

    from repro.core.layouts import pad_to_multiple

    sd = ref.sparse_dim % 2
    xc = dense.T if sd == 0 else dense
    C = _math.comb(ref.m, ref.n)
    CG = C * ref.g
    xp = pad_to_multiple(pad_to_multiple(xc, ref.gr, 0), ref.m * CG, 1)
    R_pad = xp.shape[0]
    _, nchunks, _ = ref.blk_idx.shape
    plan = ref.gather_plan()
    cols_rows = jnp.repeat(plan.cols, ref.gr, axis=0)  # [R_pad, nblocks*n]
    val = jnp.take_along_axis(xp, cols_rows, axis=1).reshape(
        R_pad, nchunks * CG, ref.n
    )
    return GroupedNMTensor(
        val=val, blk_idx=ref.blk_idx, n=ref.n, m=ref.m, g=ref.g, gr=ref.gr,
        dense_shape=ref.dense_shape, sparse_dim=ref.sparse_dim, plan=plan,
    )


# ---------------------------------------------------------------------------
# Sparsifier implementation registry (paper §3.3 / §4.3)
# ---------------------------------------------------------------------------

_SPARSIFIER_IMPLS: dict[tuple, Callable] = {}


def register_sparsifier_implementation(sparsifier: type, inp: type, out: type):
    """Decorator mirroring ``sten.register_sparsifier_implementation``.

    The implementation signature is ``fn(sparsifier, tensor, key=None)`` and
    must return an instance of ``out``.
    """

    def deco(fn):
        keyt = (sparsifier, inp, out)
        if keyt in _SPARSIFIER_IMPLS:
            raise ValueError(f"duplicate sparsifier impl for {keyt}")
        _SPARSIFIER_IMPLS[keyt] = fn
        return fn

    return deco


def lookup_sparsifier_impl(sparsifier, inp_cls, out_cls):
    return _SPARSIFIER_IMPLS.get((type(sparsifier), inp_cls, out_cls))


def apply_sparsifier(sparsifier: Sparsifier, x, out_layout: type = DenseTensor,
                     key: Optional[jax.Array] = None):
    """Apply ``sparsifier`` to ``x`` producing ``out_layout``.

    Lookup order (paper §4.4 fallback semantics):
      1. registered (sparsifier, layout(x), out_layout) implementation;
      2. registered (sparsifier, DenseTensor, out_layout) after densifying;
      3. generic fallback: mask in dense space, then lossless conversion
         to the requested output layout.
    """
    inp_cls = type(x) if isinstance(x, SparsityLayout) else DenseTensor
    impl = lookup_sparsifier_impl(sparsifier, inp_cls, out_layout)
    if impl is not None:
        return impl(sparsifier, x, key=key)
    if inp_cls is not DenseTensor:
        impl = lookup_sparsifier_impl(sparsifier, DenseTensor, out_layout)
        if impl is not None:
            return impl(sparsifier, DenseTensor(x.to_dense()), key=key)
    # generic fallback
    dense = x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)
    if isinstance(sparsifier, KeepAll):
        masked, mask = dense, jnp.ones_like(dense, jnp.bool_)
    else:
        mask = sparsifier.mask(dense, key)
        masked = dense * mask.astype(dense.dtype)
    return _dense_to_layout(masked, mask, out_layout, sparsifier)


def _dense_to_layout(masked, mask, out_layout, sparsifier):
    if out_layout in (DenseTensor, jnp.ndarray, None):
        return DenseTensor(masked)
    if out_layout is FixedMaskTensor:
        return FixedMaskTensor(masked, mask, origin=sparsifier)
    if out_layout is CsrTensor:
        return CsrTensor.from_dense(masked)
    if out_layout is CooTensor:
        return CooTensor.from_dense(masked)
    if out_layout is NMTensor:
        n, m = getattr(sparsifier, "n", 2), getattr(sparsifier, "m", 4)
        return NMTensor.from_dense(masked, n, m)
    if out_layout is GroupedNMTensor:
        n = getattr(sparsifier, "n", 2)
        m = getattr(sparsifier, "m", 4)
        g = getattr(sparsifier, "g", 16)
        gr = getattr(sparsifier, "gr", 1)
        return nmg.dense_to_grouped_nm(masked, n=n, m=m, g=g, gr=gr)
    raise TypeError(f"no conversion path to layout {out_layout}")


# -- native (non-fallback) implementations for the structured formats -------


@register_sparsifier_implementation(NMSparsifier, DenseTensor, NMTensor)
def _dense_to_nm(sp: NMSparsifier, x, key=None):
    return NMTensor.from_dense(x.to_dense() if isinstance(x, SparsityLayout) else x,
                               sp.n, sp.m)


@register_sparsifier_implementation(GroupedNMSparsifier, DenseTensor,
                                    GroupedNMTensor)
def _dense_to_grouped_nm_impl(sp: GroupedNMSparsifier, x, key=None):
    return nmg.dense_to_grouped_nm(
        x.to_dense() if isinstance(x, SparsityLayout) else x,
        n=sp.n, m=sp.m, g=sp.g, gr=sp.gr, sparse_dim=sp.sparse_dim,
        method=sp.method,
    )


@register_sparsifier_implementation(GroupedNMSparsifier, DenseTensor,
                                    FixedMaskTensor)
def _dense_to_fixed_mask_grouped_nm(sp: GroupedNMSparsifier, x, key=None):
    """Masked-dense n:m:g — the training-time representation (paper §5.3)."""
    dense = x.to_dense() if isinstance(x, SparsityLayout) else jnp.asarray(x)
    mask = nmg.grouped_nm_mask(dense, sp.n, sp.m, sp.g, gr=sp.gr,
                               sparse_dim=sp.sparse_dim, method=sp.method)
    return FixedMaskTensor(dense * mask, mask.astype(jnp.bool_), origin=sp)

"""SLO-aware serving control loop: sparsity tiers, hysteresis ladder.

STen's thesis is that sparsity is a *pipeline* — layouts, operators and
sparsifiers composed freely — and the serving consequence is that
"how sparse are the weights" becomes a **runtime degradation axis**: the
same engine can trade a little accuracy for a lot of latency headroom by
swapping to a sparser pre-converted copy of its weights.  This module
closes ROADMAP item 5 around that idea:

* :class:`TierSpec` / :func:`build_tiers` — parse ``"dense"`` /
  ``"2:4"`` / ``"1:4:8-gr64"`` tier specs and pre-convert the model once
  per tier at warmup (through the ordinary
  :func:`~repro.serve.engine.sparsify_for_serving` builder pipeline).
  Because layouts are pytrees, each tier is just another params pytree:
  a tier switch is a pointer swap into an already-compiled decode
  program (one executable per param structure, warmed eagerly by
  ``ServeEngine.warm_tiers``), never a recompile.
* :class:`LatencyModel` — admission-time cost prediction from the active
  :class:`~repro.tune.table.TuningTable` (per-weight shape-bucket
  latency lookups via :func:`repro.tune.routing.matmul_latency_us`),
  refined online by EWMA over observed decode/prefill times.
* :class:`CadenceWatchdog` — the ``StragglerWatchdog`` leave-one-out
  median idiom from ``dist/elastic.py`` applied to *time*: windows of
  consecutive per-token decode times play the role of hosts, and the
  latest window is flagged when its median exceeds the median of the
  other retained windows by ``ratio`` — persistent cadence collapse,
  not one-token jitter.
* :class:`SLOController` — a dwell-time hysteresis state machine over
  the degradation ladder: (0) steady, (1) defer admissions + shrink the
  decode chunk, (2) drop to a sparser weight tier, (3) shed the
  lowest-priority queued requests (and only when there is a queue worth
  shedding).  Escalation needs ``escalate_dwell`` consecutive hot
  steps, de-escalation ``deescalate_dwell`` consecutive cool steps, and
  the band between the two thresholds holds the current level — so the
  controller cannot flap tiers on noise.

The controller is pure host-side Python consulted by ``ServeEngine``
between decode chunks; nothing here touches a traced program.
"""

from __future__ import annotations

import dataclasses
import statistics
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs import trace as obs
from repro.obs.registry import REGISTRY, MirroredCounters
from repro.tune import routing
from repro.tune.table import bucket

__all__ = ["TierSpec", "Tier", "build_tiers", "CadenceWatchdog",
           "SLOConfig", "LatencyModel", "SLOController"]


# ---------------------------------------------------------------------------
# sparsity tiers
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One rung of the weight-sparsity ladder.

    ``fmt`` is the ``(n, m, g)`` GroupedNM format (None = dense), ``gr``
    the row-sharing width.  Specs are ordered densest-first by the caller:
    tier 0 is what the engine serves when healthy."""

    name: str
    fmt: Optional[tuple] = None
    gr: int = 64

    @classmethod
    def parse(cls, spec: str) -> "TierSpec":
        """``"dense"`` | ``"n:m"`` | ``"n:m:g"``, optionally suffixed
        ``"-grNN"`` (row-sharing width, default 64).  ``g`` defaults to
        ``m`` (plain n:m, no intra-group permutation freedom)."""
        spec = spec.strip()
        if spec.lower() == "dense":
            return cls(name="dense")
        body, gr = spec, 64
        if "-gr" in spec:
            body, gr_s = spec.rsplit("-gr", 1)
            gr = int(gr_s)
        parts = [int(p) for p in body.split(":")]
        if len(parts) == 2:
            n, m = parts
            g = m
        elif len(parts) == 3:
            n, m, g = parts
        else:
            raise ValueError(f"unparseable tier spec {spec!r} "
                             f"(want 'dense', 'n:m' or 'n:m:g[-grNN]')")
        if not (1 <= n < m and g >= m):
            raise ValueError(f"tier spec {spec!r}: need 1 <= n < m <= g")
        return cls(name=f"{n}:{m}:{g}-gr{gr}", fmt=(n, m, g), gr=gr)

    @property
    def density(self) -> float:
        return 1.0 if self.fmt is None else self.fmt[0] / self.fmt[1]


@dataclasses.dataclass(frozen=True)
class Tier:
    """A resident weight copy: its spec plus the pre-converted params."""

    spec: TierSpec
    params: object


def build_tiers(params, specs: Sequence) -> list:
    """Pre-convert ``params`` once per spec (strings are parsed).  This is
    the warmup-time cost that buys recompile-free tier switches: every
    tier stays resident, so the controller's switch is a pytree pointer
    swap into that tier's already-compiled decode program."""
    from repro.serve.engine import sparsify_for_serving  # lazy: no cycle

    specs = [TierSpec.parse(s) if isinstance(s, str) else s for s in specs]
    if not specs:
        raise ValueError("at least one tier is required")
    if len({s.name for s in specs}) != len(specs):
        raise ValueError("duplicate tier specs")
    tiers = []
    for spec in specs:
        if spec.fmt is None:
            tiers.append(Tier(spec=spec, params=params))
        else:
            n, m, g = spec.fmt
            tiers.append(Tier(spec=spec, params=sparsify_for_serving(
                params, n, m, g, gr=spec.gr)))
    return tiers


# ---------------------------------------------------------------------------
# decode-cadence watchdog
# ---------------------------------------------------------------------------


class CadenceWatchdog:
    """Persistent decode-slowdown detector over per-token decode times.

    The :class:`~repro.dist.elastic.StragglerWatchdog` idiom transplanted
    from space to time: instead of per-host step-time medians compared
    leave-one-out across the fleet, windows of ``window`` consecutive
    per-token decode times are the "hosts", and :meth:`slow` flags the
    *latest* completed window when its median exceeds the median of the
    other retained windows by more than ``ratio`` — a sustained cadence
    collapse relative to this engine's own recent history, immune to
    single-token jitter (medians within windows) and to slow drift
    (the reference window set slides).  Silent until ``min_windows``
    windows completed, so warmup compile stalls cannot trip it."""

    def __init__(self, *, window: int = 8, n_windows: int = 8,
                 min_windows: int = 4, ratio: float = 2.0):
        assert window >= 1 and n_windows >= 2 and min_windows >= 2
        self.window = window
        self.min_windows = min_windows
        self.ratio = ratio
        self._cur: list = []
        self._meds: deque = deque(maxlen=n_windows)

    def observe(self, dt_s: float) -> None:
        """Record one per-token decode time."""
        self._cur.append(float(dt_s))
        if len(self._cur) >= self.window:
            self._meds.append(statistics.median(self._cur))
            self._cur = []

    def recent(self) -> float:
        """Median of the latest completed window (nan before the first)."""
        return self._meds[-1] if self._meds else float("nan")

    def slow(self) -> bool:
        if len(self._meds) < self.min_windows:
            return False
        latest = self._meds[-1]
        ref = statistics.median(list(self._meds)[:-1])
        return latest > self.ratio * ref


# ---------------------------------------------------------------------------
# latency prediction
# ---------------------------------------------------------------------------


class LatencyModel:
    """Admission-time latency prediction, table-seeded and EWMA-refined.

    Before the first decode step runs, predictions come from the active
    :class:`~repro.tune.table.TuningTable`: the model walks ``params`` for
    :class:`~repro.core.layouts.GroupedNMTensor` leaves at construction
    (scan-stacked ``layers`` leaves count ``cfg.n_layers`` times) and
    sums each weight's measured per-matmul latency at the requested width
    (:func:`repro.tune.routing.matmul_latency_us`, recorded by
    ``tune_decode_threshold`` from the same sweep that sets the
    gemv/spmm crossover).  That sum covers only the routed sparse
    matmuls — a floor, not the full step — so once the engine is serving,
    EWMA over *observed* step/prefill times takes over and the table is
    only the cold-start seed."""

    def __init__(self, params, cfg, *, max_slots: int, alpha: float = 0.25):
        from repro.core.layouts import GroupedNMTensor
        from repro.kernels import ops as kops

        self.max_slots = int(max_slots)
        self.alpha = float(alpha)
        dt = jnp.dtype(cfg.dtype)
        n_layers = int(getattr(cfg, "n_layers", 1))
        self._weights: list = []   # (route ctx, multiplicity)
        for path, leaf in jax.tree_util.tree_leaves_with_path(
                params, is_leaf=lambda x: isinstance(x, GroupedNMTensor)):
            if not isinstance(leaf, GroupedNMTensor):
                continue
            mult = n_layers if "layers" in jax.tree_util.keystr(path) else 1
            self._weights.append((kops._route_ctx(leaf, dt), mult))
        self._step_ewma: Optional[float] = None
        self._prefill_ewma: dict = {}   # bucket(plen) -> seconds

    # -- table-seeded prediction ------------------------------------------
    def table_step_s(self, M: int) -> Optional[float]:
        """Summed measured latency (seconds) of every routed sparse matmul
        at width ``M``, or None when the active table lacks any of the
        needed buckets (dense params have no routed matmuls: None too)."""
        if not self._weights:
            return None
        total_us = 0.0
        for ctx, mult in self._weights:
            us, _src = routing.matmul_latency_us(M=M, **ctx)
            if us is None:
                return None
            total_us += us * mult
        return total_us * 1e-6

    # -- online refinement -------------------------------------------------
    def _ewma(self, old: Optional[float], x: float) -> float:
        return x if old is None else (1 - self.alpha) * old + self.alpha * x

    def observe_step(self, dt_s: float, n_steps: int = 1) -> None:
        """Record a decode call that advanced every stream ``n_steps``
        tokens in ``dt_s`` seconds (per-step time is the stream TPOT:
        the batch is static, one token per stream per step)."""
        if n_steps > 0 and dt_s >= 0:
            self._step_ewma = self._ewma(self._step_ewma, dt_s / n_steps)

    def observe_prefill(self, plen: int, dt_s: float) -> None:
        b = bucket(plen)
        self._prefill_ewma[b] = self._ewma(self._prefill_ewma.get(b), dt_s)

    # -- estimates ---------------------------------------------------------
    def tpot_s(self) -> float:
        """Current per-token decode-time estimate: observed EWMA, else the
        table prediction at the engine's decode width, else nan."""
        if self._step_ewma is not None:
            return self._step_ewma
        t = self.table_step_s(self.max_slots)
        return float("nan") if t is None else t

    def prefill_s(self, plen: int) -> float:
        hit = self._prefill_ewma.get(bucket(plen))
        if hit is not None:
            return hit
        t = self.table_step_s(plen)
        return float("nan") if t is None else t

    def request_s(self, plen: int, gen_len: int) -> float:
        """Admission-to-finish estimate for a request: prefill plus
        ``gen_len`` decode steps (nan when nothing is known yet — the
        engine then admits rather than guessing)."""
        return self.prefill_s(plen) + gen_len * self.tpot_s()


# ---------------------------------------------------------------------------
# the hysteresis controller
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Service-level objective plus the control loop's hysteresis knobs.

    The controller compares its TPOT estimate against
    ``escalate_frac * tpot_ms`` (hot) and ``deescalate_frac * tpot_ms``
    (cool); the band between holds the current level.  ``*_dwell`` are
    consecutive-step counts a signal must persist before the level moves,
    with de-escalation deliberately much slower than escalation so a
    recovering engine does not oscillate back into overload."""

    tpot_ms: float = 50.0
    ttft_ms: Optional[float] = None
    escalate_frac: float = 0.9
    deescalate_frac: float = 0.6
    escalate_dwell: int = 2
    deescalate_dwell: int = 12
    #: level >= 1 shrinks the decode chunk by this divisor (shorter chunks
    #: = more frequent admission/control points, bounded chunk tail latency)
    chunk_shrink: int = 2
    #: shedding keeps at most this many queued requests per slot...
    queue_keep_per_slot: float = 2.0
    #: ...and a queue deeper than this many per slot is itself a hot signal
    queue_high_per_slot: float = 4.0
    # cadence-watchdog knobs (see CadenceWatchdog)
    watchdog_window: int = 8
    watchdog_n_windows: int = 8
    watchdog_min_windows: int = 4
    watchdog_ratio: float = 2.0


class SLOController:
    """Dwell-time hysteresis over the degradation ladder.

    Levels: 0 steady · 1 defer admissions + shrink decode chunk · 2 drop
    to a sparser weight tier · 3 shed lowest-priority queued requests.
    The engine consults :meth:`begin_step` once per scheduler iteration
    and reads the level back through :attr:`tier_index`,
    :meth:`admission_budget`, :meth:`decode_chunk`, :meth:`should_shed`.
    """

    def __init__(self, cfg: SLOConfig, *, n_tiers: int, max_slots: int,
                 latency: Optional[LatencyModel] = None):
        self.cfg = cfg
        self.n_tiers = max(1, int(n_tiers))
        self.max_slots = int(max_slots)
        self.latency = latency
        self.watchdog = CadenceWatchdog(
            window=cfg.watchdog_window, n_windows=cfg.watchdog_n_windows,
            min_windows=cfg.watchdog_min_windows, ratio=cfg.watchdog_ratio)
        self.level = 0
        self._hot = 0
        self._cool = 0
        #: why the controller last moved the ladder — the engine forwards
        #: this as the tier-switch reason attribute on the timeline
        self.last_reason = "steady"
        self.counters = MirroredCounters(
            {"escalations": 0, "deescalations": 0,
             "hot_steps": 0, "watchdog_trips": 0},
            REGISTRY.family("slo", help="SLO controller decisions"))

    # -- thresholds --------------------------------------------------------
    def shed_keep(self) -> int:
        return max(1, int(self.cfg.queue_keep_per_slot * self.max_slots))

    def queue_high(self) -> int:
        return max(1, int(self.cfg.queue_high_per_slot * self.max_slots))

    # -- signals in, level out --------------------------------------------
    def observe_decode(self, dt_s: float, n_steps: int) -> None:
        """Feed one decode call (``n_steps`` tokens per stream in
        ``dt_s``) into the watchdog and the latency model."""
        if n_steps <= 0:
            return
        per_tok = dt_s / n_steps
        for _ in range(n_steps):
            self.watchdog.observe(per_tok)
        if self.latency is not None:
            self.latency.observe_step(dt_s, n_steps)

    def begin_step(self, now: float, queue_depth: int) -> int:
        """Advance the hysteresis state machine; returns the level.

        Hot = TPOT estimate above ``escalate_frac`` of the SLO, or the
        cadence watchdog tripping, or the queue past ``queue_high``.
        Cool = TPOT comfortably below ``deescalate_frac`` of the SLO (or
        unknown), watchdog quiet, queue drained to the keep level.
        Anything between holds the level (the hysteresis band).
        Escalating into shedding (level 3) additionally requires a queue
        deeper than the keep target — shedding an empty queue buys
        nothing."""
        tpot = self.latency.tpot_s() if self.latency is not None \
            else float("nan")
        slo_s = self.cfg.tpot_ms * 1e-3
        wd = self.watchdog.slow()
        if wd:
            self.counters["watchdog_trips"] += 1
            obs.event("watchdog_trip", "controller", level=self.level,
                      queue_depth=queue_depth)
        hot = (wd or queue_depth > self.queue_high()
               or (tpot == tpot and tpot > self.cfg.escalate_frac * slo_s))
        cool = ((tpot != tpot or tpot < self.cfg.deescalate_frac * slo_s)
                and not wd and queue_depth <= self.shed_keep())
        if hot:
            self.counters["hot_steps"] += 1
            self._hot += 1
            self._cool = 0
            if self._hot >= self.cfg.escalate_dwell and self.level < 3:
                if self.level < 2 or queue_depth > self.shed_keep():
                    self.level += 1
                    self._hot = 0
                    self.counters["escalations"] += 1
                    # which hot signal drove the move, most-specific first
                    self.last_reason = (
                        "watchdog" if wd
                        else "queue_depth" if queue_depth > self.queue_high()
                        else "tpot")
                    obs.event("escalate", "controller",
                              level_from=self.level - 1, level_to=self.level,
                              reason=self.last_reason,
                              queue_depth=queue_depth,
                              tpot_ms=(round(tpot * 1e3, 3)
                                       if tpot == tpot else None))
        elif cool:
            self._cool += 1
            self._hot = 0
            if self._cool >= self.cfg.deescalate_dwell and self.level > 0:
                self.level -= 1
                self._cool = 0
                self.counters["deescalations"] += 1
                self.last_reason = "recovered"
                obs.event("deescalate", "controller",
                          level_from=self.level + 1, level_to=self.level,
                          reason="recovered", queue_depth=queue_depth)
        else:
            self._hot = 0
            self._cool = 0
        return self.level

    # -- what the engine does about it ------------------------------------
    @property
    def tier_index(self) -> int:
        """Which resident weight tier to serve from: tier 0 below level 2,
        one rung sparser per level past that (clamped to the ladder)."""
        if self.level < 2:
            return 0
        return min(self.level - 1, self.n_tiers - 1)

    def admission_budget(self, free_slots: int) -> int:
        """Max admissions this step: all free slots when steady, one per
        step once deferring — admission prefills are the stall the
        degraded engine is rationing."""
        return free_slots if self.level == 0 else min(free_slots, 1)

    def decode_chunk(self, base: int) -> int:
        return base if self.level == 0 else \
            max(1, base // max(1, self.cfg.chunk_shrink))

    def should_shed(self, queue_depth: int) -> bool:
        return self.level >= 3 and queue_depth > self.shed_keep()

"""Continuous-batching serving engine for the (sparse) LM stack.

The engine holds a static-shape batch of ``max_slots`` sequences — shapes
never change, so XLA compiles the decode step exactly once.  Between decode
steps it *admits* queued requests into free slots (prefill writes the
request's K/V straight into its slot via ``prefill_into_slot``) and every
decode step advances all occupied slots at their own positions (the
per-slot position vector threaded through ``decode_step`` /
``decode_attention``).  Finished slots are freed immediately and the next
admission overwrites them — the paper's sparse-serving scenario (Fig 11)
run as a service rather than a one-shot batch.

Decoding is *chunked*: when every active request is greedy, the engine
runs ``decode_chunk`` steps in one jitted ``lax.scan`` with on-device
argmax sampling and fetches the whole token block in a single host sync
(the serving analogue of the trainer's ``make_multi_step``), instead of
blocking on the device once per token.  Requests with non-greedy sampling
fall back to the per-token loop so their host-side RNG streams stay
reproducible and batch-independent.

The sparse path is the point: ``sparsify_for_serving`` converts FFN
weights to :class:`GroupedNMTensor` through the ordinary
:class:`SparsityBuilder`, and because layouts are pytrees the engine's
jitted prefill/decode accept dense and n:m:g params interchangeably.
``compare_dense_sparse`` serves the same trace under both and reports the
numbers side by side.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import SparsityBuilder
from repro.core.layouts import GroupedNMTensor
from repro.core.sparsifiers import GroupedNMSparsifier
from repro.models import decode_step
from repro.models.common import ModelConfig
from repro.serve.cache import SlotKVCache
from repro.serve.metrics import ServeMetrics, summarize
from repro.serve.queue import Request, RequestOutput, RequestQueue, \
    sample_token

__all__ = ["ServeEngine", "sparsify_for_serving", "compare_dense_sparse",
           "warmup_engine"]


#: bound on the per-config jitted-closure caches below.  Each entry pins a
#: jitted callable whose own executable cache grows per traced
#: (param-structure, shape) — in a long-running engine serving many model
#: configs that accumulates without limit, so unlike the read-only pattern
#: tables in ``core/layouts.py`` (tiny numpy constants, safe to keep
#: forever) these caches are LRU-bounded; eviction only costs a recompile
#: if a config comes back.
_JIT_CACHE_SIZE = 16

#: default slot-batch size — single source for ``ServeEngine.__init__``
#: and the warmup tuner's decode-width fallback, which must agree on the
#: width a default-constructed engine actually decodes at
DEFAULT_MAX_SLOTS = 8


@functools.lru_cache(maxsize=_JIT_CACHE_SIZE)
def _jit_decode(cfg: ModelConfig):
    """One jitted decode step per config (ModelConfig is frozen/hashable),
    shared across engine instances so a dense-vs-sparse comparison only
    compiles each (config, param-structure) once.  The cache operand is
    donated — the hot path updates the KV pool in place every token
    instead of copying it."""
    return jax.jit(
        lambda p, tok, cache, pos: decode_step(p, cfg, tok, cache, pos),
        donate_argnums=(2,),
    )


@functools.lru_cache(maxsize=2 * _JIT_CACHE_SIZE)  # keyed (cfg, n_steps)
def _jit_decode_chunk(cfg: ModelConfig, n_steps: int):
    """Jitted multi-token inner decode loop (the serving analogue of
    ``launch/train.py:make_multi_step``): ``n_steps`` decode steps under one
    ``lax.scan`` with on-device greedy sampling, so the host syncs once per
    chunk instead of once per token.  Returns the [n_steps, max_slots]
    token matrix (the single chunked host fetch) plus the updated cache."""

    def chunk(p, tok, cache, pos):
        def body(carry, _):
            tok, cache, pos = carry
            logits, cache = decode_step(p, cfg, tok, cache, pos)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)   # [B] on device
            return (nxt[:, None], cache, pos + 1), nxt

        (_, cache, _), toks = jax.lax.scan(
            body, (tok, cache, pos), None, length=n_steps
        )
        return toks, cache

    return jax.jit(chunk, donate_argnums=(2,))


def sparsify_for_serving(params, n: int = 1, m: int = 4, g: int = 16,
                         gr: int = 64):
    """Convert FFN weights to the n:m:g inference layout (paper §5.3:
    'our sparse-dense GEMM kernel during inference').

    ``gr`` shares each chunk permutation across ``gr`` consecutive output
    fibers (the row-sharing format adaptation).  For serving it defaults
    to 64: the decode GEMV and prefill SpMM kernels amortize their B-row
    gathers across the shared rows and contract them as one dense tile,
    which is what makes the sparse path *faster* than dense rather than
    gather-bound (gr=1, the paper's per-fiber CPU format, keeps maximal
    energy but pays one gather per stored value per call)."""
    sb = SparsityBuilder()
    sp = GroupedNMSparsifier(n, m, g, gr, sparse_dim=0)  # [K, N] weights
    sb.set_weight("*mlp.wi", sp, GroupedNMTensor)
    sb.set_weight("*mlp.wo", sp, GroupedNMTensor)
    return sb.sparsify_params(params)


@dataclasses.dataclass
class _SlotState:
    """Host-side bookkeeping for one occupied slot."""

    req: Request
    tokens: list
    token_times: list
    admitted_time: float
    rng: np.random.Generator
    max_new: int  # request budget clamped to the slot's cache capacity


class ServeEngine:
    """Slot-based continuous-batching engine.

    Parameters
    ----------
    params : dense or sparse (layout-bearing) model params pytree
    cfg : model config
    max_slots : batch size of the static decode step
    max_seq_len : per-slot KV capacity (prompt + generation)
    reset_freed_slots : zero a slot's cache when its request finishes.
        Admission overwrites whatever a slot holds and decode masks each
        slot to its own prefix, so this is off by default; tests use it to
        prove slot isolation.
    decode_chunk : decode steps per jit call between admissions.  When every
        active request decodes greedily, the engine runs ``decode_chunk``
        steps device-resident (``lax.scan`` with on-device sampling) and
        fetches the whole token block in one host sync; tokens past a stop
        condition are discarded host-side.  1 restores the per-token
        reference loop; any non-greedy active request also falls back to it
        (host-side RNG sampling keeps per-request streams batch-independent).
    clock : timestamp source (injectable for deterministic tests)
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 max_slots: int = DEFAULT_MAX_SLOTS,
                 max_seq_len: int = 256, reset_freed_slots: bool = False,
                 decode_chunk: int = 8,
                 clock: Callable[[], float] = time.perf_counter):
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.reset_freed_slots = reset_freed_slots
        self.decode_chunk = max(1, decode_chunk)
        self.kv = SlotKVCache(cfg, max_slots, max_seq_len)
        self.queue = RequestQueue()
        self._decode = _jit_decode(cfg)
        self._decode_chunk = (
            _jit_decode_chunk(cfg, self.decode_chunk)
            if self.decode_chunk > 1 else None
        )
        self._slots: list[Optional[_SlotState]] = [None] * max_slots
        # next cache write position per slot == current valid length
        self._pos = np.zeros(max_slots, np.int32)
        self._tok = np.zeros(max_slots, np.int32)  # last sampled token
        self._outputs: list[RequestOutput] = []
        self._clock = clock
        self._t0: Optional[float] = None

    # -- introspection ----------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    def free_slots(self) -> list:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    # -- request lifecycle ------------------------------------------------
    def submit(self, req: Request) -> None:
        assert req.prompt.size <= self.max_seq_len, (
            f"prompt ({req.prompt.size}) exceeds max_seq_len "
            f"({self.max_seq_len})"
        )
        self.queue.push(req)

    def _admit(self, slot: int, req: Request, now: float) -> None:
        """Prefill ``req`` into ``slot`` and sample its first token."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits = self.kv.write_prefill(self.params, prompt, slot)
        S = int(req.prompt.size)
        # token i (1-based) is written to the cache at position S + i - 1,
        # so generating N tokens needs S + N - 1 <= max_seq_len
        max_new = min(req.max_new_tokens, self.max_seq_len - S + 1)
        st = _SlotState(
            req=req, tokens=[], token_times=[], admitted_time=now,
            rng=np.random.default_rng(req.sampling.seed), max_new=max_new,
        )
        tok = sample_token(np.asarray(logits[0]), req.sampling, st.rng)
        st.tokens.append(tok)
        st.token_times.append(self._now())
        self._slots[slot] = st
        self._pos[slot] = S
        self._tok[slot] = tok
        if self._stopped(st, tok):
            self._finish(slot)

    def _stopped(self, st: _SlotState, tok: int) -> bool:
        return tok in st.req.stop_tokens or len(st.tokens) >= st.max_new

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        reason = "stop" if st.tokens[-1] in st.req.stop_tokens else "length"
        self._outputs.append(RequestOutput(
            uid=st.req.uid,
            prompt_len=int(st.req.prompt.size),
            tokens=list(st.tokens),
            finish_reason=reason,
            arrival_time=st.req.arrival_time,
            admitted_time=st.admitted_time,
            finish_time=self._now(),
            token_times=list(st.token_times),
        ))
        self._slots[slot] = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        if self.reset_freed_slots:
            self.kv.reset(slot)

    # -- the engine loop --------------------------------------------------
    def step(self) -> int:
        """One scheduler iteration: admit ready requests into free slots,
        then run one decode *chunk* over the batch (``decode_chunk`` steps
        device-resident when every active request is greedy, one host-paced
        step otherwise).  Returns the number of tokens produced (0 when the
        engine idled)."""
        now = self._now()
        produced = 0
        for slot in self.free_slots():
            req = self.queue.pop_ready(now)
            if req is None:
                break
            self._admit(slot, req, now)
            produced += 1  # the first token sampled from prefill logits
        active = [i for i, s in enumerate(self._slots) if s is not None]
        if not active:
            return produced
        if self._decode_chunk is not None and all(
            self._slots[s].req.sampling.greedy for s in active
        ):
            return produced + self._step_chunked(active)
        return produced + self._step_single(active)

    def _step_single(self, active) -> int:
        """Per-token reference path: one decode step, host-side sampling."""
        produced = 0
        tok = jnp.asarray(self._tok[:, None])
        pos = jnp.asarray(self._pos)
        logits, self.kv.data = self._decode(self.params, tok, self.kv.data,
                                            pos)
        logits_np = np.asarray(logits)
        t = self._now()
        for slot in active:
            st = self._slots[slot]
            nxt = sample_token(logits_np[slot], st.req.sampling, st.rng)
            st.tokens.append(nxt)
            st.token_times.append(t)
            self._pos[slot] += 1
            self._tok[slot] = nxt
            produced += 1
            if self._stopped(st, nxt):
                self._finish(slot)
        return produced

    def _step_chunked(self, active) -> int:
        """Greedy fast path: ``decode_chunk`` steps in one jit call with
        on-device argmax sampling, then a single chunked host fetch.

        The device loop always runs the full fixed-length chunk (one
        compiled program, no per-remaining-budget recompiles); tokens a
        request produced past its stop token or budget are simply discarded
        on the host.  Overshoot cache writes land in positions of slots
        that are about to be freed and are either overwritten by the next
        occupant's prefill/decode writes or masked out by the per-slot
        valid-prefix attention mask, so they are never read.  Per-token
        timestamps spread the measured chunk latency uniformly across the
        chunk's tokens (the stream's average decode cadence)."""
        produced = 0
        T = self.decode_chunk
        t0 = self._now()
        toks, self.kv.data = self._decode_chunk(
            self.params, jnp.asarray(self._tok[:, None]), self.kv.data,
            jnp.asarray(self._pos),
        )
        toks_np = np.asarray(toks)  # [T, max_slots] — one host sync
        t1 = self._now()
        for slot in active:
            st = self._slots[slot]
            for t in range(T):
                nxt = int(toks_np[t, slot])
                st.tokens.append(nxt)
                st.token_times.append(t0 + (t + 1) * (t1 - t0) / T)
                self._pos[slot] += 1
                self._tok[slot] = nxt
                produced += 1
                if self._stopped(st, nxt):
                    self._finish(slot)
                    break
        return produced

    def run(self, requests: Iterable[Request] = (),
            max_steps: int = 1_000_000) -> list:
        """Serve until the queue drains and every slot finishes.  Returns
        the :class:`RequestOutput`s finished *during this call* in uid
        order.  The engine keeps one wall-clock epoch across repeated
        ``run()``/``step()`` calls, so ``metrics()`` aggregates the full
        lifetime consistently (arrival_times are relative to the first
        call)."""
        for req in requests:
            self.submit(req)
        if self._t0 is None:
            self._t0 = self._clock()
        first_new = len(self._outputs)
        steps = 0
        while (len(self.queue) or self.num_active) and steps < max_steps:
            before = self.num_active
            self.step()
            steps += 1
            if not before and not self.num_active and len(self.queue):
                # everything idle but traffic still due: wait for it in
                # short sleeps while the clock advances; if an injected
                # clock does not self-advance (e.g. a frozen test clock),
                # warp virtual time to the arrival so the loop always
                # makes progress
                nxt = self.queue.next_arrival()
                while nxt is not None:
                    remaining = nxt - self._now()
                    if remaining <= 0:
                        break
                    t_before = self._clock()
                    time.sleep(min(remaining, 0.05))
                    if self._clock() <= t_before:
                        self._t0 -= remaining
                        break
        return sorted(self._outputs[first_new:], key=lambda o: o.uid)

    def metrics(self, *, label: str = "serve") -> ServeMetrics:
        wall = self._now() if self._t0 is not None else 0.0
        return summarize(self._outputs, wall, label=label)


def warmup_engine(params, cfg: ModelConfig, requests, *,
                  engine_kwargs: Optional[dict] = None,
                  tune: bool = False, tune_reps: int = 3) -> None:
    """Populate the jit caches (one slot-prefill per distinct prompt
    length + the decode step, for this param structure) by serving a tiny
    trace through a throwaway engine, so a measured run reports
    steady-state latency instead of compile stalls.

    With ``tune=True`` the warmup first autotunes the kernel routing for
    the *actual* shapes this engine will serve — each sparse weight's
    gemv/spmm crossover at the engine's decode width (``max_slots``) and
    the trace's prompt lengths — and activates the resulting
    :class:`~repro.tune.table.TuningTable` (merging into any already
    active), so the compilations this warmup triggers, and every
    subsequent engine trace, route through measured decisions instead of
    the shipped defaults.  Tuning must precede compilation because routing
    lookups happen at trace time; that ordering is the point of hanging
    the hook here."""
    ekw = dict(engine_kwargs or {})
    requests = list(requests)
    if tune and any(
        isinstance(leaf, GroupedNMTensor)
        for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, GroupedNMTensor))
    ):
        from repro.tune.bench import autotune_for_serving

        autotune_for_serving(
            params,
            max_slots=ekw.get("max_slots", DEFAULT_MAX_SLOTS),
            prompt_lens=sorted({int(r.prompt.size) for r in requests}) or [8],
            dtype=jnp.dtype(cfg.dtype),
            reps=tune_reps,
        )
    seen, warm = set(), []
    for r in requests:
        if r.prompt.size not in seen:
            seen.add(r.prompt.size)
            warm.append(Request(uid=-1 - len(warm), prompt=r.prompt,
                                max_new_tokens=2))
    ServeEngine(params, cfg, **ekw).run(warm)


def compare_dense_sparse(params, cfg: ModelConfig, requests, *,
                         nm: tuple = (1, 4, 16), gr: int = 64,
                         engine_kwargs: Optional[dict] = None,
                         warmup: bool = False, tune: bool = False):
    """Serve the same request trace with dense and n:m:g-sparse weights.

    Returns {'dense': (outputs, metrics), 'sparse': (outputs, metrics)} —
    the side-by-side numbers of the paper's Fig 11 serving scenario.
    ``warmup`` pre-compiles both variants so the metrics measure serving,
    not XLA compilation; ``tune`` additionally autotunes the sparse
    variant's kernel routing for the served shapes during its warmup (see
    :func:`warmup_engine`; the hook no-ops for the dense variant, which
    has no routed sparse weights)."""
    engine_kwargs = dict(engine_kwargs or {})
    requests = list(requests)
    results = {}
    for label, p in (
        ("dense", params),
        ("sparse", sparsify_for_serving(params, *nm, gr=gr)),
    ):
        if warmup:
            warmup_engine(p, cfg, requests, engine_kwargs=engine_kwargs,
                          tune=tune)
        eng = ServeEngine(p, cfg, **engine_kwargs)
        outs = eng.run(requests)
        results[label] = (outs, eng.metrics(label=label))
    return results

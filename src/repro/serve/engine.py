"""Continuous-batching serving engine for the (sparse) LM stack.

The engine holds a static-shape batch of ``max_slots`` sequences — shapes
never change, so XLA compiles the decode step exactly once.  Between decode
steps it *admits* queued requests into free slots (prefill writes the
request's K/V straight into its slot via ``prefill_into_slot``) and every
decode step advances all occupied slots at their own positions (the
per-slot position vector threaded through ``decode_step`` /
``decode_attention``).  Finished slots are freed immediately and the next
admission overwrites them — the paper's sparse-serving scenario (Fig 11)
run as a service rather than a one-shot batch.

Decoding is *chunked*: when every active request is greedy, the engine
runs ``decode_chunk`` steps in one jitted ``lax.scan`` with on-device
argmax sampling and fetches the whole token block in a single host sync
(the serving analogue of the trainer's ``make_multi_step``), instead of
blocking on the device once per token.  Requests with non-greedy sampling
fall back to the per-token loop so their host-side RNG streams stay
reproducible and batch-independent.

The sparse path is the point: ``sparsify_for_serving`` converts FFN
weights to :class:`GroupedNMTensor` through the ordinary
:class:`SparsityBuilder`, and because layouts are pytrees the engine's
jitted prefill/decode accept dense and n:m:g params interchangeably.
``compare_dense_sparse`` serves the same trace under both and reports the
numbers side by side.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.builder import SparsityBuilder
from repro.core.layouts import GroupedNMTensor
from repro.core.sparsifiers import GroupedNMSparsifier
from repro.models import decode_step, init_cache, prefill
from repro.models.common import ModelConfig
from repro.obs import trace as obs
from repro.obs.registry import REGISTRY, MirroredCounters
from repro.serve.cache import PagedKVCache, PromptTooLongError, \
    SlotKVCache, paged_commit, paged_view
from repro.serve.errors import EngineOverloadError, InjectedFaultError, \
    ServeError
from repro.serve.faults import FaultInjector
from repro.serve.metrics import ServeMetrics, summarize
from repro.serve.queue import Request, RequestOutput, RequestQueue, \
    sample_token
from repro.serve.slo import LatencyModel, SLOConfig, SLOController, \
    build_tiers
from repro.serve.tracecount import note_trace

__all__ = ["ServeEngine", "sparsify_for_serving", "compare_dense_sparse",
           "warmup_engine", "serve_programs"]


#: bound on the per-config jitted-closure caches below.  Each entry pins a
#: jitted callable whose own executable cache grows per traced
#: (param-structure, shape) — in a long-running engine serving many model
#: configs that accumulates without limit, so unlike the read-only pattern
#: tables in ``core/layouts.py`` (tiny numpy constants, safe to keep
#: forever) these caches are LRU-bounded; eviction only costs a recompile
#: if a config comes back.
_JIT_CACHE_SIZE = 16

#: default slot-batch size — single source for ``ServeEngine.__init__``
#: and the warmup tuner's decode-width fallback, which must agree on the
#: width a default-constructed engine actually decodes at
DEFAULT_MAX_SLOTS = 8


def _decode_fn(cfg: ModelConfig):
    """The raw (unjitted) per-token decode callable the engine compiles.
    Split out of :func:`_jit_decode` so ``repro.check`` can trace the
    *identical* program the runtime jits."""

    def step(p, tok, cache, pos):
        note_trace("decode")  # trace-time only: counts compilations
        return decode_step(p, cfg, tok, cache, pos)

    return step


def _decode_chunk_fn(cfg: ModelConfig, n_steps: int):
    """The raw chunked decode loop body (see :func:`_jit_decode_chunk`),
    split out for the same reason as :func:`_decode_fn`."""

    def chunk(p, tok, cache, pos):
        note_trace("decode_chunk")  # trace-time only: counts compilations

        def body(carry, _):
            tok, cache, pos = carry
            logits, cache = decode_step(p, cfg, tok, cache, pos)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)   # [B] on device
            return (nxt[:, None], cache, pos + 1), nxt

        (_, cache, _), toks = jax.lax.scan(
            body, (tok, cache, pos), None, length=n_steps
        )
        return toks, cache

    return chunk


@functools.lru_cache(maxsize=_JIT_CACHE_SIZE)
def _jit_decode(cfg: ModelConfig):
    """One jitted decode step per config (ModelConfig is frozen/hashable),
    shared across engine instances so a dense-vs-sparse comparison only
    compiles each (config, param-structure) once.  The cache operand is
    donated — the hot path updates the KV pool in place every token
    instead of copying it."""
    return jax.jit(_decode_fn(cfg), donate_argnums=(2,))


@functools.lru_cache(maxsize=2 * _JIT_CACHE_SIZE)  # keyed (cfg, n_steps)
def _jit_decode_chunk(cfg: ModelConfig, n_steps: int):
    """Jitted multi-token inner decode loop (the serving analogue of
    ``launch/train.py:make_multi_step``): ``n_steps`` decode steps under one
    ``lax.scan`` with on-device greedy sampling, so the host syncs once per
    chunk instead of once per token.  Returns the [n_steps, max_slots]
    token matrix (the single chunked host fetch) plus the updated cache."""
    return jax.jit(_decode_chunk_fn(cfg, n_steps), donate_argnums=(2,))


def serve_programs(params, cfg: ModelConfig, *, max_slots: int = 4,
                   max_seq_len: int = 64, decode_chunk: int = 4,
                   prompt_len: int = 8) -> dict:
    """The engine's compiled surface as ``{name: (fn, example_args)}`` —
    the exact callables :func:`_jit_decode` / :func:`_jit_decode_chunk` /
    the admission prefill jit, with example arguments shaped the way a
    running engine shapes them.  ``repro.check`` traces these, so a
    diagnostic on a ``serve:*`` program is a diagnostic on the real
    serving fast path, not on a checker-only approximation."""
    tok = jnp.zeros((max_slots, 1), jnp.int32)
    cache = init_cache(cfg, max_slots, max_seq_len)
    pos = jnp.full((max_slots,), prompt_len, jnp.int32)
    progs = {
        "decode": (_decode_fn(cfg), (params, tok, cache, pos)),
        "prefill": (
            lambda p, toks: prefill(p, cfg, toks, cache_len=max_seq_len),
            (params, jnp.zeros((1, prompt_len), jnp.int32)),
        ),
    }
    if decode_chunk > 1:
        progs["decode_chunk"] = (
            _decode_chunk_fn(cfg, decode_chunk), (params, tok, cache, pos),
        )
    return progs


@functools.lru_cache(maxsize=_JIT_CACHE_SIZE)
def _jit_paged_decode(cfg: ModelConfig, page_size: int, num_pages: int):
    """Paged analogue of :func:`_jit_decode`: gather the slot-major
    logical cache out of the page pool through the table, run the
    *unchanged* ``decode_step`` on it, and commit only the one written
    token row per slot back to its physical page.  The pool is donated —
    the gather/commit pair updates it in place."""

    def step(p, tok, pool, table, pos):
        note_trace("paged_decode")  # trace-time only: counts compilations
        view = paged_view(cfg, pool, table, page_size)
        logits, view = decode_step(p, cfg, tok, view, pos)
        pool = paged_commit(cfg, pool, view, table, pos, 1, page_size,
                            num_pages)
        return logits, pool

    return jax.jit(step, donate_argnums=(2,))


@functools.lru_cache(maxsize=2 * _JIT_CACHE_SIZE)
def _jit_paged_decode_chunk(cfg: ModelConfig, page_size: int,
                            num_pages: int, n_steps: int):
    """Paged analogue of :func:`_jit_decode_chunk`: one gather, ``n_steps``
    decode steps over the slot-major view under ``lax.scan`` (the exact
    loop the slot cache runs, so greedy tokens match it bitwise), then one
    commit of the ``n_steps`` written rows per slot.  The engine
    guarantees (via ``ensure_writable_range``) that every mapped page in
    the write range is private before this runs; unmapped/overshoot
    destinations resolve to the sentinel page and are dropped."""

    def chunk(p, tok, pool, table, pos):
        note_trace("paged_decode_chunk")  # trace-time: counts compilations
        view = paged_view(cfg, pool, table, page_size)

        def body(carry, _):
            tok, view, pv = carry
            logits, view = decode_step(p, cfg, tok, view, pv)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            return (nxt[:, None], view, pv + 1), nxt

        (_, view, _), toks = jax.lax.scan(
            body, (tok, view, pos), None, length=n_steps
        )
        pool = paged_commit(cfg, pool, view, table, pos, n_steps,
                            page_size, num_pages)
        return toks, pool

    return jax.jit(chunk, donate_argnums=(2,))


def sparsify_for_serving(params, n: int = 1, m: int = 4, g: int = 16,
                         gr: int = 64, *, attn: bool = False):
    """Convert FFN weights to the n:m:g inference layout (paper §5.3:
    'our sparse-dense GEMM kernel during inference').

    ``gr`` shares each chunk permutation across ``gr`` consecutive output
    fibers (the row-sharing format adaptation).  For serving it defaults
    to 64: the decode GEMV and prefill SpMM kernels amortize their B-row
    gathers across the shared rows and contract them as one dense tile,
    which is what makes the sparse path *faster* than dense rather than
    gather-bound (gr=1, the paper's per-fiber CPU format, keeps maximal
    energy but pays one gather per stored value per call).

    ``attn=True`` additionally sparsifies the attention projections
    (wq/wk/wv/wo).  q/k/v then share one format over the same contraction
    axis, so the decode step routes them through the fused QKV megakernel
    (one launch per step instead of three — ``kernels/nmg_fused.py``);
    the packed gated-MLP ``wi`` likewise takes the fused projection+gate
    launch."""
    sb = SparsityBuilder()
    sp = GroupedNMSparsifier(n, m, g, gr, sparse_dim=0)  # [K, N] weights
    sb.set_weight("*mlp.wi", sp, GroupedNMTensor)
    sb.set_weight("*mlp.wo", sp, GroupedNMTensor)
    if attn:
        for name in ("*attn.wq", "*attn.wk", "*attn.wv", "*attn.wo"):
            sb.set_weight(name, sp, GroupedNMTensor)
    return sb.sparsify_params(params)


@dataclasses.dataclass
class _SlotState:
    """Host-side bookkeeping for one occupied slot."""

    req: Request
    tokens: list
    token_times: list
    admitted_time: float
    rng: np.random.Generator
    max_new: int  # request budget clamped to the slot's cache capacity


class ServeEngine:
    """Slot-based continuous-batching engine.

    Parameters
    ----------
    params : dense or sparse (layout-bearing) model params pytree
    cfg : model config
    max_slots : batch size of the static decode step
    max_seq_len : per-slot KV capacity (prompt + generation)
    reset_freed_slots : zero a slot's cache when its request finishes.
        Admission overwrites whatever a slot holds and decode masks each
        slot to its own prefix, so this is off by default; tests use it to
        prove slot isolation.
    decode_chunk : decode steps per jit call between admissions.  When every
        active request decodes greedily, the engine runs ``decode_chunk``
        steps device-resident (``lax.scan`` with on-device sampling) and
        fetches the whole token block in one host sync; tokens past a stop
        condition are discarded host-side.  1 restores the per-token
        reference loop; any non-greedy active request also falls back to it
        (host-side RNG sampling keeps per-request streams batch-independent).
    clock : timestamp source (injectable for deterministic tests)
    paged : back the KV cache with :class:`PagedKVCache` instead of
        :class:`SlotKVCache`.  Decode runs the same ``decode_step`` over a
        gathered slot-major view of the page pool, so outputs match the
        slot cache token-for-token; what changes is capacity — with
        ``num_pages`` oversubscribed relative to
        ``max_slots * max_seq_len / page_size``, short prompts and shared
        prefixes let many more concurrent requests fit the same memory.
        Admission that cannot get pages *defers* (the request returns to
        the queue head; live slots are never corrupted) and a decode step
        that cannot get pages preempts the youngest slot, whose request is
        re-served from scratch (identical output: greedy decoding, and
        non-greedy streams restart their seeded RNG).
    page_size, num_pages, prefix_sharing : forwarded to
        :class:`PagedKVCache` when ``paged``.
    slo : :class:`~repro.serve.slo.SLOConfig` enabling the SLO control
        loop: a hysteresis state machine over the degradation ladder
        (defer admissions / shrink decode chunk -> sparser weight tier ->
        shed lowest-priority queued work), driven by a decode-cadence
        watchdog and a table-seeded latency model.
    tiers : sparsity-tier specs (densest first — strings like ``"dense"``,
        ``"2:4"``, ``"1:4:8-gr64"`` or :class:`~repro.serve.slo.TierSpec`),
        pre-converted once here so a controller tier switch is a pytree
        pointer swap into an already-compiled decode program (call
        :meth:`warm_tiers` after construction to compile every tier
        eagerly).  ``params`` must be the *dense* weights when tiers are
        given; tier 0 is what the engine serves when healthy.
    faults : a :class:`~repro.serve.faults.FaultInjector` wrapping the
        decode/admission paths (deterministic seeded latency spikes,
        slow-decode windows, transient errors retried with capped
        exponential backoff) — the overload benchmark's chaos source.
    max_queue : bound the arrival queue; ``submit()`` past the bound
        raises :class:`~repro.serve.errors.EngineOverloadError`.
    """

    def __init__(self, params, cfg: ModelConfig, *,
                 max_slots: int = DEFAULT_MAX_SLOTS,
                 max_seq_len: int = 256, reset_freed_slots: bool = False,
                 decode_chunk: int = 8,
                 clock: Callable[[], float] = time.perf_counter,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 slo: Optional[SLOConfig] = None,
                 tiers: Optional[Iterable] = None,
                 faults: Optional[FaultInjector] = None,
                 max_queue: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.reset_freed_slots = reset_freed_slots
        self.decode_chunk = max(1, decode_chunk)
        self.paged = paged
        self.queue = RequestQueue()
        self.faults = faults
        self.max_queue = max_queue
        self.tiers = build_tiers(params, list(tiers)) if tiers else None
        self.tier_idx = 0
        if self.tiers:
            self.params = self.tiers[0].params
        self.tokens_by_tier = (
            {t.spec.name: 0 for t in self.tiers} if self.tiers else None
        )
        self.slo = slo
        if slo is not None:
            self._latency = LatencyModel(self.params, cfg,
                                         max_slots=max_slots)
            self._controller: Optional[SLOController] = SLOController(
                slo, n_tiers=len(self.tiers) if self.tiers else 1,
                max_slots=max_slots, latency=self._latency)
        else:
            self._latency = None
            self._controller = None
        #: decode-chunk sizes this engine may run (compiled at warmup):
        #: the base chunk, the controller's shrunk chunk, and 1 (the
        #: non-greedy / degraded fallback)
        self._chunk_sizes = sorted({self.decode_chunk, 1} | (
            {max(1, self.decode_chunk // max(1, slo.chunk_shrink))}
            if slo is not None else set()
        ))
        self._decode_calls = 0  # global decode-call index (fault schedule)
        if paged:
            self.kv = PagedKVCache(cfg, max_slots, max_seq_len,
                                   page_size=page_size, num_pages=num_pages,
                                   prefix_sharing=prefix_sharing)
            self._decode = _jit_paged_decode(cfg, self.kv.page_size,
                                             self.kv.num_pages)
            self._decode_chunk = (
                _jit_paged_decode_chunk(cfg, self.kv.page_size,
                                        self.kv.num_pages, self.decode_chunk)
                if self.decode_chunk > 1 else None
            )
        else:
            self.kv = SlotKVCache(cfg, max_slots, max_seq_len)
            self._decode = _jit_decode(cfg)
            self._decode_chunk = (
                _jit_decode_chunk(cfg, self.decode_chunk)
                if self.decode_chunk > 1 else None
            )
        #: scheduler counters (all zero for the slot cache except
        #: rejected/peak_active): deferred admissions, mid-stream
        #: preemptions, rejected requests, peak concurrently-active slots,
        #: plus the SLO/fault loop's shed/timeout/retry/tier-switch counts.
        #: Reads/writes behave exactly like the plain dict this used to
        #: be; increases additionally mirror into the telemetry registry
        #: so a benchmark's registry snapshot includes engine stats.
        self.stats = MirroredCounters(
            {"deferred_admissions": 0, "preemptions": 0,
             "rejected": 0, "peak_active": 0, "shed": 0,
             "timeout": 0, "fault_retries": 0, "tier_switches": 0},
            REGISTRY.family("engine_stats",
                            help="engine scheduler counters"))
        # chunked decode falls back to single-step once a lone slot cannot
        # get a full chunk's pages; cleared when a request finishes (pages
        # freed) — see _ensure_decode_pages
        self._force_single = False
        self._slots: list[Optional[_SlotState]] = [None] * max_slots
        # next cache write position per slot == current valid length
        self._pos = np.zeros(max_slots, np.int32)
        self._tok = np.zeros(max_slots, np.int32)  # last sampled token
        self._outputs: list[RequestOutput] = []
        self._clock = clock
        self._t0: Optional[float] = None

    # -- introspection ----------------------------------------------------
    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self._slots)

    def free_slots(self) -> list:
        return [i for i, s in enumerate(self._slots) if s is None]

    def _now(self) -> float:
        if self._t0 is None:
            self._t0 = self._clock()
        return self._clock() - self._t0

    def _abs(self, rel: float) -> float:
        """Engine-relative seconds back to the clock's absolute domain —
        what the flight recorder's retroactive spans take.  (With an
        injected test clock the absolute values live in that clock's
        domain, not ``perf_counter``'s; spans stay internally consistent
        either way.)"""
        return (self._t0 or 0.0) + rel

    # -- request lifecycle ------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue a request, validating it against this engine's capacity
        *now* rather than failing later at admission: a prompt that cannot
        fit the per-slot cache (prompt + at least one generated token)
        raises :class:`PromptTooLongError`, and a full bounded queue
        raises :class:`~repro.serve.errors.EngineOverloadError`.  Traces
        fed through :meth:`run` get these converted to ``"rejected"``
        outputs instead — one bad request must not kill a serve loop."""
        S = int(req.prompt.size)
        if S > self.max_seq_len:
            raise PromptTooLongError(
                f"request {req.uid}: prompt length {S} exceeds the "
                f"per-slot capacity {self.max_seq_len} (prompt plus at "
                f"least one generated token must fit)"
            )
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            obs.event("overload_reject", "engine", uid=req.uid,
                      queue_depth=len(self.queue))
            # postmortem: dump the flight recorder before surfacing the
            # overload, so the timeline leading into it survives the crash
            obs.postmortem("EngineOverloadError")
            raise EngineOverloadError(
                f"request {req.uid}: queue is at its bound "
                f"({self.max_queue}); retry later or raise max_queue"
            )
        self.queue.push(req)

    def _reject(self, req: Request, now: float) -> None:
        self._outputs.append(RequestOutput(
            uid=req.uid, prompt_len=int(req.prompt.size), tokens=[],
            finish_reason="rejected", arrival_time=req.arrival_time,
            admitted_time=now, finish_time=self._now(), token_times=[],
            deadline=req.deadline,
        ))
        self.stats["rejected"] += 1
        obs.event("rejected", f"req:{req.uid}", uid=req.uid)

    def _finish_unserved(self, req: Request, now: float,
                         reason: str) -> None:
        """Terminal outcome for a request that never occupied a slot:
        ``"timeout"`` (deadline expired while queued / predicted blown at
        admission) or ``"shed"`` (the controller dropped it)."""
        self._outputs.append(RequestOutput(
            uid=req.uid, prompt_len=int(req.prompt.size), tokens=[],
            finish_reason=reason, arrival_time=req.arrival_time,
            admitted_time=now, finish_time=self._now(), token_times=[],
            deadline=req.deadline,
        ))
        self.stats[reason] += 1
        if obs.enabled():
            obs.complete("queued", self._abs(req.arrival_time),
                         self._abs(self._now()), f"req:{req.uid}",
                         uid=req.uid, outcome=reason)
            obs.event(reason, f"req:{req.uid}", uid=req.uid)

    def _admit(self, slot: int, req: Request, now: float) -> bool:
        """Prefill ``req`` into ``slot`` and sample its first token.
        Returns False (leaving the slot free and the cache untouched) when
        the paged pool cannot supply the prompt's pages; raises
        :class:`PromptTooLongError` for over-long prompts."""
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        if self.faults is not None:
            self.faults.admission_delay()
        t_pre = self._now()
        if self.paged:
            logits = self.kv.admit(self.params, prompt, slot)
            if logits is None:
                return False
        else:
            logits = self.kv.write_prefill(self.params, prompt, slot)
        S = int(req.prompt.size)
        if self._latency is not None:
            self._latency.observe_prefill(S, self._now() - t_pre)
        if obs.enabled():
            # the request's lifecycle row: time spent queued (arrival to
            # admission), then the prefill that admitted it
            obs.complete("queued", self._abs(req.arrival_time),
                         self._abs(now), f"req:{req.uid}", uid=req.uid)
            obs.complete("prefill", self._abs(t_pre),
                         self._abs(self._now()), f"req:{req.uid}",
                         uid=req.uid, slot=slot, prompt_len=S)
        # token i (1-based) is written to the cache at position S + i - 1,
        # so generating N tokens needs S + N - 1 <= max_seq_len
        max_new = min(req.max_new_tokens, self.max_seq_len - S + 1)
        st = _SlotState(
            req=req, tokens=[], token_times=[], admitted_time=now,
            rng=np.random.default_rng(req.sampling.seed), max_new=max_new,
        )
        tok = sample_token(np.asarray(logits[0]), req.sampling, st.rng)
        st.tokens.append(tok)
        st.token_times.append(self._now())
        self._slots[slot] = st
        self._pos[slot] = S
        self._tok[slot] = tok
        if self._stopped(st, tok):
            self._finish(slot)
        return True

    def _stopped(self, st: _SlotState, tok: int) -> bool:
        return tok in st.req.stop_tokens or len(st.tokens) >= st.max_new

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        reason = "stop" if st.tokens[-1] in st.req.stop_tokens else "length"
        obs.event("finish", f"req:{st.req.uid}", uid=st.req.uid,
                  reason=reason, tokens=len(st.tokens))
        self._outputs.append(RequestOutput(
            uid=st.req.uid,
            prompt_len=int(st.req.prompt.size),
            tokens=list(st.tokens),
            finish_reason=reason,
            arrival_time=st.req.arrival_time,
            admitted_time=st.admitted_time,
            finish_time=self._now(),
            token_times=list(st.token_times),
            deadline=st.req.deadline,
        ))
        self._slots[slot] = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        if self.paged:
            self.kv.release_slot(slot, zero=self.reset_freed_slots)
            self._force_single = False  # pages freed; chunks may fit again
        elif self.reset_freed_slots:
            self.kv.reset(slot)

    def _preempt(self, slot: int) -> None:
        """Evict an active slot mid-stream: free its pages and return its
        request to the queue head.  Generated tokens are discarded — the
        re-served request reproduces them exactly (greedy decoding is
        deterministic, and non-greedy requests restart their seeded RNG
        stream), so preemption is invisible in the outputs."""
        st = self._slots[slot]
        self.kv.release_slot(slot)
        self._slots[slot] = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        self.queue.push_front(st.req)
        self.stats["preemptions"] += 1
        obs.event("preempt", f"req:{st.req.uid}", uid=st.req.uid, slot=slot,
                  tokens_discarded=len(st.tokens))

    def _ensure_decode_pages(self, active, n_steps: int):
        """Before a paged decode of ``n_steps``, make every active slot's
        write range mapped and private (allocating growth pages,
        copy-on-writing shared ones).  When the pool runs dry the
        *youngest* active slot is preempted and the rest retry — oldest
        requests keep their pages, matching the admission order the queue
        would re-serve anyway.  Returns the surviving slots, or None when
        a lone slot cannot fit a multi-step chunk (the caller then falls
        back to single-step decode, which needs at most one new page).  A
        lone slot that cannot get even one page is rejected outright —
        its prompt fits but prompt + one generated token cannot, and with
        nothing left to preempt it would requeue forever."""
        pending = sorted(active,
                         key=lambda s: (self._slots[s].admitted_time, s))
        ok: list = []
        while pending:
            slot = pending[0]
            if self.kv.ensure_writable_range(slot, int(self._pos[slot]),
                                             n_steps):
                ok.append(pending.pop(0))
                continue
            if not ok and len(pending) == 1:
                if n_steps > 1:
                    return None  # retry as single-step before evicting
                st = self._slots[slot]
                self.kv.release_slot(slot)
                self._slots[slot] = None
                self._pos[slot] = 0
                self._tok[slot] = 0
                self._reject(st.req, st.admitted_time)
                break
            self._preempt(pending.pop())
        return sorted(ok)

    # -- sparsity tiers ----------------------------------------------------
    def set_tier(self, idx: int, reason: Optional[str] = None) -> None:
        """Serve from tier ``idx``'s resident weight copy.  A pure pytree
        pointer swap: the jitted decode programs key their executables on
        param structure, so after :meth:`warm_tiers` this never
        recompiles (``trace_events()`` stays flat across switches).
        ``reason`` annotates the timeline event (the engine forwards the
        controller's last escalation reason)."""
        if self.tiers is None:
            raise ValueError("engine was built without tiers")
        if idx == self.tier_idx:
            return
        obs.event("tier_switch", "controller",
                  tier_from=self.tiers[self.tier_idx].spec.name,
                  tier_to=self.tiers[idx].spec.name,
                  reason=reason or "manual")
        self.params = self.tiers[idx].params
        self.tier_idx = idx
        self.stats["tier_switches"] += 1

    def warm_tiers(self, prompt_lens: Iterable[int] = (8,)) -> None:
        """Eagerly compile every (tier, program) the controller may run:
        each tier's prefill (per distinct prompt length), single-step
        decode, and every chunk size in ``self._chunk_sizes`` — by serving
        a tiny trace per (tier, chunk size) through throwaway engines that
        share this engine's module-level jit caches.  After this, tier
        switches and chunk shrinks at serve time are pointer swaps into
        already-compiled executables."""
        if self.tiers is None:
            return
        plens = sorted({int(p) for p in prompt_lens}) or [8]
        kw = dict(max_slots=self.max_slots, max_seq_len=self.max_seq_len,
                  paged=self.paged)
        if self.paged:
            kw.update(page_size=self.kv.page_size,
                      num_pages=self.kv.num_pages)
        for tier in self.tiers:
            for T in self._chunk_sizes:
                reqs = [Request(uid=-1 - i,
                                prompt=np.arange(1, plen + 1) % 7 + 1,
                                max_new_tokens=max(2, T + 1))
                        for i, plen in enumerate(plens)]
                # max_new > T forces the chunked path through a full chunk
                # plus the tail; a lone non-greedy request warms the
                # single-step program (T == 1 runs it directly)
                eng = ServeEngine(tier.params, self.cfg, decode_chunk=T,
                                  **kw)
                eng.run(reqs)

    # -- fault hooks -------------------------------------------------------
    def _fault_gate(self, step_idx: int) -> None:
        """Run the injector's pre-decode gate, retrying injected transient
        faults with capped exponential backoff.  A burst outlasting
        ``max_retries`` propagates — that is a real outage, not jitter."""
        f = self.faults
        if f is None:
            return
        attempt = 0
        while True:
            try:
                f.pre_decode(step_idx)
                return
            except InjectedFaultError:
                if attempt >= f.cfg.max_retries:
                    obs.event("fault_retries_exhausted", "faults",
                              step=step_idx, attempts=attempt)
                    raise
                self.stats["fault_retries"] += 1
                obs.event("fault_retry", "faults", step=step_idx,
                          attempt=attempt)
                f.sleep(min(f.cfg.backoff_s * (2 ** attempt),
                            f.cfg.backoff_cap_s))
                attempt += 1

    def _fault_post(self, step_idx: int, measured_s: float) -> None:
        if self.faults is not None:
            self.faults.post_decode(step_idx, measured_s)

    def _count_tokens(self, produced: int) -> None:
        if self.tokens_by_tier is not None and produced:
            self.tokens_by_tier[
                self.tiers[self.tier_idx].spec.name] += produced

    # -- the engine loop --------------------------------------------------
    def step(self) -> int:
        """One scheduler iteration: expire/shed queued work, let the SLO
        controller pick the degradation level, admit ready requests into
        free slots (all of them when steady, a rationed budget when
        degraded), then run one decode *chunk* over the batch
        (``decode_chunk`` steps device-resident when every active request
        is greedy, one host-paced step otherwise).  Returns the number of
        tokens produced (0 when the engine idled)."""
        now = self._now()
        produced = 0
        for req in self.queue.expired(now):
            self._finish_unserved(req, now, "timeout")
        ctrl = self._controller
        if ctrl is not None:
            ctrl.begin_step(now, len(self.queue))
            if self.tiers is not None:
                self.set_tier(ctrl.tier_index,
                              reason=f"slo:{ctrl.last_reason}")
            if ctrl.should_shed(len(self.queue)):
                for req in self.queue.shed(ctrl.shed_keep()):
                    self._finish_unserved(req, now, "shed")
        free = self.free_slots()
        budget = len(free) if ctrl is None \
            else ctrl.admission_budget(len(free))
        while free and budget > 0:
            req = self.queue.pop_ready(now)
            if req is None:
                break
            if req.deadline is not None and self._latency is not None:
                # admission-time cost prediction: a request that cannot
                # possibly finish inside its deadline times out now,
                # without burning a slot on doomed work
                est = self._latency.request_s(
                    int(req.prompt.size),
                    min(req.max_new_tokens,
                        self.max_seq_len - int(req.prompt.size) + 1))
                if est == est and now + est > req.deadline:
                    self._finish_unserved(req, now, "timeout")
                    continue
            try:
                admitted = self._admit(free[0], req, now)
            except PromptTooLongError:
                self._reject(req, now)
                continue  # slot stays free for the next ready request
            if not admitted:
                # out of pages: the request returns to the queue head and
                # admission stops — live slots are untouched, and pages
                # will free up as active requests finish
                self.queue.push_front(req)
                self.stats["deferred_admissions"] += 1
                break
            free.pop(0)
            budget -= 1
            produced += 1  # the first token sampled from prefill logits
        active = [i for i, s in enumerate(self._slots) if s is not None]
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        len(active))
        if not active:
            self._count_tokens(produced)
            return produced
        T = self.decode_chunk if ctrl is None \
            else ctrl.decode_chunk(self.decode_chunk)
        if (T > 1 and self._decode_chunk is not None
                and not self._force_single
                and all(self._slots[s].req.sampling.greedy for s in active)):
            produced += self._step_chunked(active, T)
        else:
            produced += self._step_single(active)
        self._count_tokens(produced)
        return produced

    def _step_single(self, active) -> int:
        """Per-token reference path: one decode step, host-side sampling."""
        produced = 0
        if self.paged:
            active = self._ensure_decode_pages(active, 1)
            if not active:
                return 0
        step_idx = self._decode_calls
        self._decode_calls += 1
        self._fault_gate(step_idx)
        t0 = self._now()
        tok = jnp.asarray(self._tok[:, None])
        pos = jnp.asarray(self._pos)
        if self.paged:
            logits, self.kv.data = self._decode(
                self.params, tok, self.kv.data, self.kv.device_table(), pos)
        else:
            logits, self.kv.data = self._decode(self.params, tok,
                                                self.kv.data, pos)
        logits_np = np.asarray(logits)
        self._fault_post(step_idx, self._now() - t0)
        t = self._now()
        if self._controller is not None:
            self._controller.observe_decode(t - t0, 1)
        if obs.enabled():
            obs.complete("decode_call", self._abs(t0), self._abs(t),
                         "engine", call=step_idx, steps=1,
                         n_active=len(active), tier=self.tier_idx)
            for slot in active:
                obs.complete("decode_step", self._abs(t0), self._abs(t),
                             f"req:{self._slots[slot].req.uid}",
                             call=step_idx, tier=self.tier_idx)
        for slot in active:
            st = self._slots[slot]
            nxt = sample_token(logits_np[slot], st.req.sampling, st.rng)
            st.tokens.append(nxt)
            st.token_times.append(t)
            self._pos[slot] += 1
            self._tok[slot] = nxt
            produced += 1
            if self._stopped(st, nxt):
                self._finish(slot)
        return produced

    def _chunk_fn(self, T: int):
        """The jitted chunk program for ``T`` steps — the pre-bound default
        for the base chunk, the module-level cache (same compiled
        executables) for the controller's shrunk chunk."""
        if T == self.decode_chunk:
            return self._decode_chunk
        if self.paged:
            return _jit_paged_decode_chunk(self.cfg, self.kv.page_size,
                                           self.kv.num_pages, T)
        return _jit_decode_chunk(self.cfg, T)

    def _step_chunked(self, active, T: Optional[int] = None) -> int:
        """Greedy fast path: ``T`` (default ``decode_chunk``) steps in one
        jit call with on-device argmax sampling, then a single chunked
        host fetch.

        The device loop always runs the full fixed-length chunk (one
        compiled program, no per-remaining-budget recompiles); tokens a
        request produced past its stop token or budget are simply discarded
        on the host.  Overshoot cache writes land in positions of slots
        that are about to be freed and are either overwritten by the next
        occupant's prefill/decode writes or masked out by the per-slot
        valid-prefix attention mask, so they are never read.  Per-token
        timestamps spread the measured chunk latency uniformly across the
        chunk's tokens (the stream's average decode cadence)."""
        produced = 0
        T = self.decode_chunk if T is None else T
        if self.paged:
            active = self._ensure_decode_pages(active, T)
            if active is None:
                # a lone slot can't fit a whole chunk's pages: degrade to
                # the one-page-at-a-time path until a finish frees pages
                self._force_single = True
                active = [i for i, s in enumerate(self._slots)
                          if s is not None]
                return self._step_single(active) if active else 0
            if not active:
                return 0
        step_idx = self._decode_calls
        self._decode_calls += 1
        self._fault_gate(step_idx)
        fn = self._chunk_fn(T)
        t0 = self._now()
        if self.paged:
            toks, self.kv.data = fn(
                self.params, jnp.asarray(self._tok[:, None]), self.kv.data,
                self.kv.device_table(), jnp.asarray(self._pos),
            )
        else:
            toks, self.kv.data = fn(
                self.params, jnp.asarray(self._tok[:, None]), self.kv.data,
                jnp.asarray(self._pos),
            )
        toks_np = np.asarray(toks)  # [T, max_slots] — one host sync
        self._fault_post(step_idx, self._now() - t0)
        t1 = self._now()
        if self._controller is not None:
            self._controller.observe_decode(t1 - t0, T)
        if obs.enabled():
            obs.complete("decode_call", self._abs(t0), self._abs(t1),
                         "engine", call=step_idx, steps=T,
                         n_active=len(active), tier=self.tier_idx)
            for slot in active:
                obs.complete("decode_chunk", self._abs(t0), self._abs(t1),
                             f"req:{self._slots[slot].req.uid}",
                             call=step_idx, steps=T, tier=self.tier_idx)
        for slot in active:
            st = self._slots[slot]
            for t in range(T):
                nxt = int(toks_np[t, slot])
                st.tokens.append(nxt)
                st.token_times.append(t0 + (t + 1) * (t1 - t0) / T)
                self._pos[slot] += 1
                self._tok[slot] = nxt
                produced += 1
                if self._stopped(st, nxt):
                    self._finish(slot)
                    break
        return produced

    def run(self, requests: Iterable[Request] = (),
            max_steps: int = 1_000_000) -> list:
        """Serve until the queue drains and every slot finishes.  Returns
        the :class:`RequestOutput`s finished *during this call* in uid
        order.  The engine keeps one wall-clock epoch across repeated
        ``run()``/``step()`` calls, so ``metrics()`` aggregates the full
        lifetime consistently (arrival_times are relative to the first
        call)."""
        first_new = len(self._outputs)
        for req in requests:
            try:
                self.submit(req)
            except ServeError:
                # one bad request (over-long prompt, full bounded queue)
                # must not kill a trace replay: it finishes as rejected
                self._reject(req, self._now())
        if self._t0 is None:
            self._t0 = self._clock()
        steps = 0
        while (len(self.queue) or self.num_active) and steps < max_steps:
            before = self.num_active
            self.step()
            steps += 1
            if not before and not self.num_active and len(self.queue):
                # everything idle but traffic still due: wait for it in
                # short sleeps while the clock advances; if an injected
                # clock does not self-advance (e.g. a frozen test clock),
                # warp virtual time to the arrival so the loop always
                # makes progress
                nxt = self.queue.next_arrival()
                while nxt is not None:
                    remaining = nxt - self._now()
                    if remaining <= 0:
                        break
                    t_before = self._clock()
                    time.sleep(min(remaining, 0.05))
                    if self._clock() <= t_before:
                        self._t0 -= remaining
                        break
        return sorted(self._outputs[first_new:], key=lambda o: o.uid)

    def metrics(self, *, label: str = "serve") -> ServeMetrics:
        wall = self._now() if self._t0 is not None else 0.0
        slo = self.slo
        return summarize(
            self._outputs, wall, label=label,
            slo_tpot_s=None if slo is None else slo.tpot_ms * 1e-3,
            slo_ttft_s=None if slo is None or slo.ttft_ms is None
            else slo.ttft_ms * 1e-3,
            tokens_by_tier=self.tokens_by_tier,
        )


def warmup_engine(params, cfg: ModelConfig, requests, *,
                  engine_kwargs: Optional[dict] = None,
                  tune: bool = False, tune_reps: int = 3) -> None:
    """Populate the jit caches (one slot-prefill per distinct prompt
    length + the decode step, for this param structure) by serving a tiny
    trace through a throwaway engine, so a measured run reports
    steady-state latency instead of compile stalls.

    With ``tune=True`` the warmup first autotunes the kernel routing for
    the *actual* shapes this engine will serve — each sparse weight's
    gemv/spmm crossover at the engine's decode width (``max_slots``) and
    the trace's prompt lengths — and activates the resulting
    :class:`~repro.tune.table.TuningTable` (merging into any already
    active), so the compilations this warmup triggers, and every
    subsequent engine trace, route through measured decisions instead of
    the shipped defaults.  Tuning must precede compilation because routing
    lookups happen at trace time; that ordering is the point of hanging
    the hook here."""
    ekw = dict(engine_kwargs or {})
    requests = list(requests)
    if tune and any(
        isinstance(leaf, GroupedNMTensor)
        for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, GroupedNMTensor))
    ):
        from repro.tune.bench import autotune_for_serving

        autotune_for_serving(
            params,
            max_slots=ekw.get("max_slots", DEFAULT_MAX_SLOTS),
            prompt_lens=sorted({int(r.prompt.size) for r in requests}) or [8],
            dtype=jnp.dtype(cfg.dtype),
            reps=tune_reps,
        )
    seen, warm = set(), []
    for r in requests:
        if r.prompt.size not in seen:
            seen.add(r.prompt.size)
            warm.append(Request(uid=-1 - len(warm), prompt=r.prompt,
                                max_new_tokens=2))
    ServeEngine(params, cfg, **ekw).run(warm)


def compare_dense_sparse(params, cfg: ModelConfig, requests, *,
                         nm: tuple = (1, 4, 16), gr: int = 64,
                         engine_kwargs: Optional[dict] = None,
                         warmup: bool = False, tune: bool = False):
    """Serve the same request trace with dense and n:m:g-sparse weights.

    Returns {'dense': (outputs, metrics), 'sparse': (outputs, metrics)} —
    the side-by-side numbers of the paper's Fig 11 serving scenario.
    ``warmup`` pre-compiles both variants so the metrics measure serving,
    not XLA compilation; ``tune`` additionally autotunes the sparse
    variant's kernel routing for the served shapes during its warmup (see
    :func:`warmup_engine`; the hook no-ops for the dense variant, which
    has no routed sparse weights)."""
    engine_kwargs = dict(engine_kwargs or {})
    requests = list(requests)
    results = {}
    for label, p in (
        ("dense", params),
        ("sparse", sparsify_for_serving(params, *nm, gr=gr)),
    ):
        if warmup:
            warmup_engine(p, cfg, requests, engine_kwargs=engine_kwargs,
                          tune=tune)
        eng = ServeEngine(p, cfg, **engine_kwargs)
        outs = eng.run(requests)
        results[label] = (outs, eng.metrics(label=label))
    return results

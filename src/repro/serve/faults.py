"""Deterministic, seeded fault injection for the serving engine.

The SLO control loop (``serve/slo.py``) claims the engine degrades
*quality* gracefully instead of *latency* catastrophically under overload
and infrastructure misbehavior.  This module is how that claim becomes
testable: a :class:`FaultInjector` wraps host-side hooks around the
engine's decode step and admission path and injects, from a schedule
precomputed entirely from ``FaultConfig.seed``:

* **latency spikes** — a one-off sleep before a decode step (GC pause,
  noisy neighbor, page fault storm),
* **slow-decode windows** — contiguous step ranges whose decode time is
  *multiplied* by a factor (thermal throttling, a co-tenant stealing the
  core).  The injector measures the real step and sleeps the remainder,
  so a sparser weight tier — whose real step is cheaper — proportionally
  shrinks the injected slowdown too, exactly like real throttling would,
* **transient errors** — :class:`InjectedFaultError` raised before the
  decode runs; the engine retries with capped exponential backoff.  The
  schedule bounds consecutive failures below the engine's retry cap, so
  injected faults are always recoverable (a genuine outage is modelled by
  raising the cap breach, which the engine propagates),
* **admission delays** — fixed extra latency on the prefill path.

Everything is derived from the seed up front (``horizon`` steps, reused
modulo beyond it), so two runs with the same seed see byte-identical
fault schedules regardless of wall-clock timing — the property the
fault-storm tests and the ``fig11_serve --bursty --faults`` benchmark
lean on.  All hooks are host-side: no injected fault can alter a traced
program, which is why faulted token streams stay bitwise-identical to
fault-free runs at the same weight tier.

:func:`burst_arrivals` builds the bursty arrival-time traces (background
Poisson plus co-arriving bursts) the overload benchmark and tests share.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs import trace as obs
from repro.obs.registry import REGISTRY, MirroredCounters
from repro.serve.errors import InjectedFaultError

__all__ = ["FaultConfig", "FaultInjector", "InjectedFaultError",
           "burst_arrivals"]


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded fault-schedule parameters (all probabilities per decode
    step; an all-zeros config injects nothing)."""

    seed: int = 0
    #: precomputed schedule length; steps beyond it reuse the schedule
    #: modulo ``horizon`` (keeps long runs faulting, stays deterministic)
    horizon: int = 2048
    #: P(latency spike before a decode step) and its [lo, hi) seconds
    spike_prob: float = 0.0
    spike_s: tuple = (0.005, 0.02)
    #: ((start_step, stop_step, factor), ...) — decode steps in
    #: [start, stop) have their measured duration multiplied by ``factor``
    #: (the injector sleeps the remainder after the real step)
    slow_windows: tuple = ()
    #: P(transient error burst at a decode step) and the max consecutive
    #: raises per burst (drawn uniformly in [1, max]); keep the max below
    #: the engine's ``max_retries`` so injected faults stay recoverable
    error_prob: float = 0.0
    max_consecutive_errors: int = 2
    #: fixed extra seconds injected on every admission (prefill) path
    admission_delay_s: float = 0.0
    # -- retry policy the *engine* applies to transient errors ------------
    max_retries: int = 4
    backoff_s: float = 0.001
    backoff_cap_s: float = 0.02


class FaultInjector:
    """Host-side fault hooks with a fully seeded schedule.

    The engine calls :meth:`pre_decode` (possibly repeatedly, under its
    retry loop) before each decode step and :meth:`post_decode` after it
    with the measured duration; :meth:`admission_delay` rides the prefill
    path.  ``sleep`` is injectable so virtual-clock tests can advance a
    fake clock instead of blocking the process.
    """

    def __init__(self, cfg: FaultConfig = FaultConfig(), *,
                 sleep: Callable[[float], None] = time.sleep):
        self.cfg = cfg
        self.sleep = sleep
        rng = np.random.default_rng(cfg.seed)
        h = max(1, int(cfg.horizon))
        spikes = rng.random(h) < cfg.spike_prob
        self._spike_s = np.where(
            spikes, rng.uniform(cfg.spike_s[0], cfg.spike_s[1], h), 0.0
        )
        errs = rng.random(h) < cfg.error_prob
        self._errors = np.where(
            errs, rng.integers(1, max(1, cfg.max_consecutive_errors) + 1,
                               size=h), 0
        ).astype(np.int64)
        # per-step retry bookkeeping (reset when the engine moves on)
        self._err_step: Optional[int] = None
        self._errs_left = 0
        self._spiked_step: Optional[int] = None
        #: what actually fired, for reports/tests — a plain dict to read,
        #: mirrored into the telemetry registry (and, with the flight
        #: recorder on, each injection below lands on the "faults" track)
        self.injected = MirroredCounters(
            {"spikes": 0, "spike_s": 0.0, "errors": 0,
             "slow_steps": 0, "slow_s": 0.0, "admission_delays": 0},
            REGISTRY.family("faults", help="injected faults, by kind"))

    # -- schedule introspection (deterministic, pure) ---------------------
    def spike_at(self, step: int) -> float:
        return float(self._spike_s[step % len(self._spike_s)])

    def errors_at(self, step: int) -> int:
        return int(self._errors[step % len(self._errors)])

    def slow_factor(self, step: int) -> float:
        for start, stop, factor in self.cfg.slow_windows:
            if start <= step < stop:
                return float(factor)
        return 1.0

    # -- engine hooks -----------------------------------------------------
    def pre_decode(self, step: int) -> None:
        """Fault gate before decode step ``step``.  Raises
        :class:`InjectedFaultError` while the step's scheduled error burst
        has raises left (the engine retries); once clear, injects the
        step's latency spike (exactly once) and returns."""
        if self._err_step != step:
            self._err_step = step
            self._errs_left = self.errors_at(step)
        if self._errs_left > 0:
            self._errs_left -= 1
            self.injected["errors"] += 1
            obs.event("injected_error", "faults", step=step,
                      remaining=self._errs_left)
            raise InjectedFaultError(f"injected transient fault at decode "
                                     f"step {step}")
        if self._spiked_step != step:
            self._spiked_step = step
            s = self.spike_at(step)
            if s > 0:
                self.injected["spikes"] += 1
                self.injected["spike_s"] += s
                obs.event("latency_spike", "faults", step=step,
                          seconds=round(s, 6))
                self.sleep(s)

    def post_decode(self, step: int, measured_s: float) -> None:
        """Apply the slow-window multiplier: the real step took
        ``measured_s``; sleep the remainder up to ``factor * measured_s``."""
        factor = self.slow_factor(step)
        if factor > 1.0 and measured_s > 0:
            extra = (factor - 1.0) * measured_s
            self.injected["slow_steps"] += 1
            self.injected["slow_s"] += extra
            obs.event("slow_window", "faults", step=step, factor=factor,
                      extra_s=round(extra, 6))
            self.sleep(extra)

    def admission_delay(self) -> None:
        if self.cfg.admission_delay_s > 0:
            self.injected["admission_delays"] += 1
            obs.event("admission_delay", "faults",
                      seconds=self.cfg.admission_delay_s)
            self.sleep(self.cfg.admission_delay_s)


def burst_arrivals(*, n_background: int, rate_hz: float,
                   bursts: Sequence[tuple] = (), seed: int = 0) -> list:
    """Arrival times for a bursty overload trace: ``n_background``
    Poisson arrivals at ``rate_hz`` plus, for each ``(t, size)`` in
    ``bursts``, ``size`` co-arriving requests at time ``t`` (a thundering
    herd).  Returns sorted floats; fully determined by ``seed``."""
    rng = np.random.default_rng(seed)
    times = list(np.cumsum(rng.exponential(1.0 / rate_hz, n_background)))
    for t, size in bursts:
        times.extend([float(t)] * int(size))
    return sorted(float(t) for t in times)

"""Request/response plumbing for the continuous-batching serving engine.

A :class:`Request` carries a prompt, per-request sampling parameters and
stop conditions; the :class:`RequestQueue` is the arrival side of the
engine (requests become visible once their ``arrival_time`` has passed,
which is how the benchmarks model Poisson traffic).  A finished request is
returned as a :class:`RequestOutput` with the wall-clock timestamps the
metrics layer aggregates into TTFT / per-token latency / throughput.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["SamplingParams", "Request", "RequestOutput", "RequestQueue",
           "sample_token"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    ``greedy`` overrides everything else; otherwise softmax sampling at
    ``temperature`` restricted to the ``top_k`` highest logits
    (``top_k=0`` means the full vocabulary).  ``seed`` makes a request's
    sampling stream reproducible independent of scheduling order.
    """

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens plus generation/stop settings."""

    uid: int
    prompt: np.ndarray                 # [S] int32 token ids
    max_new_tokens: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    stop_tokens: tuple = ()            # any of these ends generation
    arrival_time: float = 0.0          # seconds after engine start

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens >= 1


@dataclasses.dataclass
class RequestOutput:
    """A finished request with its generation and latency timestamps.

    ``token_times`` holds one wall-clock stamp per generated token (the
    first entry is the end of prefill, i.e. time-to-first-token)."""

    uid: int
    prompt_len: int
    tokens: list
    finish_reason: str                 # "length" | "stop"
    arrival_time: float
    admitted_time: float
    finish_time: float
    token_times: list

    @property
    def ttft(self) -> float:
        return self.token_times[0] - self.arrival_time

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


class RequestQueue:
    """Arrival queue with simulated arrival times.

    ``pop_ready(now)`` hands out the earliest-submitted request whose
    ``arrival_time`` has passed (submission order need not match arrival
    order); ``next_arrival()`` lets the engine idle-wait precisely when
    every slot is free but traffic is still due."""

    def __init__(self):
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def pop_ready(self, now: float) -> Optional[Request]:
        # requests may be submitted out of arrival order; scan for the
        # first due one (queues are engine-sized, so O(n) is fine)
        for i, req in enumerate(self._q):
            if req.arrival_time <= now:
                del self._q[i]
                return req
        return None

    def next_arrival(self) -> Optional[float]:
        return min(r.arrival_time for r in self._q) if self._q else None

    def __len__(self) -> int:
        return len(self._q)


def sample_token(logits: np.ndarray, sampling: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Sample one token id from a [V] logits row on the host.

    Host-side sampling keeps per-request RNG streams independent of batch
    composition — a slot's output never depends on which other requests
    happen to share the batch."""
    logits = np.asarray(logits, np.float32)
    if sampling.greedy:
        return int(np.argmax(logits))
    t = max(sampling.temperature, 1e-5)
    z = logits / t
    if sampling.top_k and sampling.top_k < z.size:
        kth = np.partition(z, -sampling.top_k)[-sampling.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - np.max(z)
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(z.size, p=p))

"""Request/response plumbing for the continuous-batching serving engine.

A :class:`Request` carries a prompt, per-request sampling parameters and
stop conditions; the :class:`RequestQueue` is the arrival side of the
engine (requests become visible once their ``arrival_time`` has passed,
which is how the benchmarks model Poisson traffic).  A finished request is
returned as a :class:`RequestOutput` with the wall-clock timestamps the
metrics layer aggregates into TTFT / per-token latency / throughput.

This module also owns the *host side* of the paged KV cache
(:class:`~repro.serve.cache.PagedKVCache`): the :class:`PageAllocator`
tracks physical-page refcounts, the free list, and the prefix-hash index
that lets requests with a common prompt prefix share pages.  All
allocation / free / compaction decisions happen here, on the host,
between decode steps — only the resulting int32 page table crosses into
XLA, so the device-side programs stay static-shape.
"""

from __future__ import annotations

import dataclasses
import hashlib
from collections import deque
from typing import Optional

import numpy as np

__all__ = ["SamplingParams", "Request", "RequestOutput", "RequestQueue",
           "PageAllocator", "prefix_hashes", "sample_token"]


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters.

    ``greedy`` overrides everything else; otherwise softmax sampling at
    ``temperature`` restricted to the ``top_k`` highest logits
    (``top_k=0`` means the full vocabulary).  ``seed`` makes a request's
    sampling stream reproducible independent of scheduling order.
    """

    greedy: bool = True
    temperature: float = 1.0
    top_k: int = 0
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One serving request: prompt tokens plus generation/stop settings.

    ``priority`` orders admission (higher first) and shields a request
    from load shedding — the SLO controller sheds lowest priority first.
    ``deadline_s`` is an optional completion budget measured from
    ``arrival_time``: a request still queued past its deadline finishes
    as ``"timeout"`` without ever occupying a slot, and one predicted at
    admission time to blow its deadline is timed out instead of admitted.
    """

    uid: int
    prompt: np.ndarray                 # [S] int32 token ids
    max_new_tokens: int = 16
    sampling: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    stop_tokens: tuple = ()            # any of these ends generation
    arrival_time: float = 0.0          # seconds after engine start
    priority: int = 0                  # higher admits first, sheds last
    deadline_s: Optional[float] = None  # completion budget from arrival

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        assert self.prompt.size > 0, "empty prompt"
        assert self.max_new_tokens >= 1
        assert self.deadline_s is None or self.deadline_s > 0

    @property
    def deadline(self) -> Optional[float]:
        """Absolute deadline (engine clock), or None."""
        return None if self.deadline_s is None \
            else self.arrival_time + self.deadline_s


@dataclasses.dataclass
class RequestOutput:
    """A finished request with its generation and latency timestamps.

    ``token_times`` holds one wall-clock stamp per generated token (the
    first entry is the end of prefill, i.e. time-to-first-token)."""

    uid: int
    prompt_len: int
    tokens: list
    # "length" | "stop" | "rejected" | "timeout" | "shed"
    finish_reason: str
    arrival_time: float
    admitted_time: float
    finish_time: float
    token_times: list
    deadline: Optional[float] = None   # absolute deadline, if the request
    #                                    carried one (for SLO accounting)

    @property
    def ttft(self) -> float:
        # rejected requests finish with no tokens; nan keeps them out of
        # the latency percentiles instead of raising
        if not self.token_times:
            return float("nan")
        return self.token_times[0] - self.arrival_time

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival_time


class RequestQueue:
    """Arrival queue with simulated arrival times.

    ``pop_ready(now)`` hands out the earliest-submitted request whose
    ``arrival_time`` has passed (submission order need not match arrival
    order); ``next_arrival()`` lets the engine idle-wait precisely when
    every slot is free but traffic is still due."""

    def __init__(self):
        self._q: deque[Request] = deque()

    def push(self, req: Request) -> None:
        self._q.append(req)

    def push_front(self, req: Request) -> None:
        """Return a request to the head of the queue — used when admission
        has to back out (out of pages) or a slot is preempted mid-stream,
        so the request keeps its place ahead of later arrivals."""
        self._q.appendleft(req)

    def pop_ready(self, now: float) -> Optional[Request]:
        """Hand out the best due request: highest ``priority`` first, then
        earliest absolute deadline (no deadline sorts last), then
        submission order.  Requests may be submitted out of arrival
        order; queues are engine-sized, so the O(n) scan is fine."""
        best_i = None
        best_key = None
        inf = float("inf")
        for i, req in enumerate(self._q):
            if req.arrival_time > now:
                continue
            key = (-req.priority,
                   inf if req.deadline is None else req.deadline, i)
            if best_key is None or key < best_key:
                best_i, best_key = i, key
        if best_i is None:
            return None
        req = self._q[best_i]
        del self._q[best_i]
        return req

    def expired(self, now: float) -> list:
        """Remove and return every queued request whose deadline has
        passed — the engine finishes them as ``"timeout"`` without a
        slot ever having been spent on them."""
        out = [r for r in self._q
               if r.deadline is not None and r.deadline < now]
        if out:
            dead = set(id(r) for r in out)
            self._q = deque(r for r in self._q if id(r) not in dead)
        return out

    def shed(self, keep: int) -> list:
        """Remove and return queued requests beyond ``keep``, shedding
        lowest priority first and, within a priority, newest arrivals
        first (the oldest work keeps its place — it has waited longest
        and sheds last)."""
        n_shed = len(self._q) - max(0, int(keep))
        if n_shed <= 0:
            return []
        order = sorted(range(len(self._q)),
                       key=lambda i: (self._q[i].priority,
                                      -self._q[i].arrival_time, -i))
        victims = set(order[:n_shed])
        out = [self._q[i] for i in sorted(victims)]
        self._q = deque(r for i, r in enumerate(self._q)
                        if i not in victims)
        return out

    def next_arrival(self) -> Optional[float]:
        return min(r.arrival_time for r in self._q) if self._q else None

    def __len__(self) -> int:
        return len(self._q)


# ---------------------------------------------------------------------------
# paged-cache host bookkeeping: allocator + prefix-sharing index
# ---------------------------------------------------------------------------


def prefix_hashes(tokens: np.ndarray, page_size: int) -> list:
    """Chained digests of every full token page of a prompt, plus (when the
    prompt does not end on a page boundary) a final digest of the *whole*
    prompt for the partial tail page.

    Returns ``[(digest, covered_len), ...]`` where ``covered_len`` is the
    number of prompt tokens the chain covers up to and including that page.
    Chaining (each digest folds in the previous one) encodes that K/V at a
    position depends on *all* earlier tokens under causal attention — page
    j is only shareable if pages 0..j-1 matched too, which the lookup gets
    for free by walking the chain until the first miss."""
    toks = np.ascontiguousarray(np.asarray(tokens, np.int32).reshape(-1))
    out = []
    h = hashlib.blake2b(digest_size=16)
    n_full = toks.size // page_size
    for j in range(n_full):
        h = h.copy()
        h.update(toks[j * page_size:(j + 1) * page_size].tobytes())
        out.append((h.digest(), (j + 1) * page_size))
    tail = toks.size % page_size
    if tail:
        h = h.copy()
        h.update(toks[n_full * page_size:].tobytes())
        out.append((h.digest(), toks.size))
    return out


class PageAllocator:
    """Refcounted physical-page pool + prefix-sharing index (host side).

    Invariants the property tests pin down:

    * a page is never handed out twice while live (``alloc`` only returns
      pages with refcount 0, set to 1),
    * ``decref`` frees a page exactly when its refcount reaches 0 (and
      only then returns it to the free list / invalidates its prefix-hash
      entries),
    * ``num_free + pages_in_use == num_pages`` always.

    The prefix index maps a chained token-prefix digest to the physical
    page holding that prefix's K/V rows.  Entries are invalidated the
    moment their page is freed, so a lookup can never resurrect a recycled
    page.  (Digest collisions — 128-bit blake2b — are assumed absent.)
    """

    def __init__(self, num_pages: int):
        assert num_pages >= 1
        self.num_pages = int(num_pages)
        self.refcount = np.zeros(self.num_pages, np.int64)
        self._free: deque = deque(range(self.num_pages))
        self._by_hash: dict = {}          # digest -> physical page
        self._hashes_of: dict = {}        # physical page -> set of digests

    # -- allocation -------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.num_pages - len(self._free)

    def alloc(self, n: int) -> Optional[list]:
        """Take ``n`` fresh pages (refcount 1 each), or None — leaving the
        pool untouched — when fewer than ``n`` are free (the caller then
        queues/preempts instead of partially allocating)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            assert self.refcount[p] == 0, f"page {p} double-allocated"
            self.refcount[p] = 1
        return pages

    def incref(self, page: int) -> None:
        assert self.refcount[page] > 0, f"incref on dead page {page}"
        self.refcount[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True iff this freed the page."""
        assert self.refcount[page] > 0, f"decref on dead page {page}"
        self.refcount[page] -= 1
        if self.refcount[page] == 0:
            for h in self._hashes_of.pop(page, ()):
                self._by_hash.pop(h, None)
            self._free.append(page)
            return True
        return False

    # -- prefix sharing ---------------------------------------------------
    def register_prefix(self, digest: bytes, page: int) -> None:
        """Publish ``page`` as holding the K/V rows of the prefix with this
        digest, so later admissions can share it.  First writer wins (the
        existing entry stays authoritative for its sharers)."""
        assert self.refcount[page] > 0
        if digest in self._by_hash:
            return
        self._by_hash[digest] = page
        self._hashes_of.setdefault(page, set()).add(digest)

    def lookup_prefix(self, digest: bytes) -> Optional[int]:
        return self._by_hash.get(digest)

    # -- compaction -------------------------------------------------------
    def compaction_perm(self) -> dict:
        """Plan a compaction: map every live physical page to a new id
        packed at the front of the pool (in increasing old-id order).
        Pure planning — ``apply_compaction`` commits it after the device
        pool has been permuted."""
        live = [p for p in range(self.num_pages) if self.refcount[p] > 0]
        return {old: new for new, old in enumerate(live)}

    def apply_compaction(self, old_to_new: dict) -> None:
        ref = np.zeros_like(self.refcount)
        for old, new in old_to_new.items():
            ref[new] = self.refcount[old]
        self.refcount = ref
        self._free = deque(range(len(old_to_new), self.num_pages))
        self._by_hash = {h: old_to_new[p] for h, p in self._by_hash.items()}
        self._hashes_of = {
            old_to_new[p]: hs for p, hs in self._hashes_of.items()
        }


def sample_token(logits: np.ndarray, sampling: SamplingParams,
                 rng: np.random.Generator) -> int:
    """Sample one token id from a [V] logits row on the host.

    Host-side sampling keeps per-request RNG streams independent of batch
    composition — a slot's output never depends on which other requests
    happen to share the batch."""
    logits = np.asarray(logits, np.float32)
    if sampling.greedy:
        return int(np.argmax(logits))
    t = max(sampling.temperature, 1e-5)
    z = logits / t
    if sampling.top_k and sampling.top_k < z.size:
        kth = np.partition(z, -sampling.top_k)[-sampling.top_k]
        z = np.where(z >= kth, z, -np.inf)
    z = z - np.max(z)
    p = np.exp(z)
    p /= p.sum()
    return int(rng.choice(z.size, p=p))

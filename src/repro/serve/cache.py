"""Slot KV cache: the static-shape state behind continuous batching.

JAX/XLA wants fixed shapes, so the serving cache is one
``init_cache(cfg, max_slots, max_seq_len)`` pytree whose batch axis is a
pool of *slots*.  A request occupies a slot from admission to completion;
admission writes its prefill K/V into the slot via the model's
``prefill_into_slot`` entry point, decode advances every slot at its own
position (``decode_step`` with a per-slot position vector), and freed
slots are simply overwritten by the next admission.  ``decode_attention``
masks each slot to its own valid prefix, so stale tail entries are never
read.

``reset_slot`` (explicit zeroing, useful for tests/debugging) and
``gather_slots`` (compaction: reorder live slots to the front, e.g. before
shrinking the pool) are jitted pure updates of the cache pytree.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import init_cache, prefill_into_slot
from repro.models.common import ModelConfig

__all__ = ["SlotKVCache", "reset_slot", "gather_slots"]


@functools.lru_cache(maxsize=16)
def _jit_slot_prefill(cfg: ModelConfig):
    """One jitted slot-prefill per config, shared across caches; jit then
    specializes per (prompt length, param structure).  The cache operand is
    donated: admission updates the slot pool in place instead of copying
    the whole [max_slots, max_seq_len] pytree.

    Bounded (unlike the read-only pattern tables in ``core/layouts.py``):
    each entry holds a jitted closure whose executable cache grows per
    traced prompt length, so an unbounded cache leaks compiled programs in
    a long-running engine that cycles through many configs.  Eviction of a
    cold config only costs a recompile if it returns."""
    return jax.jit(
        lambda p, toks, cache, slot, off: prefill_into_slot(
            p, cfg, toks, cache, slot, write_offset=off
        ),
        donate_argnums=(2,),
    )


@jax.jit
def reset_slot(cache, slot):
    """Zero batch row ``slot`` of every cache leaf."""
    return jax.tree_util.tree_map(
        lambda l: l.at[:, slot].set(jnp.zeros((), l.dtype)), cache
    )


@jax.jit
def gather_slots(cache, perm):
    """Reorder the slot axis by ``perm`` (int32 [max_slots]) — slot
    compaction.  Row i of the result is old row perm[i]."""
    return jax.tree_util.tree_map(lambda l: l[:, perm], cache)


class SlotKVCache:
    """Owns the slot-pool cache pytree plus per-slot host bookkeeping."""

    def __init__(self, cfg: ModelConfig, max_slots: int, max_seq_len: int,
                 *, enc_len: int = 0):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.data: Any = init_cache(cfg, max_slots, max_seq_len,
                                    enc_len=enc_len)
        # one compiled slot-prefill per distinct prompt length (prompts are
        # not padded: padding would write pad-token K/V into the slot)
        self._prefill_jit = _jit_slot_prefill(cfg)

    def write_prefill(self, params, tokens, slot: int, *,
                      write_offset: int = 0):
        """Admit one request: prefill ``tokens`` [1, S] into ``slot`` at
        seq offset ``write_offset``.  Returns the last-position logits
        [1, V]."""
        assert tokens.ndim == 2 and tokens.shape[0] == 1
        assert tokens.shape[1] <= self.max_seq_len, (
            f"prompt ({tokens.shape[1]}) exceeds max_seq_len "
            f"({self.max_seq_len})"
        )
        logits, self.data = self._prefill_jit(
            params, tokens, self.data, jnp.asarray(slot, jnp.int32),
            jnp.asarray(write_offset, jnp.int32),
        )
        return logits

    def reset(self, slot: int) -> None:
        self.data = reset_slot(self.data, jnp.asarray(slot, jnp.int32))

    def compact(self, perm) -> None:
        self.data = gather_slots(self.data, jnp.asarray(perm, jnp.int32))

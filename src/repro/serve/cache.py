"""Serving KV caches: the static-shape state behind continuous batching.

Two implementations share one contract (static shapes, per-slot positions,
admission via prefill, decode via ``decode_step``):

* :class:`SlotKVCache` — the original slot-owns-a-full-row pool: one
  ``init_cache(cfg, max_slots, max_seq_len)`` pytree whose batch axis is a
  pool of *slots*.  A request occupies a slot from admission to
  completion; admission writes its prefill K/V into the slot via the
  model's ``prefill_into_slot`` entry point, decode advances every slot at
  its own position, and freed slots are simply overwritten by the next
  admission.  ``decode_attention`` masks each slot to its own valid
  prefix, so stale tail entries are never read.

* :class:`PagedKVCache` — the paged pool: sequence-bearing leaves are
  stored as ``[L, num_pages, page_size, ...]`` and each slot owns an int32
  row of a ``[max_slots, pages_per_slot]`` page table mapping its logical
  pages to physical ones (sentinel ``num_pages`` = unmapped).  Decode
  gathers a slot-major *view* through the table, runs the unchanged
  ``decode_step`` on it, and commits only the newly written token rows
  back through the table — so the XLA programs stay static-shape and the
  attention/transformer entry points are untouched.  Requests admitted
  with a common prompt prefix refcount the same physical pages
  (copy-on-write; host bookkeeping in
  :class:`~repro.serve.queue.PageAllocator`), which is what lets a pool
  sized for N full sequences serve many times that many concurrent
  prefix-sharing requests.

Out-of-range writes are *dropped*, never clamped: unmapped / overshoot
destinations are redirected to the sentinel page index, which XLA scatter
discards (the same masked-overshoot contract the slot cache's chunked
decode relies on).  Gather clamps sentinel reads to a real page, but every
row a clamped read can produce lies beyond the slot's valid prefix and is
masked by ``decode_attention``.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import forward, init_cache, logits_of, prefill_into_slot
from repro.models.common import ModelConfig
# the slot writer's structural helpers: which cache leaves carry a seq
# axis, and the storage-dtype cast (int8 KV quantization)
from repro.models.transformer import _seq_leaf_kinds, _to_cache_dtype
# PromptTooLongError lives in the typed serve error family now; re-exported
# here because this module is where it historically came from
from repro.serve.errors import PromptTooLongError
from repro.serve.queue import PageAllocator, prefix_hashes
from repro.serve.tracecount import note_trace

__all__ = ["SlotKVCache", "PagedKVCache", "PromptTooLongError",
           "reset_slot", "gather_slots", "paged_view", "paged_commit"]


@functools.lru_cache(maxsize=16)
def _jit_slot_prefill(cfg: ModelConfig):
    """One jitted slot-prefill per config, shared across caches; jit then
    specializes per (prompt length, param structure).  The cache operand is
    donated: admission updates the slot pool in place instead of copying
    the whole [max_slots, max_seq_len] pytree.

    Bounded (unlike the read-only pattern tables in ``core/layouts.py``):
    each entry holds a jitted closure whose executable cache grows per
    traced prompt length, so an unbounded cache leaks compiled programs in
    a long-running engine that cycles through many configs.  Eviction of a
    cold config only costs a recompile if it returns."""

    def _prefill(p, toks, cache, slot, off):
        note_trace("slot_prefill")  # trace-time only: counts compilations
        return prefill_into_slot(p, cfg, toks, cache, slot,
                                 write_offset=off)

    return jax.jit(_prefill, donate_argnums=(2,))


@jax.jit
def reset_slot(cache, slot):
    """Zero batch row ``slot`` of every cache leaf."""
    return jax.tree_util.tree_map(
        lambda l: l.at[:, slot].set(jnp.zeros((), l.dtype)), cache
    )


@jax.jit
def gather_slots(cache, perm):
    """Reorder the slot axis by ``perm`` (int32 [max_slots]) — slot
    compaction.  Row i of the result is old row perm[i]."""
    return jax.tree_util.tree_map(lambda l: l[:, perm], cache)


class SlotKVCache:
    """Owns the slot-pool cache pytree plus per-slot host bookkeeping."""

    def __init__(self, cfg: ModelConfig, max_slots: int, max_seq_len: int,
                 *, enc_len: int = 0):
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.data: Any = init_cache(cfg, max_slots, max_seq_len,
                                    enc_len=enc_len)
        # one compiled slot-prefill per distinct prompt length (prompts are
        # not padded: padding would write pad-token K/V into the slot)
        self._prefill_jit = _jit_slot_prefill(cfg)

    def write_prefill(self, params, tokens, slot: int, *,
                      write_offset: int = 0):
        """Admit one request: prefill ``tokens`` [1, S] into ``slot`` at
        seq offset ``write_offset``.  Returns the last-position logits
        [1, V]."""
        assert tokens.ndim == 2 and tokens.shape[0] == 1
        if tokens.shape[1] > self.max_seq_len:
            raise PromptTooLongError(
                f"prompt ({tokens.shape[1]}) exceeds max_seq_len "
                f"({self.max_seq_len})"
            )
        logits, self.data = self._prefill_jit(
            params, tokens, self.data, jnp.asarray(slot, jnp.int32),
            jnp.asarray(write_offset, jnp.int32),
        )
        return logits

    def reset(self, slot: int) -> None:
        self.data = reset_slot(self.data, jnp.asarray(slot, jnp.int32))

    def compact(self, perm) -> None:
        self.data = gather_slots(self.data, jnp.asarray(perm, jnp.int32))


# ---------------------------------------------------------------------------
# paged cache: device-side pure functions
# ---------------------------------------------------------------------------


def paged_view(cfg: ModelConfig, pool, table, page_size: int):
    """Gather the slot-major logical cache out of the paged pool.

    Seq leaves [L, num_pages, page_size, ...] become
    [L, max_slots, pages_per_slot * page_size, ...] by indexing with the
    (flattened) page table; state leaves (SSM states, cross K/V) are
    slot-indexed already and pass through.  Sentinel (unmapped) table
    entries clamp to a real page — the rows they produce sit beyond the
    slot's valid prefix and are masked by ``decode_attention``."""
    kinds = _seq_leaf_kinds(cfg, 0)
    B, pps = table.shape

    def leaf(l, is_seq):
        if not is_seq:
            return l
        npg = l.shape[1]
        flat = jnp.clip(table.reshape(-1), 0, npg - 1)
        v = l[:, flat]  # [L, B * pps, page_size, ...]
        return v.reshape((l.shape[0], B, pps * page_size) + l.shape[3:])

    return jax.tree_util.tree_map(leaf, pool, kinds)


def paged_commit(cfg: ModelConfig, pool, view, table, pos, n_steps: int,
                 page_size: int, num_pages: int):
    """Write back what a decode chunk changed: for each slot, the
    ``n_steps`` token rows written at positions ``pos .. pos+n_steps-1``
    of the slot-major view are scattered into their physical pages; state
    leaves are taken wholesale from the view.

    Unmapped slots (sentinel table rows) and overshoot positions
    (``>= pages_per_slot * page_size``) resolve to the out-of-range page
    index ``num_pages``, which XLA scatter drops — the paged spelling of
    the slot cache's dropped out-of-range writes.  The engine guarantees
    every *mapped* destination page is private (refcount 1) before the
    chunk runs, so no two slots ever scatter into the same page."""
    kinds = _seq_leaf_kinds(cfg, 0)
    B, pps = table.shape
    S = pps * page_size
    t = jnp.arange(n_steps, dtype=jnp.int32)
    wpos = pos[:, None] + t[None, :]                     # [B, T]
    safe = jnp.clip(wpos, 0, S - 1)
    phys = jnp.take_along_axis(table, safe // page_size, axis=1)
    phys = jnp.where(wpos < S, phys, num_pages)          # drop overshoot
    row = safe % page_size
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]

    def leaf(pl, vl, is_seq):
        if not is_seq:
            return vl
        rows_v = vl[:, bidx, safe]                       # [L, B, T, ...]
        return pl.at[:, phys, row].set(rows_v)

    return jax.tree_util.tree_map(leaf, pool, view, kinds)


@functools.lru_cache(maxsize=16)
def _jit_copy_page(cfg: ModelConfig):
    """Copy-on-write primitive: duplicate physical page ``src`` into
    ``dst`` on every seq leaf (state leaves are per-slot, not paged)."""
    kinds = _seq_leaf_kinds(cfg, 0)

    def copy(pool, src, dst):
        return jax.tree_util.tree_map(
            lambda l, isq: l.at[:, dst].set(l[:, src]) if isq else l,
            pool, kinds,
        )

    return jax.jit(copy, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _jit_zero_pages(cfg: ModelConfig):
    """Zero a fixed-size batch of physical pages (sentinel entries are
    dropped by the scatter) — the paged analogue of ``reset_slot``."""
    kinds = _seq_leaf_kinds(cfg, 0)

    def zero(pool, pages):
        return jax.tree_util.tree_map(
            lambda l, isq: l.at[:, pages].set(jnp.zeros((), l.dtype))
            if isq else l,
            pool, kinds,
        )

    return jax.jit(zero, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _jit_gather_pages(cfg: ModelConfig):
    """Permute the physical-page axis (compaction)."""
    kinds = _seq_leaf_kinds(cfg, 0)

    def gather(pool, perm):
        return jax.tree_util.tree_map(
            lambda l, isq: l[:, perm] if isq else l, pool, kinds,
        )

    return jax.jit(gather, donate_argnums=(0,))


@functools.lru_cache(maxsize=16)
def _jit_paged_prefill(cfg: ModelConfig, page_size: int, num_pages: int):
    """Admission for the paged cache: run the collecting forward (the same
    graph ``prefill_into_slot`` traces), then scatter each token row of
    the contributions through the slot's page-table row.  Rows below
    ``start`` (the shared-prefix length) are redirected to the sentinel
    page and dropped — their physical pages already hold bitwise-identical
    K/V written by the first request that computed this prefix (causal
    attention: a position's K/V depends only on tokens at or before it).
    State leaves write batch row ``slot`` wholesale.  Jit specializes per
    prompt length, like the slot prefill."""

    def run(p, toks, pool, table_row, slot, start):
        note_trace("paged_prefill")  # trace-time only: counts compilations
        hidden, _, contribs, _ = forward(
            p, cfg, toks, remat="none", collect_cache=True,
        )
        logits = logits_of(p, cfg, hidden[:, -1:])[:, 0]
        S = toks.shape[1]
        pos = jnp.arange(S, dtype=jnp.int32)
        phys = table_row[pos // page_size]
        phys = jnp.where(pos >= start, phys, num_pages)  # drop shared rows
        rowi = pos % page_size
        kinds = _seq_leaf_kinds(cfg, 0)

        def leaf(pl, cl, is_seq):
            piece = _to_cache_dtype(cl[:, 0], pl.dtype)
            if not is_seq:
                return pl.at[:, slot].set(piece)
            return pl.at[:, phys, rowi].set(piece)   # [L, S, ...] rows

        pool = jax.tree_util.tree_map(leaf, pool, contribs, kinds)
        return logits, pool

    return jax.jit(run, donate_argnums=(2,))


class PagedKVCache:
    """Paged KV pool + page table + host-side allocator/sharing state.

    Parameters
    ----------
    cfg, max_slots, max_seq_len : as for :class:`SlotKVCache` —
        ``max_seq_len`` is the per-slot *logical* capacity (page table
        width × page size), no longer a physical reservation.
    page_size : tokens per physical page; must divide ``max_seq_len``.
    num_pages : physical pool size.  Defaults to
        ``max_slots * max_seq_len / page_size`` — exactly the slot cache's
        memory — but the point of paging is that with prefix sharing and
        mixed prompt lengths the pool can be *oversubscribed*: many more
        slots than ``num_pages // pages_per_slot``.
    prefix_sharing : admit requests with a known prompt prefix onto the
        existing physical pages (refcounted, copy-on-write).

    Local/sliding-window layers are stored full-length (no ring
    truncation): a ring buffer would alias multiple logical positions onto
    one physical row, which is exactly what a page table cannot express.
    """

    SENTINEL_DOC = "unmapped table entries hold num_pages (out of range)"

    def __init__(self, cfg: ModelConfig, max_slots: int, max_seq_len: int,
                 *, page_size: int = 16, num_pages: Optional[int] = None,
                 prefix_sharing: bool = True):
        if max_seq_len % page_size:
            raise ValueError(
                f"max_seq_len ({max_seq_len}) must be a multiple of "
                f"page_size ({page_size})"
            )
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_seq_len = max_seq_len
        self.page_size = page_size
        self.pages_per_slot = max_seq_len // page_size
        self.num_pages = (max_slots * self.pages_per_slot
                          if num_pages is None else int(num_pages))
        self.prefix_sharing = prefix_sharing
        self.alloc = PageAllocator(self.num_pages)
        # host-side page table; device copy is re-uploaded per decode call
        # (tiny: max_slots * pages_per_slot int32)
        self.table = np.full((max_slots, self.pages_per_slot),
                             self.num_pages, np.int32)
        self.data: Any = self._init_pool()
        self._prefill_jit = _jit_paged_prefill(cfg, page_size,
                                               self.num_pages)
        self._copy_jit = _jit_copy_page(cfg)
        self._zero_jit = _jit_zero_pages(cfg)
        self._gather_jit = _jit_gather_pages(cfg)
        self.stats = {"shared_tokens": 0, "prefilled_tokens": 0,
                      "cow_copies": 0, "peak_pages_in_use": 0}

    def _init_pool(self):
        """Seq leaves [L, num_pages, page_size, ...]; state leaves keep the
        slot-indexed [L, max_slots, ...] shape of the slot cache.  Built
        with ``local_window_cache=False`` so every seq leaf is full-length
        (see class docstring)."""
        kinds = _seq_leaf_kinds(self.cfg, 0)
        paged = init_cache(self.cfg, self.num_pages, self.page_size,
                           local_window_cache=False)
        slotted = init_cache(self.cfg, self.max_slots, self.page_size,
                             local_window_cache=False)
        return jax.tree_util.tree_map(
            lambda pg, st, isq: pg if isq else st, paged, slotted, kinds,
        )

    # -- introspection ----------------------------------------------------
    def device_table(self):
        return jnp.asarray(self.table)

    def slot_pages(self, slot: int) -> list:
        """Mapped (logical_page, physical_page) pairs for a slot."""
        row = self.table[slot]
        return [(j, int(p)) for j, p in enumerate(row)
                if p != self.num_pages]

    def logical_view(self):
        """Host-side helper (tests / debugging): the slot-major logical
        cache the decode step sees."""
        return paged_view(self.cfg, self.data, self.device_table(),
                          self.page_size)

    def _note_usage(self):
        used = self.alloc.pages_in_use()
        if used > self.stats["peak_pages_in_use"]:
            self.stats["peak_pages_in_use"] = used

    # -- admission --------------------------------------------------------
    def admit(self, params, tokens, slot: int):
        """Admit one request's prompt [1, S] into ``slot``: map shared
        prefix pages (refcount++), allocate private pages for the rest of
        the prompt, prefill, and scatter only the non-shared rows.

        Returns the last-position logits [1, V], or None when the pool
        cannot supply the private pages (the engine re-queues the request
        — admission never corrupts live slots).  Raises
        :class:`PromptTooLongError` beyond the logical capacity."""
        assert tokens.ndim == 2 and tokens.shape[0] == 1
        S = int(tokens.shape[1])
        if S > self.max_seq_len:
            raise PromptTooLongError(
                f"prompt ({S}) exceeds max_seq_len ({self.max_seq_len})"
            )
        assert np.all(self.table[slot] == self.num_pages), (
            f"slot {slot} admitted while still mapped"
        )
        toks_np = np.asarray(tokens[0])
        chain = (prefix_hashes(toks_np, self.page_size)
                 if self.prefix_sharing else [])
        shared: list = []
        shared_len = 0
        for digest, covered in chain:
            page = self.alloc.lookup_prefix(digest)
            if page is None:
                break
            shared.append((digest, page))
            shared_len = covered
        n_logical = -(-S // self.page_size)
        fresh = self.alloc.alloc(n_logical - len(shared))
        if fresh is None:
            return None  # out of pages; nothing increfed yet
        for _, page in shared:
            self.alloc.incref(page)
        row = self.table[slot]
        for j, (_, page) in enumerate(shared):
            row[j] = page
        for j, page in zip(range(len(shared), n_logical), fresh):
            row[j] = page
        # publish this prompt's prefix chain for future sharers (no-op for
        # digests already registered)
        for digest, covered in chain:
            row_idx = (covered - 1) // self.page_size
            self.alloc.register_prefix(digest, int(row[row_idx]))
        self._note_usage()
        self.stats["shared_tokens"] += shared_len
        self.stats["prefilled_tokens"] += S
        logits, self.data = self._prefill_jit(
            params, tokens, self.data, jnp.asarray(row),
            jnp.asarray(slot, jnp.int32), jnp.asarray(shared_len, jnp.int32),
        )
        return logits

    # -- decode-write preparation (allocation growth + copy-on-write) -----
    def ensure_writable_range(self, slot: int, start: int,
                              n_steps: int) -> bool:
        """Guarantee every page that decode positions
        ``start .. start+n_steps-1`` touch is mapped *and* private
        (refcount 1), copy-on-writing shared pages and allocating unmapped
        ones.  Returns False — leaving completed work in place, which is
        harmless (mapped pages stay refcounted to this slot) — when the
        pool runs dry; the engine then preempts a slot and retries."""
        lo = max(0, start)
        hi = min(start + n_steps, self.max_seq_len)
        for lp in sorted({p // self.page_size for p in range(lo, hi)}):
            phys = int(self.table[slot, lp])
            if phys == self.num_pages:
                got = self.alloc.alloc(1)
                if got is None:
                    return False
                self.table[slot, lp] = got[0]
            elif self.alloc.refcount[phys] > 1:
                got = self.alloc.alloc(1)
                if got is None:
                    return False
                self.data = self._copy_jit(
                    self.data, jnp.asarray(phys, jnp.int32),
                    jnp.asarray(got[0], jnp.int32),
                )
                self.alloc.decref(phys)
                self.table[slot, lp] = got[0]
                self.stats["cow_copies"] += 1
        self._note_usage()
        return True

    # -- release / reset / compaction -------------------------------------
    def release_slot(self, slot: int, *, zero: bool = False) -> list:
        """Unmap a slot, decref its pages; returns the physical pages this
        actually freed.  With ``zero`` the freed pages are also cleared on
        device (the isolation-test analogue of ``reset_slot``)."""
        freed = []
        for j in range(self.pages_per_slot):
            phys = int(self.table[slot, j])
            if phys == self.num_pages:
                continue
            self.table[slot, j] = self.num_pages
            if self.alloc.decref(phys):
                freed.append(phys)
        if zero and freed:
            pages = np.full(self.pages_per_slot, self.num_pages, np.int32)
            pages[:len(freed)] = freed
            self.data = self._zero_jit(self.data, jnp.asarray(pages))
        return freed

    def compact(self) -> None:
        """Pack live physical pages to the front of the pool, preserving
        their contents, and rewrite the table + allocator to match (e.g.
        before shrinking the pool)."""
        old_to_new = self.alloc.compaction_perm()
        perm = np.arange(self.num_pages, dtype=np.int32)
        for old, new in old_to_new.items():
            perm[new] = old
        self.data = self._gather_jit(self.data, jnp.asarray(perm))
        self.alloc.apply_compaction(old_to_new)
        for s in range(self.max_slots):
            for j in range(self.pages_per_slot):
                p = int(self.table[s, j])
                if p != self.num_pages:
                    self.table[s, j] = old_to_new[p]

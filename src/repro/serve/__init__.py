"""repro.serve — continuous-batching sparse serving engine (paper Fig 11
as a service: slot-based scheduling, per-slot KV caches, dense vs n:m:g
weights side by side)."""

from repro.serve.cache import SlotKVCache, gather_slots, reset_slot
from repro.serve.engine import (
    ServeEngine,
    compare_dense_sparse,
    sparsify_for_serving,
    warmup_engine,
)
from repro.serve.metrics import ServeMetrics, summarize
from repro.serve.queue import (
    Request,
    RequestOutput,
    RequestQueue,
    SamplingParams,
    sample_token,
)

__all__ = [
    "ServeEngine",
    "SlotKVCache",
    "ServeMetrics",
    "Request",
    "RequestOutput",
    "RequestQueue",
    "SamplingParams",
    "sample_token",
    "summarize",
    "sparsify_for_serving",
    "compare_dense_sparse",
    "warmup_engine",
    "reset_slot",
    "gather_slots",
]

"""repro.serve — continuous-batching sparse serving engine (paper Fig 11
as a service: slot-based scheduling, per-slot KV caches — slot-pool or
paged with copy-on-write prefix sharing — dense vs n:m:g weights side by
side), plus the SLO control loop that degrades quality (sparser weight
tiers, deferred admissions, load shedding) instead of latency under
overload, and the seeded fault injector that proves it."""

from repro.serve.cache import (
    PagedKVCache,
    SlotKVCache,
    gather_slots,
    paged_commit,
    paged_view,
    reset_slot,
)
from repro.serve.engine import (
    ServeEngine,
    compare_dense_sparse,
    sparsify_for_serving,
    warmup_engine,
)
from repro.serve.errors import (
    DeadlineExceededError,
    EngineOverloadError,
    InjectedFaultError,
    PromptTooLongError,
    ServeError,
    raise_for_output,
)
from repro.serve.faults import FaultConfig, FaultInjector, burst_arrivals
from repro.serve.metrics import ServeMetrics, summarize
from repro.serve.queue import (
    PageAllocator,
    Request,
    RequestOutput,
    RequestQueue,
    SamplingParams,
    prefix_hashes,
    sample_token,
)
from repro.serve.slo import (
    CadenceWatchdog,
    LatencyModel,
    SLOConfig,
    SLOController,
    Tier,
    TierSpec,
    build_tiers,
)
from repro.serve.tracecount import (
    note_trace,
    reset_trace_events,
    trace_events,
)

__all__ = [
    "ServeEngine",
    "SlotKVCache",
    "PagedKVCache",
    "PageAllocator",
    "ServeError",
    "PromptTooLongError",
    "DeadlineExceededError",
    "EngineOverloadError",
    "InjectedFaultError",
    "raise_for_output",
    "FaultConfig",
    "FaultInjector",
    "burst_arrivals",
    "SLOConfig",
    "SLOController",
    "CadenceWatchdog",
    "LatencyModel",
    "Tier",
    "TierSpec",
    "build_tiers",
    "ServeMetrics",
    "Request",
    "RequestOutput",
    "RequestQueue",
    "SamplingParams",
    "prefix_hashes",
    "sample_token",
    "summarize",
    "sparsify_for_serving",
    "compare_dense_sparse",
    "warmup_engine",
    "reset_slot",
    "gather_slots",
    "paged_view",
    "paged_commit",
    "note_trace",
    "trace_events",
    "reset_trace_events",
]

"""repro.serve — continuous-batching sparse serving engine (paper Fig 11
as a service: slot-based scheduling, per-slot KV caches — slot-pool or
paged with copy-on-write prefix sharing — dense vs n:m:g weights side by
side)."""

from repro.serve.cache import (
    PagedKVCache,
    PromptTooLongError,
    SlotKVCache,
    gather_slots,
    paged_commit,
    paged_view,
    reset_slot,
)
from repro.serve.engine import (
    ServeEngine,
    compare_dense_sparse,
    sparsify_for_serving,
    warmup_engine,
)
from repro.serve.metrics import ServeMetrics, summarize
from repro.serve.queue import (
    PageAllocator,
    Request,
    RequestOutput,
    RequestQueue,
    SamplingParams,
    prefix_hashes,
    sample_token,
)

__all__ = [
    "ServeEngine",
    "SlotKVCache",
    "PagedKVCache",
    "PageAllocator",
    "PromptTooLongError",
    "ServeMetrics",
    "Request",
    "RequestOutput",
    "RequestQueue",
    "SamplingParams",
    "prefix_hashes",
    "sample_token",
    "summarize",
    "sparsify_for_serving",
    "compare_dense_sparse",
    "warmup_engine",
    "reset_slot",
    "gather_slots",
    "paged_view",
    "paged_commit",
]

"""Trace-event counters for the serving fast path.

``note_trace(name)`` is called from *inside* the raw (unjitted) bodies of
the engine's decode/prefill programs, so it executes exactly once per JAX
trace — i.e. once per compilation of a new (param-structure, shape)
variant — and never at run time.  Tests use the counter deltas to prove
the SLO control loop's tier switches are recompile-free after
``ServeEngine.warm_tiers``: a tier swap is a pytree pointer swap into an
already-compiled program, so serving across tier switches must not move
these counters at all.

A dedicated leaf module (rather than a counter on ``serve/engine.py``)
because both ``serve/cache.py`` (slot prefill) and ``serve/engine.py``
(decode/chunk programs) record events, and cache must not import engine.
"""

from __future__ import annotations

import collections

__all__ = ["note_trace", "trace_events", "reset_trace_events"]

_TRACE_EVENTS: collections.Counter = collections.Counter()


def note_trace(name: str) -> None:
    """Record one trace of the named serve program (trace-time only)."""
    _TRACE_EVENTS[name] += 1


def trace_events() -> dict:
    """{program name: times traced} for this process."""
    return dict(_TRACE_EVENTS)


def reset_trace_events() -> None:
    _TRACE_EVENTS.clear()

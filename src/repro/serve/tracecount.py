"""Trace-event counters for the serving fast path.

``note_trace(name)`` is called from *inside* the raw (unjitted) bodies of
the engine's decode/prefill programs, so it executes exactly once per JAX
trace — i.e. once per compilation of a new (param-structure, shape)
variant — and never at run time.  Tests use the counter deltas to prove
the SLO control loop's tier switches are recompile-free after
``ServeEngine.warm_tiers``: a tier swap is a pytree pointer swap into an
already-compiled program, so serving across tier switches must not move
these counters at all.

The store is now a ``repro.obs`` registry family: ``note_trace`` /
``trace_events`` / ``reset_trace_events`` remain as thin shims over it so
every existing call site and test keeps working, but the counts land in
``TelemetryRegistry.snapshot()`` alongside the dispatch/kernel counters,
and — when the flight recorder is on — each JIT trace shows up as a
timestamped ``jit_trace`` event on the engine track (a recompile during
steady-state serving is exactly the kind of thing you want visible on
the timeline).

A dedicated leaf module (rather than a counter on ``serve/engine.py``)
because both ``serve/cache.py`` (slot prefill) and ``serve/engine.py``
(decode/chunk programs) record events, and cache must not import engine.
"""

from __future__ import annotations

from repro.obs.registry import REGISTRY

__all__ = ["note_trace", "trace_events", "reset_trace_events"]

_TRACE_EVENTS = REGISTRY.family(
    "serve_jit_traces",
    help="JAX traces of serve programs, by program name "
         "(trace-time only; flat counts prove recompile-free serving)",
    trace_as="jit_trace", track="engine")


def note_trace(name: str) -> None:
    """Record one trace of the named serve program (trace-time only)."""
    _TRACE_EVENTS[name] += 1


def trace_events() -> dict:
    """{program name: times traced} for this process."""
    return dict(_TRACE_EVENTS)


def reset_trace_events() -> None:
    _TRACE_EVENTS.clear()

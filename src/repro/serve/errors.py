"""Typed error family for the serving engine.

Every failure the engine can hand back to a caller is a
:class:`ServeError` subclass, so callers can catch the family with one
``except`` while still distinguishing the cases that matter:

* :class:`PromptTooLongError` — the request can never fit the engine's
  KV capacity (raised at ``submit()`` time; a trace fed through
  ``ServeEngine.run`` converts it into a ``finish_reason="rejected"``
  output instead, so one bad request cannot kill a serve loop),
* :class:`DeadlineExceededError` — the request's ``deadline_s`` expired
  (queued requests past their deadline finish as ``"timeout"`` without
  ever occupying a slot),
* :class:`EngineOverloadError` — admission control turned the request
  away: the bounded queue was full at ``submit()`` time, or the SLO
  control loop shed it (``finish_reason="shed"``).

:class:`InjectedFaultError` is deliberately *not* a :class:`ServeError`:
it models a transient infrastructure fault (``serve/faults.py``) that the
engine retries with capped exponential backoff — it is never a request
outcome.
"""

from __future__ import annotations

__all__ = [
    "ServeError",
    "PromptTooLongError",
    "DeadlineExceededError",
    "EngineOverloadError",
    "InjectedFaultError",
    "raise_for_output",
]


class ServeError(RuntimeError):
    """Base of every request-level serving failure."""


class PromptTooLongError(ServeError, ValueError):
    """A prompt (plus at least one generated token) exceeds the cache's
    per-slot capacity.

    Subclasses ``ValueError`` for compatibility with the pre-typed-family
    spelling (it used to be a bare ``ValueError`` subclass in
    ``serve/cache.py``)."""


class DeadlineExceededError(ServeError):
    """A request's ``deadline_s`` expired before it finished; queued
    requests past their deadline finish as ``"timeout"`` without ever
    occupying a slot."""


class EngineOverloadError(ServeError):
    """The engine turned a request away to protect its SLO: the bounded
    queue was full at ``submit()`` time, or the degradation ladder shed
    the request (``finish_reason="shed"``)."""


class InjectedFaultError(RuntimeError):
    """A transient fault injected by ``serve/faults.py`` around the decode
    step.  The engine retries these with capped exponential backoff; they
    never surface as request outcomes."""


#: terminal ``finish_reason`` -> exception class for callers that want
#: exceptions rather than outcome strings
_REASON_ERRORS = {
    "rejected": PromptTooLongError,
    "timeout": DeadlineExceededError,
    "shed": EngineOverloadError,
}


def raise_for_output(output) -> None:
    """Raise the typed error matching a failed
    :class:`~repro.serve.queue.RequestOutput`; no-op for served requests
    (``finish_reason`` ``"length"``/``"stop"``)."""
    cls = _REASON_ERRORS.get(output.finish_reason)
    if cls is not None:
        raise cls(
            f"request {output.uid} finished as {output.finish_reason!r} "
            f"after {output.finish_time - output.arrival_time:.3f}s"
        )

"""Serving metrics: TTFT, per-token latency percentiles, throughput.

Aggregates the timestamps each :class:`~repro.serve.queue.RequestOutput`
carries into the numbers a serving benchmark reports (p50/p99 per-token
latency, time-to-first-token, tok/s), and exports them as JSON for the
benchmark trajectory (``BENCH_serve.json``).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Iterable, Optional

import numpy as np

__all__ = ["ServeMetrics", "summarize"]


def _pct(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if len(xs) \
        else float("nan")


@dataclasses.dataclass
class ServeMetrics:
    """Summary statistics over a set of finished requests (seconds)."""

    label: str
    num_requests: int
    num_tokens: int
    num_rejected: int
    wall_time: float
    ttft_p50: float
    ttft_p99: float
    tok_latency_p50: float
    tok_latency_p99: float
    request_latency_p50: float
    throughput_tok_s: float

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def dump_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)

    def report(self) -> str:
        ms = 1e3
        return (
            f"[{self.label}] {self.num_requests} requests, "
            f"{self.num_tokens} tokens in {self.wall_time:.2f}s | "
            f"ttft p50/p99 {self.ttft_p50 * ms:.1f}/"
            f"{self.ttft_p99 * ms:.1f} ms | "
            f"per-token p50/p99 {self.tok_latency_p50 * ms:.2f}/"
            f"{self.tok_latency_p99 * ms:.2f} ms | "
            f"{self.throughput_tok_s:.1f} tok/s"
        )


def summarize(outputs: Iterable, wall_time: float, *,
              label: str = "serve") -> ServeMetrics:
    """Fold finished requests into a :class:`ServeMetrics`.

    Per-token latency is the gap between consecutive token timestamps
    within each request (the decode cadence a user of that stream sees);
    TTFT is first-token time minus arrival."""
    outputs = list(outputs)
    ttfts, gaps, req_lat = [], [], []
    n_tok, n_rej = 0, 0
    for o in outputs:
        if o.finish_reason == "rejected":
            n_rej += 1  # no tokens, no timestamps — excluded from stats
            continue
        n_tok += len(o.tokens)
        ttfts.append(o.ttft)
        req_lat.append(o.latency)
        ts = o.token_times
        gaps.extend(b - a for a, b in zip(ts[:-1], ts[1:]))
    return ServeMetrics(
        label=label,
        num_requests=len(outputs) - n_rej,
        num_tokens=n_tok,
        num_rejected=n_rej,
        wall_time=wall_time,
        ttft_p50=_pct(ttfts, 50),
        ttft_p99=_pct(ttfts, 99),
        tok_latency_p50=_pct(gaps, 50),
        tok_latency_p99=_pct(gaps, 99),
        request_latency_p50=_pct(req_lat, 50),
        throughput_tok_s=n_tok / max(wall_time, 1e-9),
    )

"""Serving metrics: TTFT, per-token latency percentiles, throughput.

Aggregates the timestamps each :class:`~repro.serve.queue.RequestOutput`
carries into the numbers a serving benchmark reports (p50/p99 per-token
latency, time-to-first-token, tok/s), and exports them as JSON for the
benchmark trajectory (``BENCH_serve.json``).

Overload/SLO runs additionally get outcome accounting: shed / timeout
counters, queue-delay percentiles (arrival to admission), per-tier token
counts, deadline misses, and — when the caller supplies its SLO
thresholds — the SLO-attainment fraction.  Requests that never produced
tokens (rejected / shed / timed out) stay out of the latency percentiles
but count against attainment: an answer that never came is the worst
latency of all.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

from repro.ioutil import atomic_write_json
from repro.statutil import fmt as _fmt, pct as _pct  # shared nan-safe helpers

__all__ = ["ServeMetrics", "summarize"]


@dataclasses.dataclass
class ServeMetrics:
    """Summary statistics over a set of finished requests (seconds)."""

    label: str
    num_requests: int
    num_tokens: int
    num_rejected: int
    wall_time: float
    ttft_p50: float
    ttft_p99: float
    tok_latency_p50: float
    tok_latency_p99: float
    request_latency_p50: float
    throughput_tok_s: float
    # -- overload / SLO accounting (defaults keep old call sites valid) ---
    num_shed: int = 0
    num_timeout: int = 0
    num_deadline_miss: int = 0
    queue_delay_p50: float = float("nan")
    queue_delay_p99: float = float("nan")
    #: fraction of *all* outcomes that met the SLO (nan when the caller
    #: supplied no SLO thresholds)
    slo_attainment: float = float("nan")
    #: {tier name: tokens served from that tier}, when tiers were in play
    tokens_by_tier: Optional[dict] = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def dump_json(self, path: str) -> None:
        atomic_write_json(path, self.to_dict())

    def report(self) -> str:
        ms = 1e3
        lines = [
            f"[{self.label}] {self.num_requests} requests, "
            f"{self.num_tokens} tokens in {self.wall_time:.2f}s | "
            f"ttft p50/p99 {_fmt(self.ttft_p50, ms)}/"
            f"{_fmt(self.ttft_p99, ms)} ms | "
            f"per-token p50/p99 {_fmt(self.tok_latency_p50, ms, 2)}/"
            f"{_fmt(self.tok_latency_p99, ms, 2)} ms | "
            f"{_fmt(self.throughput_tok_s)} tok/s",
            f"[{self.label}] outcomes: rejected {self.num_rejected}, "
            f"shed {self.num_shed}, timeout {self.num_timeout}, "
            f"deadline-miss {self.num_deadline_miss} | "
            f"queue delay p50/p99 {_fmt(self.queue_delay_p50, ms)}/"
            f"{_fmt(self.queue_delay_p99, ms)} ms",
        ]
        if not math.isnan(self.slo_attainment):
            lines.append(f"[{self.label}] SLO attainment "
                         f"{self.slo_attainment * 100:.1f}%")
        if self.tokens_by_tier:
            per_tier = ", ".join(f"{k}: {v}"
                                 for k, v in self.tokens_by_tier.items())
            lines.append(f"[{self.label}] tokens by tier: {per_tier}")
        return "\n".join(lines)


#: outcomes that never produced tokens — excluded from latency stats,
#: counted against SLO attainment
_UNSERVED = ("rejected", "shed", "timeout")


def summarize(outputs: Iterable, wall_time: float, *,
              label: str = "serve", slo_tpot_s: Optional[float] = None,
              slo_ttft_s: Optional[float] = None,
              tokens_by_tier: Optional[dict] = None) -> ServeMetrics:
    """Fold finished requests into a :class:`ServeMetrics`.

    Per-token latency is the gap between consecutive token timestamps
    within each request (the decode cadence a user of that stream sees);
    TTFT is first-token time minus arrival.  With ``slo_tpot_s`` /
    ``slo_ttft_s`` set, a served request attains the SLO when its mean
    decode gap and TTFT stay within them (whichever are set); unserved
    outcomes never attain."""
    outputs = list(outputs)
    ttfts, gaps, req_lat, qdelay = [], [], [], []
    n_tok = 0
    n_by_reason = {r: 0 for r in _UNSERVED}
    n_miss = 0
    attained = 0
    has_slo = slo_tpot_s is not None or slo_ttft_s is not None
    for o in outputs:
        if o.finish_reason in n_by_reason:
            n_by_reason[o.finish_reason] += 1
            continue  # no tokens, no timestamps — out of the latency stats
        n_tok += len(o.tokens)
        ttfts.append(o.ttft)
        req_lat.append(o.latency)
        qdelay.append(o.admitted_time - o.arrival_time)
        ts = o.token_times
        mine = [b - a for a, b in zip(ts[:-1], ts[1:])]
        gaps.extend(mine)
        deadline = getattr(o, "deadline", None)
        if deadline is not None and o.finish_time > deadline:
            n_miss += 1
        if has_slo:
            ok = True
            if slo_ttft_s is not None and not o.ttft <= slo_ttft_s:
                ok = False
            if slo_tpot_s is not None and mine and \
                    sum(mine) / len(mine) > slo_tpot_s:
                ok = False
            attained += ok
    n_unserved = sum(n_by_reason.values())
    return ServeMetrics(
        label=label,
        num_requests=len(outputs) - n_unserved,
        num_tokens=n_tok,
        num_rejected=n_by_reason["rejected"],
        wall_time=wall_time,
        ttft_p50=_pct(ttfts, 50),
        ttft_p99=_pct(ttfts, 99),
        tok_latency_p50=_pct(gaps, 50),
        tok_latency_p99=_pct(gaps, 99),
        request_latency_p50=_pct(req_lat, 50),
        # a zero/near-zero wall (no work actually ran) has no meaningful
        # rate — nan here, rendered "--" by report(), like nan-safe ttft
        throughput_tok_s=(n_tok / wall_time if wall_time > 1e-9
                          else float("nan")),
        num_shed=n_by_reason["shed"],
        num_timeout=n_by_reason["timeout"],
        num_deadline_miss=n_miss,
        queue_delay_p50=_pct(qdelay, 50),
        queue_delay_p99=_pct(qdelay, 99),
        slo_attainment=(attained / len(outputs)
                        if has_slo and outputs else float("nan")),
        tokens_by_tier=dict(tokens_by_tier) if tokens_by_tier else None,
    )

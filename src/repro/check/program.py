"""CheckedProgram: one traced (and optionally compiled) entry program plus
the trace-time evidence the rules inspect.

``build_program`` traces ``fn`` with ``jax.make_jaxpr`` while snapshotting
the dispatcher's fallback counters, the conversion log, and the kernel
routing counters, so each program carries exactly the dispatch decisions
*its own* trace caused (deltas, not process-wide totals).  VMEM estimates
for the routed Pallas configs are computed here, at build time, because
routing lookups resolve against whatever tuning table is active *now* —
the same trace-time contract the kernels themselves live by.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.core.layouts import (
    FixedMaskTensor,
    GroupedNMTensor,
    SparsityLayout,
)

__all__ = ["CheckedProgram", "build_program", "collect_sparse_weights"]


@dataclasses.dataclass
class CheckedProgram:
    """Everything the rules need to know about one entry program."""

    name: str
    model_dtype: Any                    # jnp dtype the program's math is in
    decode_path: bool                   # R3 (dtype) applies to this program
    jaxpr: Any = None                   # ClosedJaxpr | None
    hlo_text: Optional[str] = None      # compiled module text | None
    sparse_weights: dict = dataclasses.field(default_factory=dict)
    fallbacks: dict = dataclasses.field(default_factory=dict)   # dispatch delta
    conversions: list = dataclasses.field(default_factory=list)  # convert delta
    routes: dict = dataclasses.field(default_factory=dict)      # kernel delta
    vmem_estimates: list = dataclasses.field(default_factory=list)
    device_kind: str = ""


def collect_sparse_weights(tree) -> dict:
    """{path: layout} for every sparse-layout leaf of a params pytree."""
    out = {}
    leaves = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, SparsityLayout)
    )[0]
    for path, leaf in leaves:
        if isinstance(leaf, (GroupedNMTensor, FixedMaskTensor)):
            out[jax.tree_util.keystr(path)] = leaf
    return out


def build_program(name: str, fn: Callable, example_args: tuple, *,
                  model_dtype, decode_path: bool = False,
                  sparse_weights: Optional[dict] = None,
                  hlo: bool = False, decode_m: Optional[int] = None,
                  prefill_n: Optional[int] = None,
                  device_kind: Optional[str] = None) -> CheckedProgram:
    """Trace ``fn(*example_args)`` into a :class:`CheckedProgram`.

    ``decode_m`` / ``prefill_n`` are the activation widths the VMEM
    estimator sizes the routed gemv / spmm configs at; omit either to skip
    that estimate.  ``hlo=True`` additionally jit-compiles the program and
    stores the module text for the HLO pass (slower; the CLI default).
    """
    import importlib

    disp = importlib.import_module("repro.core.dispatch")
    conv = importlib.import_module("repro.core.convert")
    kops = importlib.import_module("repro.kernels.ops")
    from repro.tune.table import device_kind as _device_kind

    if sparse_weights is None:
        sparse_weights = collect_sparse_weights(example_args)

    disp_before = disp.dispatch_counters()
    kern_before = kops.kernel_counters()
    conv_before = len(conv.conversion_log())

    jaxpr = jax.make_jaxpr(fn)(*example_args)

    fallbacks = {
        k: v - disp_before.get(k, 0)
        for k, v in disp.dispatch_counters().items()
        if v > disp_before.get(k, 0)
    }
    routes = {
        k: v - kern_before.get(k, 0)
        for k, v in kops.kernel_counters().items()
        if v > kern_before.get(k, 0)
    }
    conversions = conv.conversion_log()[conv_before:]

    kind = device_kind or _device_kind()
    vmem = _vmem_estimates(sparse_weights, model_dtype, kind,
                           decode_m=decode_m, prefill_n=prefill_n)

    hlo_text = None
    if hlo:
        lowered = (fn.lower(*example_args) if hasattr(fn, "lower")
                   else jax.jit(fn).lower(*example_args))
        hlo_text = lowered.compile().as_text()

    return CheckedProgram(
        name=name, model_dtype=model_dtype, decode_path=decode_path,
        jaxpr=jaxpr, hlo_text=hlo_text, sparse_weights=dict(sparse_weights),
        fallbacks=fallbacks, conversions=conversions, routes=routes,
        vmem_estimates=vmem, device_kind=kind,
    )


def _vmem_estimates(sparse_weights: dict, model_dtype, device_kind: str, *,
                    decode_m: Optional[int], prefill_n: Optional[int]
                    ) -> list:
    """Routed-config VMEM working sets per GroupedNM weight — resolved now,
    while the active tuning table (if any) is the one the program traced
    against."""
    from repro.check.static_pass import gemv_vmem, spmm_vmem

    ests = []
    for path, w in sparse_weights.items():
        if not isinstance(w, GroupedNMTensor):
            continue
        if decode_m is not None:
            ests.append(gemv_vmem(w, model_dtype, decode_m, device_kind,
                                  weight=path))
        if prefill_n is not None:
            ests.append(spmm_vmem(w, model_dtype, prefill_n, device_kind,
                                  weight=path))
    return ests

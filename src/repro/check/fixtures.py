"""Seeded regression fixtures: for every rule, one program that triggers
it and one that is clean.

These are the checker's own test vectors — ``tests/test_check_meta.py``
asserts the registry and this table stay in lockstep, and
``tests/test_check.py`` asserts each trigger actually fails (nonzero exit
under ``--strict``) while each clean program passes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.check.program import build_program
from repro.core.layouts import CsrTensor, FixedMaskTensor, GroupedNMTensor
from repro.tune.routing import clear_active_table, set_active_table
from repro.tune.table import TuningTable, device_kind

__all__ = ["FIXTURES", "fixture_programs"]

_N, _M, _G, _GR = 1, 4, 8, 4


def _weight(K: int = 64, R: int = 64) -> GroupedNMTensor:
    x = jax.random.normal(jax.random.PRNGKey(0), (K, R), jnp.float32)
    return GroupedNMTensor.from_dense(x, _N, _M, _G, gr=_GR, sparse_dim=0)


def _x(rows: int = 4, K: int = 64):
    return jnp.ones((rows, K), jnp.float32)


# -- R1: silent densify ------------------------------------------------------


def _r1_trigger():
    w = _weight()

    def f(x):
        return x @ w.to_dense()      # densified projection: the bug

    return build_program("fixture/r1:trigger", f, (_x(),),
                         model_dtype=jnp.float32, decode_path=True,
                         sparse_weights={"w": w}, hlo=True, decode_m=4)


def _r1_clean():
    from repro.models.common import mm
    w = _weight()

    def f(x):
        return mm(x, w)              # dispatched sparse fast path

    return build_program("fixture/r1:clean", f, (_x(),),
                         model_dtype=jnp.float32, decode_path=True,
                         sparse_weights={"w": w}, hlo=True, decode_m=4)


# -- R2: conversion churn ----------------------------------------------------


def _csr():
    d = jnp.where(jnp.arange(64).reshape(8, 8) % 3 == 0, 1.0, 0.0)
    return CsrTensor.from_dense(d)


def _r2_trigger():
    import importlib
    conv = importlib.import_module("repro.core.convert")
    c = _csr()

    def f(x):
        a = conv.convert(c, FixedMaskTensor)
        b = conv.convert(c, FixedMaskTensor)   # the same conversion, again
        return x + a.to_dense() + b.to_dense()

    return build_program("fixture/r2:trigger", f, (jnp.ones((8, 8)),),
                         model_dtype=jnp.float32)


def _r2_clean():
    import importlib
    conv = importlib.import_module("repro.core.convert")
    c = _csr()

    def f(x):
        a = conv.convert(c, FixedMaskTensor)   # converted once, reused
        ad = a.to_dense()
        return x + ad + ad

    return build_program("fixture/r2:clean", f, (jnp.ones((8, 8)),),
                         model_dtype=jnp.float32)


# -- R3: dtype promotion on the decode path ---------------------------------


def _r3_trigger():
    def f(x):
        return x.astype(jnp.float32) * 2.0     # elementwise math widened

    return build_program("fixture/r3:trigger", f,
                         (jnp.ones((4, 8), jnp.bfloat16),),
                         model_dtype=jnp.bfloat16, decode_path=True)


def _r3_clean():
    y = jnp.ones((8, 4), jnp.float32)

    def f(x):
        # widening that feeds only the matmul accumulation is the
        # kernels' own f32-accumulator contract — allowed
        return (x.astype(jnp.float32) @ y).astype(jnp.bfloat16)

    return build_program("fixture/r3:clean", f,
                         (jnp.ones((4, 8), jnp.bfloat16),),
                         model_dtype=jnp.bfloat16, decode_path=True)


# -- R4: host sync inside the decode loop -----------------------------------


def _r4_trigger():
    def f(x):
        def body(c, _):
            y = jax.pure_callback(
                lambda v: v, jax.ShapeDtypeStruct(c.shape, c.dtype), c
            )
            return y, ()

        out, _ = jax.lax.scan(body, x, None, length=2)
        return out

    return build_program("fixture/r4:trigger", f, (jnp.ones((4,)),),
                         model_dtype=jnp.float32, decode_path=True,
                         hlo=True)


def _r4_clean():
    def f(x):
        def body(c, _):
            return c * 2.0, ()

        out, _ = jax.lax.scan(body, x, None, length=2)
        return out

    return build_program("fixture/r4:clean", f, (jnp.ones((4,)),),
                         model_dtype=jnp.float32, decode_path=True,
                         hlo=True)


# -- R5: weak-typed signature (recompile hazard) ----------------------------


def _r5_trigger():
    def f(x):
        return x + 1

    # a Python float argument traces weak-typed
    return build_program("fixture/r5:trigger", f, (1.0,),
                         model_dtype=jnp.float32)


def _r5_clean():
    def f(x):
        return x + 1

    return build_program("fixture/r5:clean", f, (np.float32(1.0),),
                         model_dtype=jnp.float32)


# -- R6: VMEM overrun from a bad tuned tile ---------------------------------


def _r6_program(name):
    from repro.models.common import mm
    w = _weight()

    def f(x):
        return mm(x, w)

    return build_program(name, f, (_x(),), model_dtype=jnp.float32,
                         decode_path=True, sparse_weights={"w": w},
                         decode_m=4)


def _r6_trigger():
    # a tuned (corrupt) tile so large the gathered-B block alone blows the
    # budget; estimates bake at build time, while this table is active
    bad = TuningTable(device=device_kind(),
                      entries={"gemv_pallas": {"tm": 1 << 20,
                                               "target_depth": 128}})
    set_active_table(bad)
    try:
        return _r6_program("fixture/r6:trigger")
    finally:
        clear_active_table()


def _r6_clean():
    return _r6_program("fixture/r6:clean")


# -- R7: unmodelled device kind ---------------------------------------------


def _r7_program(name, kind):
    def f(x):
        return x * 2.0

    return build_program(name, f, (_x(),), model_dtype=jnp.float32,
                         device_kind=kind)


def _r7_trigger():
    return _r7_program("fixture/r7:trigger", "tpu:tpu_v99")


def _r7_clean():
    return _r7_program("fixture/r7:clean", None)


FIXTURES = {
    "R1": {"trigger": _r1_trigger, "clean": _r1_clean},
    "R2": {"trigger": _r2_trigger, "clean": _r2_clean},
    "R3": {"trigger": _r3_trigger, "clean": _r3_clean},
    "R4": {"trigger": _r4_trigger, "clean": _r4_clean},
    "R5": {"trigger": _r5_trigger, "clean": _r5_clean},
    "R6": {"trigger": _r6_trigger, "clean": _r6_clean},
    "R7": {"trigger": _r7_trigger, "clean": _r7_clean},
}


def fixture_programs(rule_id: str, kind: str):
    """Build the ``kind`` ('trigger' | 'clean') fixture for ``rule_id``."""
    return FIXTURES[rule_id][kind]()

"""Jaxpr-walker detectors: the trace-level halves of R1/R3/R4/R5.

The walker recurses into every subjaxpr (pjit bodies, scan/while bodies,
cond branches, custom-derivative calls), tracking whether the current
scope is inside a device loop — that flag is what makes R4
("host callback *inside the decode loop*") precise instead of a blanket
callback ban.
"""

from __future__ import annotations

from typing import Iterator

import jax.numpy as jnp

from repro.check.diagnostics import Diagnostic, Severity

__all__ = ["iter_scopes", "jaxpr_r1", "jaxpr_r3", "jaxpr_r4", "jaxpr_r5"]

#: primitives that run a subjaxpr once per loop iteration
_LOOP_PRIMS = frozenset({"scan", "while", "fori_loop"})
#: host-callback primitives (any of these inside a loop is a per-iteration
#: host sync)
_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "callback",
    "host_callback", "outside_call",
})
#: scatter family — what a traced ``to_dense`` of an n:m layout lowers to
_SCATTER_PRIMS = frozenset({
    "scatter", "scatter-add", "scatter_add", "scatter-mul", "scatter_mul",
})
#: sinks allowed to consume a promoted value without tripping R3: matmul
#: accumulation and reductions legitimately widen (the kernels' own f32
#: accumulator contract); elementwise math in the wide dtype is the bug
_PROMOTE_SINKS = frozenset({
    "dot_general", "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
})
#: padding slack when matching a scatter output against a sparse weight's
#: dense shape (layouts pad R to the group row-sharing and K to the block
#: grid; both pads are bounded by one tile)
_PAD_SLACK = 256


def _subjaxprs(params: dict):
    """Every jaxpr-valued entry of an eqn's params (closed or open)."""
    for v in params.values():
        vals = v if isinstance(v, (tuple, list)) else (v,)
        for item in vals:
            if hasattr(item, "jaxpr"):       # ClosedJaxpr
                yield item.jaxpr
            elif hasattr(item, "eqns"):      # raw Jaxpr
                yield item


def iter_scopes(closed_jaxpr) -> Iterator[tuple]:
    """Yield ``(jaxpr, in_loop)`` for the top jaxpr and every subjaxpr,
    ``in_loop`` true once any enclosing primitive is a device loop."""
    seen = set()

    def walk(jaxpr, in_loop):
        if id(jaxpr) in seen:
            return
        seen.add(id(jaxpr))
        yield jaxpr, in_loop
        for eqn in jaxpr.eqns:
            sub_loop = in_loop or eqn.primitive.name in _LOOP_PRIMS
            for sub in _subjaxprs(eqn.params):
                yield from walk(sub, sub_loop)

    yield from walk(closed_jaxpr.jaxpr, False)


def _shape_matches_weight(shape, weights: dict):
    """Does a 2D scatter output look like a (padded) densified sparse
    weight?  Returns the matching weight path or None."""
    if len(shape) != 2:
        return None
    d0, d1 = int(shape[0]), int(shape[1])
    for path, w in weights.items():
        dense = getattr(w, "dense_shape", None) or getattr(w, "shape", None)
        if dense is None or len(dense) != 2:
            continue
        a, b = int(dense[0]), int(dense[1])
        for x, y in ((a, b), (b, a)):
            if x <= d0 <= x + _PAD_SLACK and y <= d1 <= y + _PAD_SLACK:
                return path
    return None


def jaxpr_r1(program) -> list:
    """Silent densify: a scatter whose output is shaped like a densified
    sparse weight, with a dense ``dot_general`` reachable downstream in
    the same scope — i.e. ``w.to_dense() @ x`` smuggled past the sparse
    kernels."""
    if program.jaxpr is None or not program.sparse_weights:
        return []
    diags = []
    for jaxpr, _ in iter_scopes(program.jaxpr):
        # sources: scatter outputs matching a sparse weight's dense shape
        sources = {}
        for eqn in jaxpr.eqns:
            if eqn.primitive.name not in _SCATTER_PRIMS:
                continue
            for outv in eqn.outvars:
                path = _shape_matches_weight(
                    getattr(outv.aval, "shape", ()), program.sparse_weights
                )
                if path is not None:
                    sources[id(outv)] = path
        if not sources:
            continue
        # forward dataflow: does any source reach a dot_general?
        tainted = dict(sources)
        for eqn in jaxpr.eqns:
            hit = next((tainted[id(v)] for v in eqn.invars
                        if id(v) in tainted), None)
            if hit is None:
                continue
            if eqn.primitive.name == "dot_general":
                diags.append(Diagnostic(
                    rule="R1", severity=Severity.ERROR, entry=program.name,
                    message=f"sparse weight {hit!r} is densified (scatter) "
                            f"and then contracted by a dense dot_general — "
                            f"the sparse fast path is silently bypassed",
                    op="dot_general", location="jaxpr",
                    fix="route the contraction through the registered "
                        "sparse op (models.common.mm / kernels.ops."
                        "nmg_linear) instead of w.to_dense() @ x",
                ))
                continue
            for outv in eqn.outvars:
                tainted[id(outv)] = hit
    return diags


def jaxpr_r3(program) -> list:
    """Dtype promotion past the model dtype on the decode path, outside the
    allowed accumulation sinks — what breaks the bitwise megakernel
    contract."""
    if program.jaxpr is None or not program.decode_path:
        return []
    model = jnp.dtype(program.model_dtype)
    diags = []
    for jaxpr, _ in iter_scopes(program.jaxpr):
        consumers: dict[int, list] = {}
        for eqn in jaxpr.eqns:
            for v in eqn.invars:
                if hasattr(v, "aval"):
                    consumers.setdefault(id(v), []).append(eqn.primitive.name)
        for eqn in jaxpr.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            outv = eqn.outvars[0]
            out_dt = jnp.dtype(outv.aval.dtype)
            if not jnp.issubdtype(out_dt, jnp.floating):
                continue
            if out_dt.itemsize <= model.itemsize:
                continue
            sinks = consumers.get(id(outv), [])
            if sinks and all(s in _PROMOTE_SINKS for s in sinks):
                continue    # f32 accumulation: the kernel contract itself
            diags.append(Diagnostic(
                rule="R3", severity=Severity.ERROR, entry=program.name,
                message=f"decode-path value promoted to {out_dt.name} past "
                        f"the model dtype {model.name} and consumed by "
                        f"{sorted(set(sinks)) or 'the program output'} — "
                        f"breaks the bitwise decode contract",
                op="convert_element_type", location="jaxpr",
                fix=f"keep elementwise math in {model.name}; widen only "
                    f"inside matmul/reduction accumulation",
            ))
    return diags


def jaxpr_r4(program) -> list:
    """Host callback inside a device loop: every iteration of the decode
    chunk (or training scan) would synchronize with the host."""
    if program.jaxpr is None:
        return []
    diags = []
    for jaxpr, in_loop in iter_scopes(program.jaxpr):
        if not in_loop:
            continue
        for eqn in jaxpr.eqns:
            if eqn.primitive.name in _CALLBACK_PRIMS:
                diags.append(Diagnostic(
                    rule="R4", severity=Severity.ERROR, entry=program.name,
                    message="host callback inside a device loop — one "
                            "host round-trip per iteration defeats the "
                            "chunked (device-resident) decode/train loop",
                    op=eqn.primitive.name, location="jaxpr:loop-body",
                    fix="hoist the callback out of the scan/while body, or "
                        "accumulate on device and fetch once per chunk",
                ))
    return diags


def jaxpr_r5(program) -> list:
    """Recompile hazard: weak-typed program inputs/outputs.  A weak-typed
    argument retraces when the caller's Python literal changes flavor,
    fragmenting the jit cache the engine relies on compiling exactly
    once."""
    if program.jaxpr is None:
        return []
    diags = []
    jaxpr = program.jaxpr.jaxpr
    for role, vs in (("input", jaxpr.invars), ("output", jaxpr.outvars)):
        for v in vs:
            aval = getattr(v, "aval", None)
            if aval is not None and getattr(aval, "weak_type", False):
                diags.append(Diagnostic(
                    rule="R5", severity=Severity.WARNING, entry=program.name,
                    message=f"weak-typed {role} "
                            f"({getattr(aval, 'dtype', '?')}) — Python "
                            f"scalars leak into the traced signature and "
                            f"fragment the jit cache",
                    op=role, location="jaxpr:signature",
                    fix="pass numpy/jnp arrays with explicit dtypes "
                        "instead of Python scalars",
                ))
    return diags

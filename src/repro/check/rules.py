"""Rule registry: R1-R6 (plus R7, the device-model warning) as typed
:class:`Rule` records binding an id, severity, description, and the
detector functions from the jaxpr / HLO / trace-evidence passes.

Every rule registered here must have a triggering and a clean fixture in
``repro.check.fixtures`` — ``tests/test_check_meta.py`` enforces that, so
a new rule cannot land silently untested.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from repro.check import hlo_pass, jaxpr_pass, static_pass
from repro.check.diagnostics import Diagnostic, Severity

__all__ = ["Rule", "all_rules", "run_rules", "register_rule"]


@dataclasses.dataclass(frozen=True)
class Rule:
    rule_id: str
    name: str
    severity: Severity
    description: str
    detectors: tuple     # each: CheckedProgram -> list[Diagnostic]


_RULES: dict[str, Rule] = {}


def register_rule(rule_id: str, name: str, severity: Severity,
                  description: str, detectors: Sequence[Callable]) -> Rule:
    if rule_id in _RULES:
        raise ValueError(f"duplicate rule {rule_id}")
    rule = Rule(rule_id, name, severity, description, tuple(detectors))
    _RULES[rule_id] = rule
    return rule


def all_rules() -> dict[str, Rule]:
    return dict(_RULES)


def run_rules(program, rules: Sequence[str] | None = None
              ) -> list[Diagnostic]:
    """Run every registered rule (or the named subset) over one program."""
    out: list[Diagnostic] = []
    for rid in sorted(rules or _RULES):
        for detect in _RULES[rid].detectors:
            out.extend(detect(program))
    return out


register_rule(
    "R1", "silent-densify", Severity.ERROR,
    "A GroupedNM/FixedMask operand reaches a dense dot/einsum without an "
    "explicit densify site: dispatcher fallback counters, jaxpr "
    "scatter-to-dot reachability, and the same check on the compiled HLO.",
    (static_pass.static_r1, jaxpr_pass.jaxpr_r1, hlo_pass.hlo_r1),
)
register_rule(
    "R2", "conversion-churn", Severity.WARNING,
    "The same weight is converted between layouts more than once per "
    "traced program.",
    (static_pass.static_r2,),
)
register_rule(
    "R3", "dtype-promotion", Severity.ERROR,
    "An op on the decode path promotes past the model dtype outside "
    "matmul/reduction accumulation, breaking the bitwise decode contract.",
    (jaxpr_pass.jaxpr_r3, hlo_pass.hlo_r3),
)
register_rule(
    "R4", "host-sync-in-loop", Severity.ERROR,
    "A host callback (or host custom-call) lives inside the lax.scan / "
    "while decode chunk — one host round-trip per iteration.",
    (jaxpr_pass.jaxpr_r4, hlo_pass.hlo_r4),
)
register_rule(
    "R5", "recompile-hazard", Severity.WARNING,
    "Weak-typed program inputs/outputs fragment the jit cache on retrace.",
    (jaxpr_pass.jaxpr_r5,),
)
register_rule(
    "R6", "vmem-overrun", Severity.ERROR,
    "The routed Pallas (tm/tn, target_depth, stream) config's estimated "
    "per-grid-step working set exceeds the per-device VMEM budget.",
    (static_pass.static_r6,),
)
register_rule(
    "R7", "unmodelled-device", Severity.WARNING,
    "The running device kind has no HW_BY_KIND entry; budgets and "
    "roofline terms are modelled against TPU v5e constants.",
    (static_pass.static_r7,),
)

"""Trace-evidence detectors (dispatch counters, conversion log, routed
VMEM estimates, device-kind budgets): R1's counter half, R2, R6, R7.

The VMEM estimators mirror the block shapes of ``kernels/nmg_gemv.py``
and ``kernels/nmg_spmm.py`` exactly — per grid step, the operand tiles +
output tile + scratch a routed ``(tm | tn, target_depth, stream)`` config
makes resident — and compare them against the per-device budget in
``launch/hlo_analysis.HW_BY_KIND``.  An oversized tuned tile is caught
*here*, before a real-TPU run hits the Mosaic allocator.
"""

from __future__ import annotations

import collections
import math

import jax.numpy as jnp

from repro.check.diagnostics import Diagnostic, Severity
from repro.launch.hlo_analysis import hw_for_device
from repro.tune import routing

__all__ = ["static_r1", "static_r2", "static_r6", "static_r7",
           "gemv_vmem", "spmm_vmem"]


def static_r1(program) -> list:
    """Dense-fallback traces recorded by the dispatcher while this program
    traced: a sparse layout was materialized for a reference dense op."""
    diags = []
    for (outcome, op, sig), count in sorted(program.fallbacks.items()):
        if outcome != "dense_fallback":
            continue
        diags.append(Diagnostic(
            rule="R1", severity=Severity.ERROR, entry=program.name,
            message=f"dispatcher fell back to the dense implementation of "
                    f"{op!r} for signature {list(sig)} ({count} trace(s)) "
                    f"— the sparse operand was silently densified",
            op=op, location="dispatch-counters",
            fix=f"register a sparse implementation for ({op}, "
                f"{list(sig)}) or convert the operand to a supported "
                f"layout before the call",
        ))
    return diags


def static_r2(program) -> list:
    """Conversion churn: the same (layout -> layout, shape) conversion ran
    more than once while tracing one program — each repeat re-materializes
    and re-compresses the same weight."""
    counts = collections.Counter(
        (src, dst, shape) for src, dst, shape in program.conversions
        if src != "DenseTensor"
    )
    diags = []
    for (src, dst, shape), n in sorted(counts.items()):
        if n <= 1:
            continue
        diags.append(Diagnostic(
            rule="R2", severity=Severity.WARNING, entry=program.name,
            message=f"{src} -> {dst} conversion of shape {list(shape)} ran "
                    f"{n}x in one traced program — convert once and reuse "
                    f"the converted layout",
            op=f"{src}->{dst}", location="conversion-log",
            fix="hoist the conversion out of the traced function (convert "
                "at load/sparsify time, not per call)",
        ))
    return diags


# ---------------------------------------------------------------------------
# R6: routed-config VMEM working sets (mirrors the Pallas block shapes)
# ---------------------------------------------------------------------------


def _fmt_ctx(w, dtype) -> dict:
    sd = w.sparse_dim % 2
    return dict(K=int(w.dense_shape[sd]), R=int(w.dense_shape[1 - sd]),
                fmt=(w.n, w.m, w.g), gr=w.gr, dtype=jnp.dtype(dtype))


def gemv_vmem(w, dtype, M: int, device_kind: str, *, weight: str = "") -> dict:
    """Per-grid-step VMEM bytes of the routed decode GEMV config: index
    slab + value tile + gathered-B tile (CG*m x M_pad) + output tile +
    f32 accumulator scratch (the ``nmg_gemv_pallas`` block shapes)."""
    ctx = _fmt_ctx(w, dtype)
    cfg, src = routing.gemv_pallas_config(**ctx)
    n, m, g = ctx["fmt"]
    gr = ctx["gr"]
    cg = math.comb(m, n) * g
    tm = int(cfg["tm"])
    m_pad = M + (-M) % tm
    vb = jnp.dtype(dtype).itemsize
    nbytes = (cg * 4                      # SMEM pattern indices
              + gr * cg * n * vb          # value tile
              + cg * m * m_pad * vb       # gathered B tile
              + gr * m_pad * vb           # output tile
              + gr * m_pad * 4)           # f32 accumulator scratch
    hw, _ = hw_for_device(device_kind)
    return {"kernel": "nmg_gemv", "weight": weight, "config": dict(cfg),
            "source": src, "M": int(M), "bytes": int(nbytes),
            "budget": int(hw["vmem_bytes"]), "device": device_kind}


def spmm_vmem(w, dtype, N: int, device_kind: str, *, weight: str = "") -> dict:
    """Per-grid-step VMEM bytes of the routed prefill SpMM config.  The
    streamed schedule keeps a full K_pad x tn B slab resident plus the
    double-buffered value scratch; the grid schedule tiles B per chunk."""
    ctx = _fmt_ctx(w, dtype)
    cfg, src = routing.spmm_pallas_config(**ctx)
    n, m, g = ctx["fmt"]
    gr = ctx["gr"]
    cg = math.comb(m, n) * g
    tn = min(int(cfg["tn"]), N + (-N) % 128)
    k_pad = ctx["K"] + (-ctx["K"]) % (m * g)
    vb = jnp.dtype(dtype).itemsize
    if cfg.get("stream", True):
        nbytes = (k_pad * tn * vb           # resident B slab
                  + 2 * gr * cg * n * vb    # double-buffered value scratch
                  + gr * tn * 4)            # f32 output tile
    else:
        nbytes = (cg * m * tn * vb          # per-chunk B tile
                  + gr * cg * n * vb        # value tile
                  + gr * tn * 4)
    hw, _ = hw_for_device(device_kind)
    return {"kernel": "nmg_spmm", "weight": weight, "config": dict(cfg),
            "source": src, "N": int(N), "bytes": int(nbytes),
            "budget": int(hw["vmem_bytes"]), "device": device_kind}


def static_r6(program) -> list:
    """Routed Pallas working set exceeds the per-device VMEM budget."""
    diags = []
    for est in program.vmem_estimates:
        if est["bytes"] <= est["budget"]:
            continue
        diags.append(Diagnostic(
            rule="R6", severity=Severity.ERROR, entry=program.name,
            message=f"routed {est['kernel']} config {est['config']} "
                    f"(source: {est['source']}) for weight "
                    f"{est['weight'] or '?'} needs "
                    f"~{est['bytes'] / 2**20:.1f} MiB VMEM per grid step — "
                    f"budget is {est['budget'] / 2**20:.0f} MiB on "
                    f"{est['device']}",
            op=est["kernel"], location="vmem-estimate",
            fix="shrink the tuned tile (tm/tn/target_depth) for this shape "
                "bucket, or regenerate the tuning table on this device",
        ))
    return diags


def static_r7(program) -> list:
    """Device kind with no modelled HW entry: roofline terms and VMEM
    budgets silently fall back to the TPU v5e numbers (warning — the run
    still works, the *model* is what's off)."""
    _, matched = hw_for_device(program.device_kind)
    if matched:
        return []
    return [Diagnostic(
        rule="R7", severity=Severity.WARNING, entry=program.name,
        message=f"device kind {program.device_kind!r} has no entry in "
                f"HW_BY_KIND — VMEM budgets and roofline terms are "
                f"modelled against the TPU v5e constants",
        op=program.device_kind, location="hw-model",
        fix="add this device kind to launch/hlo_analysis.HW_BY_KIND",
    )]

"""Explain/differential mode: static route predictions vs runtime
counters.

``kernels/ops.py:predict_route`` mirrors the router's branch logic
without tracing anything; this module runs a quick engine warmup (the
same ``warmup_engine`` hook the benchmarks use) over prompt lengths that
straddle the gemv/spmm crossover, then cross-checks the predicted
``kernel_counters`` keys against what the traces actually recorded.  Any
disagreement is an ERROR: either the predictor (and therefore the
checker's static story) or the router itself is wrong, and both are
load-bearing.
"""

from __future__ import annotations

import importlib

import jax
import numpy as np

from repro.check.diagnostics import Diagnostic, Severity
from repro.check.entries import CHECK_GR, CHECK_NM, check_config

__all__ = ["differential_check"]

#: kernels whose (kernel, path) keys the router itself emits — the
#: comparison surface (inner pallas/xla keys ride along with these)
_ROUTED = ("nmg_linear", "nmg_ffn", "nmg_qkv")


def _predicted_keys(cfg, sparse_params, widths) -> set:
    """Every (kernel, path) key the router should record when the engine
    traces each sparse weight at each activation width."""
    from repro.core.layouts import GroupedNMTensor
    kops = importlib.import_module("repro.kernels.ops")

    leaves = jax.tree_util.tree_flatten_with_path(
        sparse_params, is_leaf=lambda x: isinstance(x, GroupedNMTensor)
    )[0]
    keys: set = set()
    for path, w in leaves:
        if not isinstance(w, GroupedNMTensor):
            continue
        name = jax.tree_util.keystr(path)
        gated_wi = cfg.gated_mlp and "wi" in name
        for m_width in widths:
            op = "mm_gated" if gated_wi else "nmg_linear"
            keys.update(kops.predict_route(op, w, M=m_width,
                                           dtype=cfg.jdtype))
    return {k for k in keys if k[0] in _ROUTED}


def differential_check(*, arch: str = "bert-base-sten",
                       prompt_lens: tuple = (24, 8), max_slots: int = 4,
                       seed: int = 0) -> tuple[list, dict]:
    """-> (diagnostics, detail).  Empty diagnostics means every routed op
    agreed between the static prediction and the runtime counters."""
    from repro.models import init_lm
    from repro.serve import Request, SamplingParams
    from repro.serve.engine import sparsify_for_serving, warmup_engine

    disp = importlib.import_module("repro.core.dispatch")
    kops = importlib.import_module("repro.kernels.ops")

    cfg = check_config(arch)
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    n, m, g = CHECK_NM
    sparse = sparsify_for_serving(params, n, m, g, gr=CHECK_GR)

    # decode always runs at the full slot batch; prefill at each prompt len
    widths = sorted({max_slots, *prompt_lens})
    predicted = _predicted_keys(cfg, sparse, widths)

    rng = np.random.default_rng(seed)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab, size=plen,
                                           dtype=np.int32),
                max_new_tokens=2, sampling=SamplingParams(greedy=True))
        for i, plen in enumerate(prompt_lens)
    ]
    kern_before = kops.kernel_counters()
    disp_before = disp.dispatch_counters()
    warmup_engine(sparse, cfg, reqs, engine_kwargs=dict(
        max_slots=max_slots, max_seq_len=max(prompt_lens) + 16,
        decode_chunk=4,
    ))
    observed = {
        k for k, v in kops.kernel_counters().items()
        if v > kern_before.get(k, 0) and k[0] in _ROUTED
    }
    fallbacks = {
        k: v - disp_before.get(k, 0)
        for k, v in disp.dispatch_counters().items()
        if v > disp_before.get(k, 0) and k[0] == "dense_fallback"
    }

    diags = []
    entry = f"{arch}/differential"
    for key in sorted(predicted - observed):
        diags.append(Diagnostic(
            rule="DIFF", severity=Severity.ERROR, entry=entry,
            message=f"predict_route expected counter {key} but the warmup "
                    f"never recorded it — the static route model is ahead "
                    f"of the runtime router",
            op=str(key), location="kernel-counters",
            fix="align kernels.ops.predict_route with the routing branch "
                "it mirrors",
        ))
    for key in sorted(observed - predicted):
        diags.append(Diagnostic(
            rule="DIFF", severity=Severity.ERROR, entry=entry,
            message=f"runtime recorded counter {key} that predict_route "
                    f"did not predict — the router took a path the static "
                    f"model does not know about",
            op=str(key), location="kernel-counters",
            fix="align kernels.ops.predict_route with the routing branch "
                "it mirrors",
        ))
    for key, count in sorted(fallbacks.items()):
        diags.append(Diagnostic(
            rule="DIFF", severity=Severity.ERROR, entry=entry,
            message=f"warmup traced through the dense fallback {key} "
                    f"({count}x) — the quick run is not on the sparse "
                    f"fast path at all",
            op=str(key), location="dispatch-counters",
        ))
    detail = {
        "predicted": sorted(map(str, predicted)),
        "observed": sorted(map(str, observed)),
        "widths": widths,
        "agree": not diags,
    }
    return diags, detail

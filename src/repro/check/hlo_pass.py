"""Compiled-HLO detectors: the post-XLA halves of R1/R3/R4.

These reuse ``launch/hlo_analysis.py``'s module parser, so the checker
sees the program exactly as the structural roofline analyzer does —
computations, instructions, while bodies, fusion calls.  The HLO pass
catches what fusion/DCE could *introduce or fail to remove* after the
jaxpr level: a densified weight that survived to a real ``dot``, a host
custom-call living inside a compiled ``while`` body, and any f64 the
backend materialized.
"""

from __future__ import annotations

import re

from repro.check.diagnostics import Diagnostic, Severity
from repro.launch import hlo_analysis as H

__all__ = ["hlo_r1", "hlo_r3", "hlo_r4"]

_PAD_SLACK = 256

#: custom-call targets that bounce through the host
_HOST_CALL_RE = re.compile(
    r"custom_call_target=\"[^\"]*(callback|host|infeed|outfeed)[^\"]*\"",
    re.IGNORECASE,
)
_DIMS_RE = re.compile(r"\w+\[([\d,]*)\]")


def _result_dims(type_text: str):
    m = _DIMS_RE.search(type_text)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(1).split(",") if d.strip())


def _weight_match(dims, weights: dict):
    if len(dims) != 2:
        return None
    d0, d1 = dims
    for path, w in weights.items():
        dense = getattr(w, "dense_shape", None) or getattr(w, "shape", None)
        if dense is None or len(dense) != 2:
            continue
        a, b = int(dense[0]), int(dense[1])
        for x, y in ((a, b), (b, a)):
            if x <= d0 <= x + _PAD_SLACK and y <= d1 <= y + _PAD_SLACK:
                return path
    return None


def hlo_r1(program) -> list:
    """Densified-weight-reaches-dot, post-compilation: a ``scatter`` whose
    result is shaped like a densified sparse weight flowing (within its
    computation) into a ``dot``."""
    if not program.hlo_text or not program.sparse_weights:
        return []
    comps, shapes, _, _ = H.parse_module(program.hlo_text)
    diags = []
    for cname, insts in comps.items():
        tainted = {}
        for inst in insts:
            if inst.op == "scatter":
                path = _weight_match(_result_dims(inst.type_text),
                                     program.sparse_weights)
                if path is not None:
                    tainted[inst.name] = path
                    continue
            hit = next((tainted[o] for o in H.inst_operands(inst)
                        if o in tainted), None)
            if hit is None:
                continue
            if inst.op == "dot":
                diags.append(Diagnostic(
                    rule="R1", severity=Severity.ERROR, entry=program.name,
                    message=f"compiled module contracts a scatter-densified "
                            f"copy of sparse weight {hit!r} with a dense "
                            f"dot — densification survived to the backend",
                    op=f"dot in %{cname}", location="hlo",
                    fix="route the contraction through the registered "
                        "sparse op instead of densifying the weight",
                ))
            else:
                tainted[inst.name] = hit
    return diags


def hlo_r3(program) -> list:
    """f64 materialized anywhere in the compiled module: with x64 disabled
    this should be unreachable, so its presence means a double-precision
    literal or numpy scalar leaked into the decode program."""
    if not program.hlo_text or not program.decode_path:
        return []
    comps, _, _, _ = H.parse_module(program.hlo_text)
    diags = []
    for cname, insts in comps.items():
        for inst in insts:
            if inst.op in ("parameter", "constant"):
                continue
            if "f64[" in inst.type_text:
                diags.append(Diagnostic(
                    rule="R3", severity=Severity.ERROR, entry=program.name,
                    message="compiled decode program materializes f64 — a "
                            "double-precision value leaked past the model "
                            "dtype",
                    op=f"{inst.op} in %{cname}", location="hlo",
                    fix="cast host-side inputs/literals to the model dtype "
                        "before tracing",
                ))
                return diags      # one finding is enough evidence
    return diags


def hlo_r4(program) -> list:
    """Host custom-call inside a compiled ``while`` body: the compiled
    decode chunk would synchronize with the host every iteration."""
    if not program.hlo_text:
        return []
    comps, _, _, _ = H.parse_module(program.hlo_text)
    # computations reachable from a while body/condition
    loop_comps: set[str] = set()
    stack = []
    for insts in comps.values():
        for inst in insts:
            if inst.op == "while":
                for m in re.finditer(r"(?:body|condition)=%([\w\.\-]+)",
                                     inst.line):
                    stack.append(m.group(1))
    while stack:
        name = stack.pop()
        if name in loop_comps or name not in comps:
            continue
        loop_comps.add(name)
        for inst in comps[name]:
            for m in re.finditer(
                r"(?:calls|to_apply|body|condition)=%([\w\.\-]+)", inst.line
            ):
                stack.append(m.group(1))
    diags = []
    for name in sorted(loop_comps):
        for inst in comps[name]:
            if inst.op == "custom-call" and _HOST_CALL_RE.search(inst.line):
                diags.append(Diagnostic(
                    rule="R4", severity=Severity.ERROR, entry=program.name,
                    message="host custom-call inside a compiled while body "
                            "— per-iteration host sync in the device loop",
                    op=f"custom-call in %{name}", location="hlo:while-body",
                    fix="hoist the callback out of the loop body",
                ))
    return diags

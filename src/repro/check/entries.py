"""Entry-point builders: the real serving/training programs as
:class:`~repro.check.program.CheckedProgram` lists.

The checker traces the *same callables the runtime compiles* —
``serve/engine.py:serve_programs`` for the engine's decode / chunked
decode / prefill, and ``launch/train.py:make_train_step`` for training —
at the smoke scale (CPU-tractable; the program *structure* is what the
rules inspect, and it is scale-invariant).  Check configs pin
``dtype=float32``: with x64 disabled f32 is the widest reachable float,
so any R3 hit is a genuine promotion bug rather than bf16 noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.check.program import CheckedProgram, build_program
from repro.configs import get_smoke

__all__ = ["ENTRY_NAMES", "CHECK_NM", "CHECK_GR", "check_config",
           "entry_programs"]

ENTRY_NAMES = ("serve", "decode", "prefill", "train")

#: n:m:g format + row sharing the check entries sparsify with (the fig11
#: serving format family)
CHECK_NM = (1, 4, 8)
CHECK_GR = 64

#: engine knobs the serve entries are traced at
CHECK_MAX_SLOTS = 4
CHECK_MAX_SEQ = 64
CHECK_DECODE_CHUNK = 4
CHECK_PROMPT_LEN = 24     # > DECODE_M_MAX so prefill exercises the SpMM path


def check_config(arch: str = "bert-base-sten"):
    """The smoke-scaled config the checker traces entries at, pinned to
    float32 (see module docstring)."""
    return get_smoke(arch).scaled(dtype="float32")


def _serve_programs(arch: str, hlo: bool) -> list[CheckedProgram]:
    from repro.serve.engine import serve_programs, sparsify_for_serving

    cfg = check_config(arch)
    params = init_params(cfg)
    n, m, g = CHECK_NM
    sparse = sparsify_for_serving(params, n, m, g, gr=CHECK_GR)
    progs = serve_programs(
        sparse, cfg, max_slots=CHECK_MAX_SLOTS, max_seq_len=CHECK_MAX_SEQ,
        decode_chunk=CHECK_DECODE_CHUNK, prompt_len=CHECK_PROMPT_LEN,
    )
    out = []
    for pname, (fn, args) in progs.items():
        decode = pname.startswith("decode")
        out.append(build_program(
            f"{arch}/serve:{pname}", fn, args, model_dtype=cfg.jdtype,
            decode_path=True, hlo=hlo,
            decode_m=CHECK_MAX_SLOTS if decode else None,
            prefill_n=None if decode else CHECK_PROMPT_LEN,
        ))
    return out


def _train_programs(arch: str, hlo: bool) -> list[CheckedProgram]:
    from repro.launch.train import build_sparse_params, make_train_step
    from repro.optim import AdamWConfig, adamw_init

    cfg = check_config(arch)
    params = build_sparse_params(init_params(cfg), 0.5)
    opt_state = adamw_init(params)
    step = make_train_step(cfg, AdamWConfig(lr=1e-3))
    batch = {
        "tokens": jnp.asarray(np.zeros((2, 16), np.int32)),
        "labels": jnp.asarray(np.zeros((2, 16), np.int32)),
    }
    return [build_program(
        f"{arch}/train:step", step, (params, opt_state, batch),
        model_dtype=cfg.jdtype, decode_path=False, hlo=hlo,
        prefill_n=16,
    )]


def init_params(cfg):
    from repro.models import init_lm

    return init_lm(jax.random.PRNGKey(0), cfg)


def entry_programs(entry: str, *, arch: str = "bert-base-sten",
                   hlo: bool = True) -> list[CheckedProgram]:
    """Build the CheckedPrograms of one ``--entry`` for one config."""
    if entry == "train":
        return _train_programs(arch, hlo)
    if entry not in ENTRY_NAMES:
        raise ValueError(f"unknown entry {entry!r}; pick from {ENTRY_NAMES}")
    progs = _serve_programs(arch, hlo)
    if entry == "decode":
        return [p for p in progs if ":decode" in p.name]
    if entry == "prefill":
        return [p for p in progs if ":prefill" in p.name]
    return progs

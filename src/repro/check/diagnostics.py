"""Typed diagnostics model for the static sparse-program verifier.

A :class:`Diagnostic` is one finding: which rule fired, how severe it is,
which entry program it came from, the offending op/instruction, and a fix
hint.  A :class:`Report` aggregates them across programs, handles
suppression (``--ignore R2`` / ``--ignore R2:train*``), renders the
human-readable listing, serializes to JSON (``--json``), and converts to
a shell exit code (errors always fail; warnings fail under ``--strict``).
"""

from __future__ import annotations

import dataclasses
import enum
import fnmatch
from typing import Iterable, Optional

__all__ = ["Severity", "Diagnostic", "Report"]


class Severity(enum.IntEnum):
    """Ordered so max() over diagnostics picks the worst finding."""

    WARNING = 1
    ERROR = 2

    @property
    def label(self) -> str:
        return self.name.lower()


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One finding of one rule against one checked program."""

    rule: str                      # "R1".."R6", "DIFF"
    severity: Severity
    entry: str                     # program name, e.g. "serve:decode"
    message: str                   # what is wrong
    op: Optional[str] = None       # source op / HLO instruction / counter key
    location: Optional[str] = None  # e.g. "jaxpr:scan", "hlo:while_body"
    fix: Optional[str] = None      # how to make the rule pass

    def render(self) -> str:
        where = f" [{self.location}]" if self.location else ""
        opp = f" ({self.op})" if self.op else ""
        hint = f"\n    fix: {self.fix}" if self.fix else ""
        return (f"{self.severity.label}[{self.rule}] {self.entry}{where}: "
                f"{self.message}{opp}{hint}")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["severity"] = self.severity.label
        return d


def _suppressed(diag: Diagnostic, ignore: Iterable[str]) -> bool:
    """``ignore`` tokens are ``RULE`` (suppress everywhere) or
    ``RULE:entry-glob`` (suppress where the entry name matches the glob;
    a bare substring also matches)."""
    for token in ignore:
        rule, _, pat = token.partition(":")
        if rule != diag.rule:
            continue
        if not pat or fnmatch.fnmatch(diag.entry, pat) or pat in diag.entry:
            return True
    return False


class Report:
    """Aggregated diagnostics across every checked program."""

    def __init__(self, diagnostics: Iterable[Diagnostic] = ()):
        self.diagnostics: list[Diagnostic] = list(diagnostics)
        self.programs: list[str] = []      # every program that was checked

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def filtered(self, ignore: Iterable[str]) -> "Report":
        out = Report(d for d in self.diagnostics
                     if not _suppressed(d, ignore))
        out.programs = list(self.programs)
        return out

    @property
    def errors(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity >= Severity.ERROR]

    @property
    def warnings(self) -> list[Diagnostic]:
        return [d for d in self.diagnostics if d.severity < Severity.ERROR]

    def exit_code(self, strict: bool = False) -> int:
        if self.errors:
            return 1
        if strict and self.warnings:
            return 1
        return 0

    def to_json(self) -> dict:
        return {
            "programs": list(self.programs),
            "errors": len(self.errors),
            "warnings": len(self.warnings),
            "diagnostics": [d.to_dict() for d in self.diagnostics],
        }

    def render(self) -> str:
        lines = [d.render() for d in sorted(
            self.diagnostics, key=lambda d: (-d.severity, d.rule, d.entry)
        )]
        return "\n".join(lines)

    def summary(self) -> str:
        return (f"{len(self.programs)} program(s) checked: "
                f"{len(self.errors)} error(s), "
                f"{len(self.warnings)} warning(s)")

"""CLI: ``python -m repro.check`` — see package docstring."""

from __future__ import annotations

import argparse
import json
import sys

from repro.check import run_check
from repro.check.entries import ENTRY_NAMES


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="Static sparse-program verifier (jaxpr + HLO lint).",
    )
    p.add_argument("--entry", action="append", choices=ENTRY_NAMES,
                   help="entry point(s) to check (default: serve + train)")
    p.add_argument("--config", action="append",
                   help="model config name(s) (default: bert-base-sten)")
    p.add_argument("--strict", action="store_true",
                   help="exit nonzero on warnings too, not just errors")
    p.add_argument("--json", metavar="PATH",
                   help="write the full diagnostic report as JSON")
    p.add_argument("--ignore", action="append", default=[],
                   metavar="RULE[:entry-glob]",
                   help="suppress a rule, optionally only for matching "
                        "entries (e.g. R5 or R2:*/train:*)")
    p.add_argument("--differential", action="store_true",
                   help="also cross-check static route predictions against "
                        "runtime kernel counters from a quick engine warmup")
    p.add_argument("--no-hlo", action="store_true",
                   help="skip compiling entries to HLO (jaxpr passes only)")
    args = p.parse_args(argv)

    entries = tuple(args.entry or ("serve", "train"))
    configs = tuple(args.config or ("bert-base-sten",))

    reports = []
    for arch in configs:
        reports.append(run_check(
            entries, arch=arch, hlo=not args.no_hlo,
            differential=args.differential, ignore=tuple(args.ignore),
        ))

    merged = reports[0]
    for r in reports[1:]:
        merged.programs.extend(r.programs)
        merged.extend(r.diagnostics)

    rendered = merged.render()
    if rendered:
        print(rendered)
    print(merged.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(merged.to_json(), f, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return merged.exit_code(strict=args.strict)


if __name__ == "__main__":
    sys.exit(main())

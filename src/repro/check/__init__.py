"""repro.check — static sparse-program verifier.

Proves the fast path before it runs: traces the real serve/train entry
callables to jaxprs (and optionally compiled HLO), runs the R1-R7 rule
passes over them, and cross-checks static route predictions against
runtime kernel counters (``--differential``).

CLI::

    python -m repro.check [--entry serve|decode|prefill|train]...
                          [--config NAME]... [--strict] [--json PATH]
                          [--ignore RULE[:entry-glob]]... [--differential]
                          [--no-hlo]
"""

from __future__ import annotations

from repro.check.diagnostics import Diagnostic, Report, Severity
from repro.check.rules import Rule, all_rules, run_rules

__all__ = ["Diagnostic", "Report", "Severity", "Rule", "all_rules",
           "run_rules", "run_check", "preflight"]


def run_check(entries, *, arch: str = "bert-base-sten", hlo: bool = True,
              differential: bool = False, ignore=()) -> Report:
    """Build the entry programs, run every rule over each, and (optionally)
    the static-vs-runtime differential.  Returns the filtered Report."""
    from repro.check.entries import entry_programs

    report = Report()
    seen: set = set()
    for entry in entries:
        for program in entry_programs(entry, arch=arch, hlo=hlo):
            if program.name in seen:
                continue
            seen.add(program.name)
            report.programs.append(program.name)
            report.extend(run_rules(program))
    if differential:
        from repro.check.differential import differential_check

        diags, _ = differential_check(arch=arch)
        report.programs.append(f"{arch}/differential")
        report.extend(diags)
    return report.filtered(ignore)


def preflight(entries, *, arch: str = "bert-base-sten") -> int:
    """Opt-in ``--check`` hook for launch/serve.py and launch/train.py:
    fast (no-HLO) pass over the given entries, report to stdout, return a
    process exit code (nonzero only on ERROR diagnostics)."""
    report = run_check(entries, arch=arch, hlo=False)
    rendered = report.render()
    if rendered:
        print(rendered)
    print(report.summary())
    return report.exit_code(strict=False)

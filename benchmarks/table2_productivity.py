"""Paper Table 2: sparsifier productivity — accuracy after fine-tuning to
50% sparsity with one-shot / iterative / layer-wise magnitude pruning, and
the lines of code each schedule needed on top of the shared setup.

The three schedules are implemented below in their entirety so the LoC
numbers are measured from this file (inspect.getsource), mirroring the
paper's methodology.
"""

import functools
import inspect

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import FixedMaskTensor
from repro.core.sparsifiers import ScalarFractionSparsifier
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_lm, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    value_and_grad_sparse
from repro.optim.sparse_update import resparsify_params


# --- shared sparsification setup (counted once, like the paper's 112 LoC) --

def sparsify_at(params, sparsity):
    sb = SparsityBuilder()
    sb.set_weight("*mlp.w*", ScalarFractionSparsifier(sparsity),
                  FixedMaskTensor)
    sb.set_weight("*attn.w*", ScalarFractionSparsifier(sparsity),
                  FixedMaskTensor)
    return sb.sparsify_params(params)


def retarget(params, sparsity):
    sp = ScalarFractionSparsifier(sparsity)

    def visit(leaf):
        if isinstance(leaf, FixedMaskTensor):
            mask = sp.mask(leaf.val)
            return FixedMaskTensor(leaf.val * mask, mask, leaf.origin)
        return leaf

    return jax.tree_util.tree_map(
        visit, params, is_leaf=lambda x: isinstance(x, FixedMaskTensor))


def retarget_layers(params, sparsity, n_layers):
    """Sparsify only the first ``n_layers`` of the stacked weights."""
    sp = ScalarFractionSparsifier(sparsity)

    def visit(leaf):
        if isinstance(leaf, FixedMaskTensor) and leaf.val.ndim == 3:
            mask = sp.mask(leaf.val)
            layer_on = (jnp.arange(leaf.val.shape[0]) < n_layers)
            mask = jnp.where(layer_on[:, None, None], mask, True)
            return FixedMaskTensor(leaf.val * mask, mask, leaf.origin)
        return leaf

    return jax.tree_util.tree_map(
        visit, params, is_leaf=lambda x: isinstance(x, FixedMaskTensor))


# --- the three schedules (LoC measured per function) ------------------------

def one_shot(params, train, steps):
    params = sparsify_at(params, 0.5)
    return train(params, steps)


def iterative(params, train, steps):
    params = sparsify_at(params, 0.1)
    for i, s in enumerate((0.1, 0.2, 0.3, 0.4, 0.5)):
        params = retarget(params, s)
        params = train(params, steps // 5, t0=i * steps // 5)
    return params


def layer_wise(params, train, steps, n_layers=2):
    params = sparsify_at(params, 0.5)
    for i in range(n_layers):
        params = retarget_layers(params, 0.5, i + 1)
        params = train(params, steps // n_layers,
                       t0=i * steps // n_layers)
    return params


def main(steps=60, quick=False):
    if quick:
        steps = 30
    cfg = get_smoke("bert-base-sten")
    key = jax.random.PRNGKey(0)
    opt_cfg = AdamWConfig(lr=1e-3)
    data = SyntheticLMPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                          global_batch=8, seed=0))

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def jstep(params, state, batch):
        (loss, _), g = value_and_grad_sparse(
            lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
        )(params)
        p2, s2, _ = adamw_update(g, state, params, opt_cfg)
        return resparsify_params(p2), s2, loss

    def train(params, n, t0=0):
        state = adamw_init(params)
        for i in range(n):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(t0 + i).items()}
            params, state, loss = jstep(params, state, b)
        train.last_loss = float(loss)
        return params

    def eval_loss(params):
        b = {k: jnp.asarray(v) for k, v in data.batch_at(9999).items()}
        return float(loss_fn(params, cfg, b, remat="none")[0])

    base = init_lm(key, cfg)
    dense = train(jax.tree_util.tree_map(jnp.copy, base), steps)
    print("sparsifier,eval_loss,loc_added")
    print(f"dense,{eval_loss(dense):.4f},-")
    setup_loc = sum(
        len(inspect.getsource(f).splitlines())
        for f in (sparsify_at, retarget, retarget_layers)
    )
    print(f"sparsification_setup,-,{setup_loc}")
    for fn in (one_shot, iterative, layer_wise):
        # deep copy: the jitted step donates its inputs
        p = fn(jax.tree_util.tree_map(jnp.copy, dense), train, steps)
        loc = len(inspect.getsource(fn).splitlines())
        print(f"{fn.__name__},{eval_loss(p):.4f},{loc}")


if __name__ == "__main__":
    main()

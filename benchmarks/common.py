"""Shared timing utilities for the benchmark suite."""

import time

import jax

from repro.statutil import fmt, pct  # noqa: F401 — shared with serve.metrics


def time_fn(fn, *args, warmup=2, iters=5, **kw):
    """Median wall time (seconds) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def row(name: str, seconds: float, derived: str = ""):
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)

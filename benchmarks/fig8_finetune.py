"""Paper Fig 8: sparse fine-tuning recovers the dense loss.

CPU-scale reproduction: train a reduced BERT-family model to convergence-ish,
one-shot n:m:g-sparsify the FFN/attention weights (loss jumps), then
fine-tune with fixed-pattern masked training and report recovery.
"""

import functools

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import FixedMaskTensor
from repro.core.sparsifiers import GroupedNMSparsifier
from repro.data import DataConfig, SyntheticLMPipeline
from repro.models import init_lm, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    value_and_grad_sparse
from repro.optim.sparse_update import resparsify_params


def main(steps=120, quick=False):
    if quick:
        steps = 40
    cfg = get_smoke("bert-base-sten")
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    opt_cfg = AdamWConfig(lr=1e-3)
    data = SyntheticLMPipeline(DataConfig(vocab=cfg.vocab, seq_len=64,
                                          global_batch=8, seed=0))

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, state, batch):
        (loss, _), g = value_and_grad_sparse(
            lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
        )(params)
        p2, s2, _ = adamw_update(g, state, params, opt_cfg)
        return resparsify_params(p2), s2, loss

    def run(params, n_steps, t0=0):
        state = adamw_init(params)
        last = None
        for i in range(n_steps):
            b = {k: jnp.asarray(v) for k, v in data.batch_at(t0 + i).items()}
            params, state, last = step(params, state, b)
        return params, float(last)

    print("phase,loss")
    params, dense_loss = run(params, steps)
    print(f"dense_trained,{dense_loss:.4f}")

    sb = SparsityBuilder()
    sp = GroupedNMSparsifier(1, 4, 4, sparse_dim=0)
    sb.set_weight("*mlp.w*", sp, FixedMaskTensor)
    sb.set_weight("*attn.wo", sp, FixedMaskTensor)
    sparse_params = sb.sparsify_params(params)

    b0 = {k: jnp.asarray(v) for k, v in data.batch_at(steps).items()}
    loss_after_prune = float(loss_fn(sparse_params, cfg, b0,
                                     remat="none")[0])
    print(f"pruned_1:4:4_no_finetune,{loss_after_prune:.4f}")

    sparse_params, ft_loss = run(sparse_params, steps, t0=steps)
    print(f"sparse_finetuned,{ft_loss:.4f}")
    rec = (loss_after_prune - ft_loss) / max(loss_after_prune - dense_loss,
                                             1e-9)
    print(f"recovery_fraction,{min(rec, 1.0):.2f}")


if __name__ == "__main__":
    main()

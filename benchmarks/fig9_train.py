"""Paper Fig 9, trainer edition: per-step wall time of the device-resident
multi-step trainer (launch/train.py::make_multi_step) — dense vs
fixed-pattern masked training vs GMP recompute-cadence training.

Unlike fig9_overheads.py (which times one hand-rolled jitted step), this
drives the production trainer itself: ``--log-every``-sized ``lax.scan``
chunks, in-jit ``lax.cond`` GMP pattern recomputes, on-device metrics.  The
gap between ``sparse-fixed`` and ``sparse-recompute-every-N`` is the cost
of 'new' vs 'fixed' sparsification amortized over the cadence (paper Fig 9)
— now paid inside jit instead of as a host-sync stall.

    PYTHONPATH=src python -m benchmarks.fig9_train [--quick]

Writes ``BENCH_train.json`` (one entry per variant, ms/step + derived
overhead vs dense) for the perf trajectory.
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.data import DataConfig, SyntheticLMPipeline
from repro.launch.train import (
    build_sparse_params,
    make_multi_step,
    stack_batches,
)
from repro.models import init_lm
from repro.optim import AdamWConfig, GMPSchedule, adamw_init

OUT_JSON = "BENCH_train.json"


def _bench_variant(cfg, params, gmp, n_inner, data, repeats):
    opt_cfg = AdamWConfig(lr=1e-4)
    state = adamw_init(params)
    multi = make_multi_step(cfg, opt_cfg, gmp, n_inner)

    def batches(lo):
        return stack_batches(data, lo, lo + n_inner)

    stop = jnp.int32(n_inner * (repeats + 1))
    # warm-up chunk (compile); donation consumes buffers, so thread them
    params, state, m = multi(params, state, batches(0), jnp.int32(0), stop)
    jax.block_until_ready(m["loss"])
    ts = []
    step = n_inner
    for _ in range(repeats):
        b = batches(step)
        t0 = time.perf_counter()
        params, state, m = multi(params, state, b, jnp.int32(step), stop)
        jax.block_until_ready(m["loss"])
        ts.append((time.perf_counter() - t0) / n_inner)
        step += n_inner
    ts.sort()
    return ts[len(ts) // 2]


def main(quick=False, out_json=OUT_JSON):
    cfg = get_smoke("bert-base-sten")
    if not quick:
        cfg = cfg.scaled(d_model=128, d_ff=512, n_layers=4, n_heads=8,
                         head_dim=16, vocab=2048)
    n_inner = 4 if quick else 10
    repeats = 3 if quick else 5
    key = jax.random.PRNGKey(0)
    data = SyntheticLMPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=64 if quick else 128,
        global_batch=4 if quick else 8, seed=0,
    ))

    # recompute-cadence schedules: a pattern recompute every N in-jit steps
    cadences = (2,) if quick else (2, 8)
    horizon = n_inner * (repeats + 1)

    variants = [("dense", init_lm(key, cfg), None)]
    sp_params = build_sparse_params(init_lm(key, cfg), 0.75)
    variants.append(("sparse-fixed", sp_params, None))
    for every in cadences:
        gmp = GMPSchedule(mode="iterative", target_sparsity=0.75,
                          begin_step=0, end_step=horizon,
                          recompute_every=every, num_layers=cfg.n_layers)
        variants.append((f"sparse-recompute-every-{every}",
                         build_sparse_params(init_lm(key, cfg),
                                             gmp.sparsity_at(0)), gmp))

    print("variant,ms_per_step,overhead_vs_dense")
    results = []
    t_dense = None
    for name, params, gmp in variants:
        t = _bench_variant(cfg, params, gmp, n_inner, data, repeats)
        if t_dense is None:
            t_dense = t
        over = (t / t_dense - 1.0) * 100.0
        print(f"{name},{t * 1e3:.2f}ms,{over:.0f}%")
        results.append({
            "name": name,
            "us_per_call": t * 1e6,
            "derived": f"overhead_vs_dense={over:.1f}%",
        })

    payload = {"benchmark": "train", "quick": bool(quick),
               "n_inner": n_inner, "results": results}
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=OUT_JSON)
    args = ap.parse_args()
    main(quick=args.quick, out_json=args.json)

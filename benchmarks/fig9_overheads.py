"""Paper Fig 9: masked sparse-training overheads vs dense.

Measures per-step wall time of the reduced BERT config: dense training,
masked training with a *fixed* sparsification (the common regime), and with
*new* sparsification (pattern recompute) every step, for unstructured and
n:m:g masks.
"""

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs import get_smoke
from repro.core.builder import SparsityBuilder
from repro.core.layouts import FixedMaskTensor
from repro.core.sparsifiers import GroupedNMSparsifier, ScalarFractionSparsifier
from repro.models import init_lm, loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    value_and_grad_sparse
from repro.optim.sparse_update import resparsify_params


def make_step(cfg, recompute):
    opt_cfg = AdamWConfig(lr=1e-4)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, state, batch):
        (loss, _), g = value_and_grad_sparse(
            lambda p: loss_fn(p, cfg, batch, remat="none"), has_aux=True
        )(params)
        p2, s2, _ = adamw_update(g, state, params, opt_cfg)
        p2 = resparsify_params(p2, recompute_pattern=recompute)
        return p2, s2, loss

    return step


def main(quick=False):
    cfg = get_smoke("bert-base-sten")
    if not quick:
        cfg = cfg.scaled(d_model=128, d_ff=512, n_layers=4, n_heads=8,
                         head_dim=16, vocab=2048)
    key = jax.random.PRNGKey(0)
    B, S = 8, 128
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }

    def bench(params, recompute, name, t_base=None):
        state = adamw_init(params)
        step = make_step(cfg, recompute)

        def run(p, s):
            p2, s2, l = step(p, s, batch)
            return p2, s2, l

        # time with fresh copies (donation consumes buffers)
        import time as _t
        outs = step(params, state, batch)
        jax.block_until_ready(outs)
        p2, s2, _ = outs
        ts = []
        for _ in range(5):
            t0 = _t.perf_counter()
            p2, s2, l = step(p2, s2, batch)
            jax.block_until_ready(l)
            ts.append(_t.perf_counter() - t0)
        ts.sort()
        t = ts[len(ts) // 2]
        over = "" if t_base is None else f",{(t / t_base - 1) * 100:.0f}%"
        print(f"{name},{t * 1e3:.1f}ms{over}")
        return t

    print("variant,ms_per_step,overhead_vs_dense")
    params = init_lm(key, cfg)
    t_dense = bench(params, False, "dense")

    sb = SparsityBuilder()
    sb.set_weight("*mlp*", ScalarFractionSparsifier(0.75), FixedMaskTensor)
    sb.set_weight("*attn.w*", ScalarFractionSparsifier(0.75), FixedMaskTensor)
    sp = sb.sparsify_params(init_lm(key, cfg))
    bench(sp, False, "unstructured-fixed", t_dense)
    bench(sb.sparsify_params(init_lm(key, cfg)), True,
          "unstructured-new", t_dense)

    sb2 = SparsityBuilder()
    sb2.set_weight("*mlp*", GroupedNMSparsifier(1, 4, 16, sparse_dim=0),
                   FixedMaskTensor)
    bench(sb2.sparsify_params(init_lm(key, cfg)), False, "nmg-fixed", t_dense)
    bench(sb2.sparsify_params(init_lm(key, cfg)), True, "nmg-new", t_dense)


if __name__ == "__main__":
    main()

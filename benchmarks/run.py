"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints each benchmark's CSV block plus a trailing summary in
``name,us_per_call,derived`` form.
"""

import argparse
import time
import traceback

from benchmarks import (
    fig7_energy,
    fig8_finetune,
    fig9_overheads,
    fig10_gemm,
    fig11_e2e,
    table2_productivity,
    weak_scaling,
)

BENCHES = [
    ("fig7_energy", fig7_energy.main),
    ("fig10_gemm", fig10_gemm.main),
    ("fig9_overheads", fig9_overheads.main),
    ("fig11_e2e", fig11_e2e.main),
    ("fig8_finetune", fig8_finetune.main),
    ("table2_productivity", table2_productivity.main),
    ("weak_scaling", weak_scaling.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    summary = []
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"\n=== {name} " + "=" * (60 - len(name)), flush=True)
        t0 = time.time()
        try:
            fn(quick=args.quick)
            summary.append((name, time.time() - t0, "ok"))
        except Exception as e:  # keep the harness going
            traceback.print_exc()
            summary.append((name, time.time() - t0, f"FAIL:{type(e).__name__}"))

    print("\n=== summary ===")
    print("name,us_per_call,derived")
    for name, secs, status in summary:
        print(f"{name},{secs * 1e6:.0f},{status}")
    if any("FAIL" in s for _, _, s in summary):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Prints each benchmark's CSV block plus a trailing summary in
``name,us_per_call,derived`` form, and writes the same summary as
machine-readable JSON to ``BENCH_bench.json`` (the file the perf
trajectory ingests).
"""

import argparse
import time
import traceback

from repro.ioutil import atomic_write_json

from benchmarks import (
    fig6_spmm,
    fig7_energy,
    fig8_finetune,
    fig9_overheads,
    fig9_train,
    fig10_gemm,
    fig11_e2e,
    fig11_serve,
    table2_productivity,
    weak_scaling,
)

BENCHES = [
    ("fig6_spmm", fig6_spmm.main),
    ("fig7_energy", fig7_energy.main),
    ("fig10_gemm", fig10_gemm.main),
    ("fig9_overheads", fig9_overheads.main),
    ("fig9_train", fig9_train.main),
    ("fig11_e2e", fig11_e2e.main),
    ("fig11_serve", fig11_serve.main),
    ("fig8_finetune", fig8_finetune.main),
    ("table2_productivity", table2_productivity.main),
    ("weak_scaling", weak_scaling.main),
]

SUMMARY_JSON = "BENCH_bench.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=SUMMARY_JSON,
                    help="summary JSON output path")
    args = ap.parse_args()

    if args.only and args.only not in {n for n, _ in BENCHES}:
        raise SystemExit(
            f"--only {args.only!r} matches no benchmark; known: "
            + ", ".join(n for n, _ in BENCHES)
        )

    summary = []
    detail = []  # per-measurement records a benchmark returns (fig6_spmm)
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"\n=== {name} " + "=" * (60 - len(name)), flush=True)
        t0 = time.time()
        try:
            ret = fn(quick=args.quick)
            summary.append((name, time.time() - t0, "ok"))
            if isinstance(ret, list):
                detail.extend(r for r in ret if isinstance(r, dict))
        except Exception as e:  # keep the harness going
            traceback.print_exc()
            summary.append((name, time.time() - t0, f"FAIL:{type(e).__name__}"))

    print("\n=== summary ===")
    print("name,us_per_call,derived")
    for name, secs, status in summary:
        print(f"{name},{secs * 1e6:.0f},{status}")

    results = [
        {"name": name, "us_per_call": secs * 1e6, "derived": status}
        for name, secs, status in summary
    ]
    atomic_write_json(args.json, {
        "benchmark": "bench",
        "quick": bool(args.quick),
        # wall time per benchmark, then each benchmark's own
        # per-measurement records (e.g. fig6_spmm's per-(path, M)
        # kernel timings)
        "results": results + detail,
    })
    print(f"wrote {args.json}")

    if any("FAIL" in s for _, _, s in summary):
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Paper Fig 7: energy (||X_hat||_1 / ||X||_1) vs sparsity structure.

Compares unstructured, n:m, n:m:g (several g), and blocked sparsity on a
BERT_BASE FFN-sized weight tensor (768 x 3072), plus the TPU row-sharing
(gr) adaptation cost.  Expected trends (validated in tests/test_nmg.py):
unstructured >= n:m >= n:m:g(large g) >= n:m:g(small g) >= blocked.
"""

import jax
import jax.numpy as jnp

from repro.core import nmg


def main(rows=768, cols=3072, seed=0, quick=False):
    if quick:
        rows, cols = 256, 768
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols))
    print("format,sparsity,energy")
    for n, m in [(2, 4), (1, 4), (1, 10)]:
        s = 1 - n / m
        e_un = float(nmg.energy(x * nmg.unstructured_mask(x, s), x))
        print(f"unstructured,{s:.2f},{e_un:.4f}")
        e_nm = float(nmg.energy(x * nmg.nm_mask(x, n, m), x))
        print(f"{n}:{m},{s:.2f},{e_nm:.4f}")
        for g in (1, 4, 16):
            t = nmg.dense_to_grouped_nm(x, n, m, g)
            e = float(nmg.energy(t.to_dense(), x))
            print(f"{n}:{m}:{g},{s:.2f},{e:.4f}")
        for gr in (8, 128):
            t = nmg.dense_to_grouped_nm(x, n, m, 16, gr=gr)
            e = float(nmg.energy(t.to_dense(), x))
            print(f"{n}:{m}:16/gr{gr},{s:.2f},{e:.4f}")
        e_bl = float(nmg.energy(x * nmg.blocked_mask(x, m, s), x))
        print(f"blocked{m},{s:.2f},{e_bl:.4f}")


if __name__ == "__main__":
    main()

"""Paper §6.1: distributed masked-sparse-training overhead (weak scaling).

Spawns subprocesses with 1..8 fake host devices (fixed per-device batch) and
measures dense vs masked-sparse step time including gradient sync, reporting
scaling efficiency and the sparse-over-dense overhead — the CPU-scale
analogue of the paper's 128-GPU Piz Daint experiment.
"""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_WORKER = """
    import time, functools
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.core.builder import SparsityBuilder
    from repro.core.layouts import FixedMaskTensor
    from repro.core.sparsifiers import ScalarFractionSparsifier
    from repro.dist.sharding import ShardingRules, param_specs, tree_shardings
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_host_mesh
    from repro.models import init_lm
    from repro.optim import AdamWConfig, adamw_init

    ndev = len(jax.devices())
    cfg = get_smoke("bert-base-sten")
    mesh = make_host_mesh(ndev, 1)
    rules = ShardingRules(batch=("data",), embed=None, heads=None, ff=None,
                          vocab=None, expert=None)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    if {SPARSE}:
        sb = SparsityBuilder()
        sb.set_weight("*mlp.w*", ScalarFractionSparsifier(0.75),
                      FixedMaskTensor)
        sb.set_weight("*attn.w*", ScalarFractionSparsifier(0.75),
                      FixedMaskTensor)
        params = sb.sparsify_params(params)
    opt = adamw_init(params)
    step = steps_mod.make_train_step(
        cfg, AdamWConfig(lr=1e-3), steps_mod.StepConfig(remat="none"),
        mesh, rules)
    B = 2 * ndev   # fixed per-device batch (weak scaling)
    batch = {{
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, 64), 0,
                                     cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (B, 64), 0,
                                     cfg.vocab),
    }}
    with mesh:
        jstep = jax.jit(step)
        out = jstep(params, opt, batch); jax.block_until_ready(out)
        p, o, _ = out
        ts = []
        for _ in range(5):
            t0 = time.perf_counter()
            p, o, m = jstep(p, o, batch)
            jax.block_until_ready(m)
            ts.append(time.perf_counter() - t0)
        ts.sort()
    print("RESULT", ts[len(ts) // 2])
"""


def run(ndev: int, sparse: bool) -> float:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    code = textwrap.dedent(_WORKER).replace("{SPARSE}", str(sparse)) \
        .replace("{{", "{").replace("}}", "}")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            return float(line.split()[1])
    raise RuntimeError(out.stdout)


def main(quick=False):
    devs = [1, 4] if quick else [1, 2, 4, 8]
    print("devices,dense_ms,sparse_ms,dense_eff,sparse_eff,sparse_overhead")
    base_d = base_s = None
    for nd in devs:
        td, ts = run(nd, False), run(nd, True)
        base_d = base_d or td
        base_s = base_s or ts
        print(f"{nd},{td * 1e3:.1f},{ts * 1e3:.1f},"
              f"{base_d / td * 100:.0f}%,{base_s / ts * 100:.0f}%,"
              f"{(ts / td - 1) * 100:.0f}%")


if __name__ == "__main__":
    main()

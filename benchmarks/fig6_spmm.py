"""Paper Fig 6 territory, kernel edition: n:m:g matmul microbenchmark
sweeping the right-operand width M across both kernel paths.

For a serving-shaped 1:4:8 weight (gr-row-shared), times

* ``gemv``  — the decode-specialized activation-stationary path
              (``kernels/ops.py:nmg_gemv_xla``),
* ``spmm``  — the prefill-shaped blocked gather-einsum path
              (``kernels/ops.py:nmg_spmm_xla``),
* ``dense`` — the XLA dense matmul baseline on the same shapes,

at M in {1, 2, 4, 8, 16, 64, 128} — decode batches at the narrow end,
prefill tiles at the wide end.  The sweep and timing machinery is
``repro.tune.bench`` (:func:`~repro.tune.bench.sweep_m` /
:func:`~repro.tune.bench.time_us`) — the same code the autotuner runs, so
this figure and the tuning table can never disagree about what was
measured.  The **measured gemv/spmm crossover M** — the empirical value
of the router's ``decode_m_max`` for this shape — is computed from the
sweep and recorded alongside the raw timings.

A second section benchmarks the **decode megakernels**
(:mod:`repro.kernels.nmg_fused`) at the fig11 serving shapes: the fused
QKV launch against the per-projection ``nmg_gemv`` path it replaces, and
the fused gated-FFN against projection+split+act+gate, each with a
modelled roofline distance (flops/bytes of the sparse operator against
the ``launch.hlo_analysis.HW`` peak rates).  The run **fails** (exit
nonzero) if the router did not drive the fused route from the table or
the shipped defaults — the CI perf-smoke leans on that to catch silent
fallbacks to the per-projection path.

Run standalone (prints CSV, merges its records into ``BENCH_bench.json``)
or through ``benchmarks/run.py``, which merges the per-(path, M)
``us_per_call`` records (and the crossover + megakernel records) this
module returns into ``BENCH_bench.json``.

    PYTHONPATH=src python -m benchmarks.fig6_spmm [--quick]
"""

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

from repro.core import nmg
from repro.kernels import ops as kops
from repro.launch.hlo_analysis import HW
from repro.tune import bench

# serving-shaped weight: sparse along the input axis, rows shared gr-wide
N_, M_, G_, GR_ = 1, 4, 8, 64
K, N_OUT = 1024, 1024

# fig11 serving-config shapes for the megakernel section: d_model 256,
# n_heads = n_kv_heads = 4 x head_dim 64, d_ff 4096, max_slots 4
D_MODEL = 256
QKV_ROWS = (256, 256, 256)
D_FF = 4096
SERVE_SLOTS = 4


def _roofline_us(flops: float, bytes_: float) -> float:
    """Modelled per-call floor (us) on the reference TPU chip: the
    slower of the compute and HBM terms.  Off-TPU runs still record it —
    the *distance* column is then hardware-mismatched and only the
    fused-vs-sequential ratio is meaningful."""
    return max(flops / HW["peak_flops_bf16"], bytes_ / HW["hbm_bw"]) * 1e6


def _nbytes(*arrays) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in arrays)


def megakernel_main(quick=False):
    """Fused-QKV and fused-FFN decode timings vs their sequential
    equivalents, plus the decode-step launch-count and route-provenance
    records.  Raises SystemExit if the fused route was not table- or
    default-driven.

    The sequential baseline is measured at *launch* granularity — one
    dispatched kernel per projection (and one per FFN stage), which is
    exactly the structure the megakernel collapses: on TPU three
    ``pallas_call`` launches re-gathering the same activations become
    one, and off-TPU three XLA dispatches become one.  A single-program
    sequential baseline would hide the cost being removed."""
    ms = (1, SERVE_SLOTS) if quick else (1, 2, SERVE_SLOTS, 8)
    reps = 5 if quick else 9
    inner = 20  # per-rep loop: launch-overhead measurements need the depth
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 4)
    ws = tuple(
        nmg.dense_to_grouped_nm(
            jax.random.normal(k, (D_MODEL, R), jnp.float32),
            n=N_, m=M_, g=G_, gr=GR_, sparse_dim=0)
        for k, R in zip(ks[:3], QKV_ROWS))
    wi = nmg.dense_to_grouped_nm(
        jax.random.normal(ks[3], (D_MODEL, 2 * D_FF), jnp.float32),
        n=N_, m=M_, g=G_, gr=GR_, sparse_dim=0)
    fmt_str = f"{N_}:{M_}:{G_} gr{GR_} fig11-serve D{D_MODEL} dff{D_FF}"

    use_pallas = kops.on_tpu()  # XLA lowering off-TPU; interpret is smoke-only
    fused_qkv = jax.jit(lambda b: kops.nmg_qkv_xla(ws, b)) \
        if not use_pallas else jax.jit(lambda b: kops.nmg_qkv(ws, b))
    gemv_launches = tuple(
        jax.jit(lambda b, w=w: kops.nmg_gemv(w, b, use_pallas=use_pallas))
        for w in ws)

    def seq_qkv(b):  # three dispatches: the pre-fusion decode structure
        return tuple(f(b) for f in gemv_launches)

    fused_ffn = jax.jit(lambda b: kops.nmg_ffn_xla(wi, b, act="silu")) \
        if not use_pallas else jax.jit(
            lambda b: kops.nmg_ffn(wi, b, act="silu"))
    ffn_proj = jax.jit(lambda b: kops.nmg_gemv(
        wi, b, use_pallas=use_pallas, transpose_out=True))

    @jax.jit
    def ffn_gate(hh):
        u, v = jnp.split(hh, 2, axis=-1)
        return (jax.nn.silu(u) * v).T

    def seq_ffn(b):  # projection launch, then the gate epilogue dispatch
        return ffn_gate(ffn_proj(b))

    # operator intensity: sparse flops keep only the n/m fraction of the
    # dense contraction; bytes move compressed storage + activations
    density = N_ / M_
    qkv_val_bytes = _nbytes(*(w.val for w in ws), *(w.blk_idx for w in ws))
    ffn_val_bytes = _nbytes(wi.val, wi.blk_idx)

    records = []
    print("path,M,us_per_call,seq_us,speedup,roofline_us,distance")
    for M in ms:
        b = jax.random.normal(jax.random.fold_in(key, M), (D_MODEL, M))
        for path, f_fn, s_fn, rows, sbytes in (
            ("megakernel_qkv", fused_qkv, seq_qkv, sum(QKV_ROWS),
             qkv_val_bytes),
            ("megakernel_ffn", fused_ffn, seq_ffn, 2 * D_FF, ffn_val_bytes),
        ):
            # interleaved rounds, best-of: launch-overhead deltas are
            # tens of us and a noisy/contended runner inflates both
            # paths asymmetrically; the per-path minimum is the robust
            # estimator of the uncontended cost
            f_us = min(bench.time_us(f_fn, b, reps=reps, inner=inner)
                       for _ in range(3))
            s_us = min(bench.time_us(s_fn, b, reps=reps, inner=inner)
                       for _ in range(3))
            flops = 2.0 * M * rows * D_MODEL * density
            bytes_ = sbytes + _nbytes(b) + rows * M * 4
            ideal = _roofline_us(flops, bytes_)
            records.append({
                "name": f"fig6_spmm/{path}_M{M}",
                "us_per_call": f_us,
                "sequential_us": s_us,
                "speedup_vs_sequential": s_us / f_us,
                "roofline_ideal_us": ideal,
                "roofline_distance": f_us / ideal,
                "derived": fmt_str,
            })
            print(f"{path},{M},{f_us:.1f},{s_us:.1f},{s_us / f_us:.2f},"
                  f"{ideal:.2f},{f_us / ideal:.1f}")

    # route provenance at the decode shape: the serving engine reaches the
    # megakernels through maybe_fused_*; assert the router actually drove
    # them (table or shipped default — never a silent per-projection or
    # dense fallback)
    x = jax.random.normal(key, (SERVE_SLOTS, D_MODEL))
    kops.reset_kernel_counters()
    assert kops.maybe_fused_qkv(x, ws) is not None
    assert kops.maybe_fused_ffn(x, wi, act="silu") is not None
    counts = kops.kernel_counters()
    qkv_route = next((k[1] for k in counts if k[0] == "nmg_qkv"), None)
    ffn_route = next((k[1] for k in counts if k[0] == "nmg_ffn"), None)
    ok = (qkv_route in ("fused[table]", "fused[default]")
          and ffn_route in ("fused[table]", "fused[default]"))
    fused_launches = sum(v for k, v in counts.items()
                         if k[1].startswith("fused["))
    records.append({
        "name": "fig6_spmm/megakernel_decode_launches",
        "fused_launches_per_step": fused_launches,
        "sequential_launches_per_step": len(ws) + 1,  # q,k,v gemvs + packed wi
        "qkv_route": qkv_route,
        "ffn_route": ffn_route,
        "derived": fmt_str,
    })
    print(f"decode_launches,{fused_launches},(sequential {len(ws) + 1}),"
          f"qkv={qkv_route},ffn={ffn_route}")
    if not ok:
        raise SystemExit(
            f"megakernel route not table-/default-driven: qkv={qkv_route} "
            f"ffn={ffn_route} — the decode path regressed to a fallback")
    return records


def main(quick=False):
    ms = (1, 4, 16, 128) if quick else (1, 2, 4, 8, 16, 64, 128)
    reps = 5 if quick else 9
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N_OUT), jnp.float32)
    t = nmg.dense_to_grouped_nm(w, n=N_, m=M_, g=G_, gr=GR_, sparse_dim=0)
    fmt_str = f"{N_}:{M_}:{G_} gr{GR_} K{K} N{N_OUT}"

    sweep = bench.sweep_m(t, key, ms, reps=reps, include_dense=True)

    records = []
    print("path,M,us_per_call")
    for r in sweep:
        records.append({
            "name": f"fig6_spmm/{r['path']}_M{r['M']}",
            "us_per_call": r["us"],
            "derived": fmt_str,
        })
        print(f"{r['path']},{r['M']},{r['us']:.1f}")

    # the empirical decode_m_max for this shape — what `python -m
    # repro.tune` would write into the table's matching bucket, and what
    # the shipped DECODE_M_MAX default approximates
    crossover = bench.measured_crossover(sweep)
    records.append({
        "name": "fig6_spmm/gemv_spmm_crossover_M",
        "crossover_M": crossover,
        "shipped_default": kops.DECODE_M_MAX,
        "derived": fmt_str,
    })
    print(f"crossover,{crossover},(shipped default {kops.DECODE_M_MAX})")

    records.extend(megakernel_main(quick=quick))
    return records


def _merge_into_bench_json(records, path="BENCH_bench.json"):
    """Standalone-run persistence: replace same-name records in (or append
    to) the summary JSON ``benchmarks/run.py`` owns, so a bare
    ``python -m benchmarks.fig6_spmm`` still feeds the perf trajectory."""
    p = pathlib.Path(path)
    doc = json.loads(p.read_text()) if p.exists() else {
        "benchmark": "bench", "results": []}
    names = {r["name"] for r in records}
    doc["results"] = [r for r in doc.get("results", [])
                      if r.get("name") not in names] + records
    p.write_text(json.dumps(doc, indent=2))
    print(f"merged {len(records)} records into {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="BENCH_bench.json",
                    help="summary JSON to merge records into")
    args = ap.parse_args()
    _merge_into_bench_json(main(quick=args.quick), args.json)

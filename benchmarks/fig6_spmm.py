"""Paper Fig 6 territory, kernel edition: n:m:g matmul microbenchmark
sweeping the right-operand width M across both kernel paths.

For a serving-shaped 1:4:8 weight (gr-row-shared), times

* ``gemv``  — the decode-specialized activation-stationary path
              (``kernels/ops.py:nmg_gemv_xla``),
* ``spmm``  — the prefill-shaped blocked gather-einsum path
              (``kernels/ops.py:nmg_spmm_xla``),
* ``dense`` — the XLA dense matmul baseline on the same shapes,

at M in {1, 2, 4, 8, 16, 64, 128} — decode batches at the narrow end,
prefill tiles at the wide end.  The sweep and timing machinery is
``repro.tune.bench`` (:func:`~repro.tune.bench.sweep_m` /
:func:`~repro.tune.bench.time_us`) — the same code the autotuner runs, so
this figure and the tuning table can never disagree about what was
measured.  The **measured gemv/spmm crossover M** — the empirical value
of the router's ``decode_m_max`` for this shape — is computed from the
sweep and recorded alongside the raw timings.

Run standalone (prints CSV) or through ``benchmarks/run.py``, which merges
the per-(path, M) ``us_per_call`` records (and the crossover record) this
module returns into ``BENCH_bench.json``.

    PYTHONPATH=src python -m benchmarks.fig6_spmm [--quick]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import nmg
from repro.kernels import ops as kops
from repro.tune import bench

# serving-shaped weight: sparse along the input axis, rows shared gr-wide
N_, M_, G_, GR_ = 1, 4, 8, 64
K, N_OUT = 1024, 1024


def main(quick=False):
    ms = (1, 4, 16, 128) if quick else (1, 2, 4, 8, 16, 64, 128)
    reps = 5 if quick else 9
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N_OUT), jnp.float32)
    t = nmg.dense_to_grouped_nm(w, n=N_, m=M_, g=G_, gr=GR_, sparse_dim=0)
    fmt_str = f"{N_}:{M_}:{G_} gr{GR_} K{K} N{N_OUT}"

    sweep = bench.sweep_m(t, key, ms, reps=reps, include_dense=True)

    records = []
    print("path,M,us_per_call")
    for r in sweep:
        records.append({
            "name": f"fig6_spmm/{r['path']}_M{r['M']}",
            "us_per_call": r["us"],
            "derived": fmt_str,
        })
        print(f"{r['path']},{r['M']},{r['us']:.1f}")

    # the empirical decode_m_max for this shape — what `python -m
    # repro.tune` would write into the table's matching bucket, and what
    # the shipped DECODE_M_MAX default approximates
    crossover = bench.measured_crossover(sweep)
    records.append({
        "name": "fig6_spmm/gemv_spmm_crossover_M",
        "crossover_M": crossover,
        "shipped_default": kops.DECODE_M_MAX,
        "derived": fmt_str,
    })
    print(f"crossover,{crossover},(shipped default {kops.DECODE_M_MAX})")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)

"""Paper Fig 6 territory, kernel edition: n:m:g matmul microbenchmark
sweeping the right-operand width M across both kernel paths.

For a serving-shaped 1:4:8 weight (gr-row-shared), times

* ``gemv``  — the decode-specialized activation-stationary path
              (``kernels/ops.py:nmg_gemv_xla``),
* ``spmm``  — the prefill-shaped blocked gather-einsum path
              (``kernels/ops.py:nmg_spmm_xla``),
* ``dense`` — the XLA dense matmul baseline on the same shapes,

at M in {1, 2, 4, 8, 16, 64, 128} — decode batches at the narrow end,
prefill tiles at the wide end.  The crossover this sweep exposes is what
the shape router (``nmg_matmul`` / ``DECODE_M_MAX``) encodes.

Run standalone (prints CSV) or through ``benchmarks/run.py``, which merges
the per-(path, M) ``us_per_call`` records this module returns into
``BENCH_bench.json``.

    PYTHONPATH=src python -m benchmarks.fig6_spmm [--quick]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import nmg
from repro.kernels import ops as kops

# serving-shaped weight: sparse along the input axis, rows shared gr-wide
N_, M_, G_, GR_ = 1, 4, 8, 64
K, N_OUT = 1024, 1024


def _time_us(fn, *args, reps: int, inner: int = 5) -> float:
    """Median-of-``reps`` wall time of ``inner`` back-to-back calls (us)."""
    jax.block_until_ready(fn(*args))  # compile outside the timed region
    best = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = fn(*args)
        jax.block_until_ready(out)
        best.append((time.perf_counter() - t0) / inner)
    best.sort()
    return best[len(best) // 2] * 1e6


def main(quick=False):
    ms = (1, 4, 16, 128) if quick else (1, 2, 4, 8, 16, 64, 128)
    reps = 5 if quick else 9
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (K, N_OUT), jnp.float32)
    t = nmg.dense_to_grouped_nm(w, n=N_, m=M_, g=G_, gr=GR_, sparse_dim=0)
    wd = t.to_dense()  # identical nonzeros for the dense baseline

    gemv = jax.jit(lambda a, b: kops.nmg_gemv_xla(a, b))
    spmm = jax.jit(lambda a, b: kops.nmg_spmm_xla(a, b))
    dense = jax.jit(lambda b, w: (b.T @ w).T)

    records = []
    print("path,M,us_per_call")
    for m in ms:
        b = jax.random.normal(jax.random.fold_in(key, m), (K, m), jnp.float32)
        for path, fn, args in (
            ("gemv", gemv, (t, b)),
            ("spmm", spmm, (t, b)),
            ("dense", dense, (b, wd)),
        ):
            us = _time_us(fn, *args, reps=reps)
            records.append({
                "name": f"fig6_spmm/{path}_M{m}",
                "us_per_call": us,
                "derived": f"{N_}:{M_}:{G_} gr{GR_} K{K} N{N_OUT}",
            })
            print(f"{path},{m},{us:.1f}")
    return records


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)

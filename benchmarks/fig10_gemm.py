"""Paper Fig 10: n:m:g sparse-dense GEMM vs dense, on the paper's exact
768 x 3072 x 4096 BERT_BASE feed-forward GEMM.

Measured here: XLA-CPU wall time of the production gather-based path vs the
dense matmul (the CPU analogue of the paper's measured speedups), plus the
analytical TPU v5e roofline for the Pallas kernel (FLOP and HBM-byte counts
of the compressed layout), since this container has no TPU.
"""

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.core import nmg
from repro.kernels import ops as kops


def tpu_roofline_us(M, K, N, n, m, dtype_bytes=2):
    """Pallas-kernel roofline: compute vs memory bound time (us/GEMM)."""
    flops = 2 * M * N * K * n / m                    # only nnz contribute
    bytes_ = (M * K * n / m + K * N + M * N) * dtype_bytes
    t_c = flops / 197e12
    t_m = bytes_ / 819e9
    return max(t_c, t_m) * 1e6, ("compute" if t_c > t_m else "memory")


def main(M=768, K=3072, N=4096, quick=False):
    if quick:
        M, K, N = 256, 768, 1024
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)

    dense = jax.jit(lambda a, b: a @ b)
    t_dense = time_fn(dense, a, b)
    print("kernel,sparsity,us_per_gemm,speedup_vs_dense,tpu_roofline_us")
    d_ro, _ = tpu_roofline_us(M, K, N, 1, 1)
    print(f"dense,0.00,{t_dense * 1e6:.0f},1.00,{2*M*N*K/197e12*1e6:.1f}")

    for n, m, g in [(2, 4, 16), (1, 4, 16), (1, 10, 4)]:
        t = nmg.dense_to_grouped_nm(a, n=n, m=m, g=g, gr=16)
        f = jax.jit(lambda t, b: kops.nmg_spmm_xla(t, b))
        t_sp = time_fn(f, t, b)
        ro, bound = tpu_roofline_us(M, K, N, n, m)
        print(f"{n}:{m}:{g},{1 - n / m:.2f},{t_sp * 1e6:.0f},"
              f"{t_dense / t_sp:.2f},{ro:.1f}({bound})")


if __name__ == "__main__":
    main()
